"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; multi-device tests spawn subprocesses.

The repo root is added to sys.path so ``PYTHONPATH=src pytest tests/``
resolves the ``benchmarks`` package too."""
import pathlib
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

# silent rank promotion is how shape bugs ship: a [t] vector broadcast
# against a [t, d] activation runs fine and routes garbage.  Raise
# everywhere under test; production code must broadcast explicitly.
jax.config.update("jax_numpy_rank_promotion", "raise")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
