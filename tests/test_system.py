"""End-to-end behaviour tests: training drives loss down on the learnable
synthetic stream; serving produces tokens; benchmarks yield paper-shaped
results; configs cover the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ALL_SHAPES, ARCH_IDS, ReaLBConfig, TrainConfig,
                           all_cells, get_config, reduced)
from repro.core import init_m_state
from repro.data.pipeline import DataConfig, DataLoader
from repro.models import transformer as tf
from repro.optim import adamw

pytestmark = pytest.mark.slow    # end-to-end train/serve/benchmark runs


def test_assignment_coverage():
    cells = all_cells()
    assert len(ARCH_IDS) == 10
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    # long_500k runs only for the SSM and hybrid archs
    assert all(s == "long_500k" for _, s in skipped)
    runs_long = {a for a, s, ok, _ in cells if s == "long_500k" and ok}
    assert runs_long == {"falcon-mamba-7b", "jamba-1.5-large-398b"}


def test_training_reduces_loss():
    """~100 steps on the Markov LM stream must clearly reduce CE."""
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, vocab_size=128)
    rcfg = ReaLBConfig(enabled=False)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=10, total_steps=100)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, tcfg)
    m = init_m_state(1, 1, rcfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    data = DataLoader(dc)

    @jax.jit
    def step(params, opt, m, batch):
        (loss, (m2, _)), g = jax.value_and_grad(
            tf.train_loss, has_aux=True)(params, cfg, rcfg, batch, m)
        params, opt, _ = adamw.adamw_update(params, g, opt, tcfg)
        return params, opt, m2, loss

    losses = []
    for _ in range(100):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m, loss = step(params, opt, m, b)
        losses.append(float(loss))
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first - 0.5, (first, last)


def test_benchmark_modules_produce_paper_shaped_results():
    from benchmarks import costmodel as cm
    from benchmarks import traces as tr
    from repro.configs import ReaLBConfig as RC

    g = cm.KIMI_VL
    cfg = tr.workload("MMMU", iters=120)
    base = cm.sim_baseline(cfg, g)
    fp4 = cm.sim_fp4_all(cfg, g)
    realb = cm.sim_realb(cfg, g, RC())
    seq = cm.sim_realb(cfg, g, RC(), name="seq", overlap=False)
    eplb = cm.sim_eplb(cfg, g)
    s = {r.name: r.e2e_speedup(base, g) for r in (fp4, realb, seq, eplb)}
    # paper-shaped ordering: FP4-All >= ReaLB > ReaLB-seq > EPLB ~ 1
    assert s["FP4-All"] >= s["ReaLB"] - 0.02
    assert s["ReaLB"] > s["seq"]
    assert s["ReaLB"] > s["EPLB"]
    assert 0.9 < s["EPLB"] < 1.1
    assert 0.0 < realb.fp4_token_frac < 1.0


def test_trace_dynamics_match_paper():
    from benchmarks import traces as tr
    s = tr.trace_stats(tr.workload("MMMU", iters=200))
    assert 2.0 <= s["expert_imb_mean"] <= 14.0       # paper: 2–12×
    assert 1.3 <= s["device_imb_mean"] <= 3.5        # paper: 2–3× peaks
    assert s["vision_ratio_max_mean"] > 0.8          # >90% vision devices
    assert s["hot_device_flips_per_100it"] > 1.0     # hot spots move


def test_aimd_sawtooth():
    """Congestion halves M; calm raises it by 0.1 — visible sawtooth."""
    from repro.core.policy import realb_policy
    rcfg = ReaLBConfig(gate_gamma=0)
    m = jnp.full((4,), 0.9)
    hot = jnp.asarray([4000.0, 100.0, 100.0, 100.0])
    calm = jnp.asarray([1000.0, 1000.0, 1000.0, 1000.0])
    m = realb_policy(hot, hot, m, rcfg).m_new
    assert float(m[0]) == pytest.approx(0.45)
    for _ in range(3):
        m = realb_policy(calm, calm, m, rcfg).m_new
    assert float(m[0]) == pytest.approx(0.75)


def test_dryrun_artifacts_if_present():
    """If the sweep has run, every non-skipped cell must be ok on both
    meshes (the repo ships with the artifacts)."""
    import json
    import pathlib
    d = pathlib.Path("experiments/dryrun")
    if not d.exists() or not list(d.glob("*.json")):
        pytest.skip("dry-run artifacts not generated yet")
    bad = []
    for f in d.glob("*.json"):
        if ".opt" in f.name or ".base" in f.name:
            continue  # perf-iteration variants are tracked in EXPERIMENTS.md
        r = json.loads(f.read_text())
        if r["status"] not in ("ok", "skipped"):
            bad.append((f.name, r.get("error", "")[:100]))
    assert not bad, bad
