"""Per-architecture smoke tests: every assigned arch, reduced config —
one train step + prefill + decode on CPU, asserting shapes and finiteness;
plus prefill/decode consistency for representative families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ReaLBConfig, get_config, reduced
from repro.core import init_m_state
from repro.models import transformer as tf

RCFG = ReaLBConfig(gate_gamma=4)


def _batch(cfg, rng, b=2, s=16):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.enc_seq_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.n_vision_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, rng):
    cfg = reduced(get_config(arch))
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    m = init_m_state(1, 1, RCFG)

    loss, (m2, metrics) = tf.train_loss(params, cfg, RCFG, batch, m)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    res = tf.prefill_forward(params, cfg, RCFG, batch, m, cache_len=s + 4)
    assert res.logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(res.logits).all())

    db = {"tokens": batch["tokens"][:, :1],
          "pos": jnp.full((b,), s, jnp.int32)}
    res2 = tf.decode_forward(params, cfg, RCFG, db, res.cache, res.m_state)
    assert res2.logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(res2.logits).all())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "minicpm3-4b",
                                  "falcon-mamba-7b", "olmoe-1b-7b",
                                  "jamba-1.5-large-398b"])
def test_prefill_decode_consistency(arch, rng):
    """decode(token s | cache of s tokens) == prefill(s+1 tokens) logits."""
    cfg = reduced(get_config(arch))
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    full = _batch(cfg, rng, b, s + 1)
    m = init_m_state(1, 1, RCFG)

    ref = tf.prefill_forward(params, cfg, RCFG, full, m, cache_len=s + 1)

    pre_batch = {k: (v[:, :s] if k in ("tokens", "labels") else v)
                 for k, v in full.items()}
    res = tf.prefill_forward(params, cfg, RCFG, pre_batch, m,
                             cache_len=s + 1)
    db = {"tokens": full["tokens"][:, s:s + 1],
          "pos": jnp.full((b,), s, jnp.int32)}
    dec = tf.decode_forward(params, cfg, RCFG, db, res.cache, res.m_state)

    np.testing.assert_allclose(np.asarray(dec.logits),
                               np.asarray(ref.logits), rtol=2e-3, atol=2e-3)


def test_vlm_modality_default_mask(rng):
    cfg = reduced(get_config("llama-3.2-vision-90b"))
    tokens = jnp.zeros((2, 16), jnp.int32)
    _, mod = tf._prepare_inputs(cfg, {"tokens": tokens}, "train")
    assert bool(mod[:, :cfg.n_vision_tokens].all())
    assert not bool(mod[:, cfg.n_vision_tokens:].any())


def test_param_counts_match_declared():
    """init_model parameter count ≈ config.param_count() (embeddings and
    stacked blocks included; small structural deltas like norms allowed)."""
    for arch in ("qwen1.5-0.5b", "olmoe-1b-7b", "gemma-7b"):
        cfg = get_config(arch)
        spec_n = cfg.param_count()
        abstract = tf.abstract_model(cfg)
        real_n = sum(int(np.prod(x.shape))
                     for x in jax.tree.leaves(abstract))
        assert abs(real_n - spec_n) / spec_n < 0.03, (arch, real_n, spec_n)
