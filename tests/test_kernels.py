"""Per-kernel correctness: Pallas (interpret mode) vs the jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ReaLBConfig
from repro.core import ep_moe, quant
from repro.kernels import ops, ref

SHAPES = [(128, 256, 512), (64, 128, 128), (256, 384, 1024), (8, 128, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_kernel_matches_oracle(m, n, k, dtype):
    key = jax.random.PRNGKey(m * 7 + n * 3 + k)
    w = (jax.random.normal(key, (n, k)) * 0.07).astype(dtype)
    packed, scales, gs = ops.quantize_fp4(w, block_n=min(128, n),
                                          block_k=min(512, k))
    pk_r, sc_r = ref.quantize_fp4_ref(w, gs)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pk_r))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(sc_r))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("a4", [False, True])
def test_matmul_kernel_matches_oracle(m, n, k, a4):
    kw, kx = jax.random.split(jax.random.PRNGKey(n + k), 2)
    w = (jax.random.normal(kw, (n, k)) * 0.05).astype(jnp.bfloat16)
    x = jax.random.normal(kx, (m, k)).astype(jnp.bfloat16)
    packed, scales, gs = ops.quantize_fp4(w, block_n=min(128, n),
                                          block_k=min(512, k))
    y = ops.fp4_matmul(x, packed, scales, gs, a4=a4,
                       block_m=min(128, m), block_n=min(128, n),
                       block_k=min(512, k))
    y_ref = ref.fp4_matmul_ref(x, packed, scales, gs, a4=a4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


def test_matmul_kernel_multiblock_reduction():
    """K split across several grid steps must accumulate exactly."""
    m, n, k = 128, 128, 2048
    kw, kx = jax.random.split(jax.random.PRNGKey(0), 2)
    w = (jax.random.normal(kw, (n, k)) * 0.05).astype(jnp.float32)
    x = jax.random.normal(kx, (m, k)).astype(jnp.float32)
    packed, scales, gs = ops.quantize_fp4(w)
    y1 = ops.fp4_matmul(x, packed, scales, gs, block_k=512)
    y2 = ops.fp4_matmul(x, packed, scales, gs, block_k=2048)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-4)


def test_fp4_linear_end_to_end_error():
    """quantize+matmul error vs exact bf16 matmul stays in the NVFP4 range."""
    kx, kw = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(kx, (64, 256), jnp.float32)
    w = jax.random.normal(kw, (256, 128), jnp.float32) * 0.05
    y_q = ops.fp4_linear(x, w, a4=False)
    y = x @ w
    rel = float(jnp.linalg.norm(y_q - y) / jnp.linalg.norm(y))
    assert rel < 0.15, rel


def test_kernel_matches_ep_moe_sim_numerics():
    """The ep_moe jnp fp4 path and the kernel produce the same numbers
    (same QTensor → same dequant → same matmul semantics)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(kx, (32, 128), jnp.float32)
    w = jax.random.normal(kw, (128, 128), jnp.float32) * 0.1   # [K,N]
    q = quant.quantize_fp4(w.swapaxes(0, 1))                   # [N,K]
    y_sim = quant.matmul_w4a16(x, q)
    y_kernel = ops.fp4_matmul(x, q.packed, q.scales, q.global_scale,
                              block_k=128, block_n=128, block_m=32)
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_kernel),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# odd shapes: the wrappers pad to block multiples internally
# --------------------------------------------------------------------------
ODD_SHAPES = [(37, 130, 96), (5, 17, 64), (100, 200, 544), (1, 1, 32)]


@pytest.mark.parametrize("m,n,k", ODD_SHAPES)
def test_quantize_kernel_odd_shapes(m, n, k):
    """Real routed token counts / arbitrary d_ff: no caller-side padding."""
    w = (jax.random.normal(jax.random.PRNGKey(n * k), (n, k)) * 0.07)
    packed, scales, gs = ops.quantize_fp4(w)
    assert packed.shape == (n, k // 2) and scales.shape == (n, k // 16)
    pk_r, sc_r = ref.quantize_fp4_ref(w, gs)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pk_r))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(sc_r))


@pytest.mark.parametrize("m,n,k", ODD_SHAPES)
@pytest.mark.parametrize("a4", [False, True])
def test_matmul_kernel_odd_shapes(m, n, k, a4):
    kw, kx = jax.random.split(jax.random.PRNGKey(m + n + k), 2)
    w = (jax.random.normal(kw, (n, k)) * 0.05).astype(jnp.float32)
    x = jax.random.normal(kx, (m, k)).astype(jnp.float32)
    packed, scales, gs = ops.quantize_fp4(w)
    y = ops.fp4_matmul(x, packed, scales, gs, a4=a4)
    assert y.shape == (m, n)
    y_ref = ref.fp4_matmul_ref(x, packed, scales, gs, a4=a4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------------
# fused grouped FP4 expert FFN vs the _grouped_ffn_fp4 jnp oracle
# --------------------------------------------------------------------------
def _quantized_experts(rng_key, n_groups, d, f, dtype=jnp.float32):
    """QTensors in the exact layout _quantize_experts produces: gate/up
    quantized along D, down along d_ff."""
    keys = jax.random.split(rng_key, 3)
    out = {}
    for key, (name, (rows, cols)) in zip(
            keys, dict(w_gate=(f, d), w_up=(f, d), w_down=(d, f)).items()):
        w = (jax.random.normal(key, (n_groups, rows, cols)) * 0.5)
        out[name] = quant.quantize_fp4(w.astype(dtype))
    return out


def _oracle_grouped_ffn_fp4(xs, gs, wq, rcfg, act):
    """_grouped_ffn_fp4 with the backend pinned to the jnp oracle."""
    prev = ops.ffn_backend()
    ops.set_ffn_backend("jnp")
    try:
        return ep_moe._grouped_ffn_fp4(xs, gs, wq, rcfg, act)
    finally:
        ops.set_ffn_backend(prev if prev != "jnp" else None)


GROUPED_CASES = [
    # (m, d, f, gs) — sum(gs) == m; patterns from the dispatch path:
    # empty groups interleaved + zero-count pad slot (the trailing slot
    # every _moe_dispatch call appends for capacity-dropped rows)
    (24, 64, 64, [3, 0, 5, 0, 0, 9, 7, 0, 0]),
    # all tokens land in one slot (worst-case hotspot)
    (16, 64, 96, [0, 16, 0, 0, 0]),
    # first slot only, trailing slots (incl. pad) empty
    (40, 128, 64, [40, 0, 0]),
    # m not a multiple of block_m (pad-to-block inside the kernel)
    (37, 64, 64, [10, 0, 12, 15]),
    # cap-dropped rows: pad slot (last) holds unfilled capacity rows
    (32, 64, 64, [6, 10, 0, 16]),
    (8, 32, 32, [1, 2, 0, 5]),
]


@pytest.mark.parametrize("m,d,f,gs", GROUPED_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_grouped_ffn_kernel_matches_oracle(m, d, f, gs, dtype):
    gs = jnp.asarray(gs, jnp.int32)
    assert int(gs.sum()) == m
    rcfg = ReaLBConfig()
    wq = _quantized_experts(jax.random.PRNGKey(m + d + f), gs.shape[0],
                            d, f, dtype)
    xs = jax.random.normal(jax.random.PRNGKey(m * 3 + 1), (m, d)).astype(
        dtype)
    y_ref = _oracle_grouped_ffn_fp4(xs, gs, wq, rcfg, jax.nn.silu)
    y = ops.grouped_fp4_ffn(xs, gs, wq, group=rcfg.group_size,
                            act=jax.nn.silu, interpret=True)
    assert y.shape == y_ref.shape and y.dtype == y_ref.dtype
    ya = np.asarray(y, jnp.float32)
    ra = np.asarray(y_ref, jnp.float32)
    if dtype == jnp.bfloat16:
        # kernel and oracle round at different points (the kernel keeps
        # gate/up products in f32 through the activation, the oracle's
        # ragged_dot casts back to bf16 per stage), and the h fake-quant
        # is piecewise-constant — a bf16-eps difference near a level
        # midpoint jumps a whole FP4 level.  Isolated cliff elements are
        # therefore expected; pin the aggregate error instead (measured
        # rel-L2 <= 1.6% across the sweep).
        rel_l2 = (np.linalg.norm(ya - ra)
                  / max(np.linalg.norm(ra), 1e-9))
        assert rel_l2 < 3e-2, rel_l2
        peak = np.abs(ya - ra).max() / max(np.abs(ra).max(), 1e-9)
        assert peak < 0.1, peak
    else:
        np.testing.assert_allclose(ya, ra, rtol=1e-5, atol=1e-4)


def test_grouped_ffn_kernel_block_m_invariance():
    """Token-block size must not change results (same per-row math)."""
    m, d, f = 48, 64, 64
    gs = jnp.asarray([11, 0, 20, 17], jnp.int32)
    wq = _quantized_experts(jax.random.PRNGKey(9), 4, d, f)
    xs = jax.random.normal(jax.random.PRNGKey(10), (m, d))
    ys = [ops.grouped_fp4_ffn(xs, gs, wq, interpret=True)]
    from repro.kernels.grouped_fp4_ffn import grouped_fp4_ffn_kernel
    gsc = jnp.stack([wq[n].global_scale for n in ("w_gate", "w_up",
                                                  "w_down")])
    for bm in (8, 16, 128):
        ys.append(grouped_fp4_ffn_kernel(
            xs, gs, wq["w_gate"].packed, wq["w_gate"].scales,
            wq["w_up"].packed, wq["w_up"].scales,
            wq["w_down"].packed, wq["w_down"].scales, gsc,
            block_m=bm, interpret=True))
    for y in ys[1:]:
        np.testing.assert_allclose(np.asarray(y), np.asarray(ys[0]),
                                   rtol=1e-5, atol=1e-4)


def test_quantize_experts_fp4_bitwise_matches_jnp():
    """The grouped Pallas quantize path == quant.quantize_fp4 exactly
    (same global scale over the stack, same per-group recipe)."""
    w = jax.random.normal(jax.random.PRNGKey(2), (5, 48, 96)) * 0.3
    q_ref = quant.quantize_fp4(w)
    q_k = ops.quantize_experts_fp4(w, interpret=True)
    np.testing.assert_array_equal(np.asarray(q_k.packed),
                                  np.asarray(q_ref.packed))
    np.testing.assert_array_equal(np.asarray(q_k.scales),
                                  np.asarray(q_ref.scales))
    np.testing.assert_array_equal(np.asarray(q_k.global_scale),
                                  np.asarray(q_ref.global_scale))


def test_ffn_backend_switch_roundtrip():
    assert ops.ffn_backend() in ops.FFN_BACKENDS
    prev = ops.ffn_backend()
    try:
        assert ops.set_ffn_backend("interpret") == "interpret"
        assert ops.ffn_fused()
        assert ops.set_ffn_backend("jnp") == "jnp"
        assert not ops.ffn_fused()
        with pytest.raises(ValueError):
            ops.set_ffn_backend("cuda")
    finally:
        ops.set_ffn_backend(prev)
