"""Per-kernel correctness: Pallas (interpret mode) vs the jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops, ref

SHAPES = [(128, 256, 512), (64, 128, 128), (256, 384, 1024), (8, 128, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_kernel_matches_oracle(m, n, k, dtype):
    key = jax.random.PRNGKey(m * 7 + n * 3 + k)
    w = (jax.random.normal(key, (n, k)) * 0.07).astype(dtype)
    packed, scales, gs = ops.quantize_fp4(w, block_n=min(128, n),
                                          block_k=min(512, k))
    pk_r, sc_r = ref.quantize_fp4_ref(w, gs)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pk_r))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(sc_r))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("a4", [False, True])
def test_matmul_kernel_matches_oracle(m, n, k, a4):
    kw, kx = jax.random.split(jax.random.PRNGKey(n + k), 2)
    w = (jax.random.normal(kw, (n, k)) * 0.05).astype(jnp.bfloat16)
    x = jax.random.normal(kx, (m, k)).astype(jnp.bfloat16)
    packed, scales, gs = ops.quantize_fp4(w, block_n=min(128, n),
                                          block_k=min(512, k))
    y = ops.fp4_matmul(x, packed, scales, gs, a4=a4,
                       block_m=min(128, m), block_n=min(128, n),
                       block_k=min(512, k))
    y_ref = ref.fp4_matmul_ref(x, packed, scales, gs, a4=a4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


def test_matmul_kernel_multiblock_reduction():
    """K split across several grid steps must accumulate exactly."""
    m, n, k = 128, 128, 2048
    kw, kx = jax.random.split(jax.random.PRNGKey(0), 2)
    w = (jax.random.normal(kw, (n, k)) * 0.05).astype(jnp.float32)
    x = jax.random.normal(kx, (m, k)).astype(jnp.float32)
    packed, scales, gs = ops.quantize_fp4(w)
    y1 = ops.fp4_matmul(x, packed, scales, gs, block_k=512)
    y2 = ops.fp4_matmul(x, packed, scales, gs, block_k=2048)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-4)


def test_fp4_linear_end_to_end_error():
    """quantize+matmul error vs exact bf16 matmul stays in the NVFP4 range."""
    kx, kw = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(kx, (64, 256), jnp.float32)
    w = jax.random.normal(kw, (256, 128), jnp.float32) * 0.05
    y_q = ops.fp4_linear(x, w, a4=False)
    y = x @ w
    rel = float(jnp.linalg.norm(y_q - y) / jnp.linalg.norm(y))
    assert rel < 0.15, rel


def test_kernel_matches_ep_moe_sim_numerics():
    """The ep_moe jnp fp4 path and the kernel produce the same numbers
    (same QTensor → same dequant → same matmul semantics)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(kx, (32, 128), jnp.float32)
    w = jax.random.normal(kw, (128, 128), jnp.float32) * 0.1   # [K,N]
    q = quant.quantize_fp4(w.swapaxes(0, 1))                   # [N,K]
    y_sim = quant.matmul_w4a16(x, q)
    y_kernel = ops.fp4_matmul(x, q.packed, q.scales, q.global_scale,
                              block_k=128, block_n=128, block_m=32)
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_kernel),
                               rtol=1e-5, atol=1e-5)
