"""Runtime sentinel: host-sync guard, recompile accounting, and the
engine e2e invariants — zero post-warmup recompiles across replan /
kill-rejoin / async drain, and a sync-free hot loop with tracer and
profiler enabled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sentinel import NULL_SENTINEL, Sentinel
from repro.configs import (ReaLBConfig, ReplicationConfig, get_config,
                           reduced)


# --------------------------------------------------------------------------
# host-sync guard
# --------------------------------------------------------------------------
def test_hot_window_catches_scalar_coercion():
    with Sentinel() as s:
        x = jnp.ones(())
        with s.hot("iter"):
            float(x)                       # unsanctioned device->host pull
    assert len(s.violations) == 1
    v = s.violations[0]
    assert v.kind == "host_sync" and v.context == "iter"
    assert "test_sentinel" in v.where
    assert not s.ok


def test_sanctioned_window_allows_pulls():
    with Sentinel() as s:
        x = jnp.ones(())
        with s.hot("iter"):
            with s.sanctioned("telemetry"):
                float(x)
                int(jnp.ones((), jnp.int32))
    assert s.violations == []
    assert s.sanctioned_pulls == {"telemetry": 1}
    assert s.ok


def test_outside_hot_window_unguarded():
    with Sentinel() as s:
        float(jnp.ones(()))                # between iterations: fine
    assert s.violations == []


def test_strict_raises_with_site():
    with Sentinel(strict=True) as s:
        with pytest.raises(RuntimeError, match="unsanctioned"):
            with s.hot("decode"):
                bool(jnp.ones((), bool))
    assert len(s.violations) == 1


def test_guard_uninstalls_on_exit():
    s = Sentinel()
    with s:
        pass
    # after disarm the property is the original: no guard, no recording
    with jax.transfer_guard_device_to_host("allow"):
        float(jnp.ones(()))
    assert s.violations == []


def test_device_compute_unaffected_inside_hot():
    with Sentinel() as s:
        x = jnp.arange(8.0)
        with s.hot("iter"):
            y = jnp.sum(x * 2)             # stays on device: no pull
    assert s.violations == []
    assert float(y) == 56.0


# --------------------------------------------------------------------------
# recompile accounting
# --------------------------------------------------------------------------
def test_recompile_counter_flags_new_shapes():
    s = Sentinel()
    f = jax.jit(lambda x: x + 1)
    s.register_entry("f", f)
    f(jnp.ones(4))
    warm = s.mark_warm()
    assert warm == {"f": 1}
    f(jnp.ones(4))                         # cache hit
    assert s.post_warm_recompiles() == {}
    assert s.ok
    f(jnp.ones(8))                         # new shape -> recompile
    assert s.post_warm_recompiles() == {"f": 1}
    assert not s.ok


def test_register_entry_cumulative_across_generations():
    s = Sentinel()
    f1 = jax.jit(lambda x: x + 1)
    s.register_entry("f", f1)
    f1(jnp.ones(4))
    f2 = jax.jit(lambda x: x + 2)          # an engine rebuild
    s.register_entry("f", f2)
    s.note_rebuild("capacity resize")
    f2(jnp.ones(4))
    assert s.compile_counts() == {"f": 2}
    assert s.rebuilds == ["capacity resize"]


def test_null_sentinel_is_free_and_reentrant():
    assert not NULL_SENTINEL.enabled
    with NULL_SENTINEL.hot("iter"):
        with NULL_SENTINEL.hot("iter"):
            with NULL_SENTINEL.sanctioned("x"):
                float(jnp.ones(()))
    NULL_SENTINEL.note_rebuild("r")
    assert NULL_SENTINEL.ok
    assert NULL_SENTINEL.report()["ok"] is True


def test_report_shape():
    with Sentinel() as s:
        with s.hot("iter"):
            float(jnp.ones(()))
    rep = s.report()
    assert set(rep) == {"ok", "violations", "sanctioned_pulls",
                        "compile_counts", "warm_counts",
                        "post_warm_recompiles", "rebuilds"}
    assert rep["ok"] is False and len(rep["violations"]) == 1


# --------------------------------------------------------------------------
# engine end-to-end (slow): the serving invariants themselves
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    import repro.models.transformer as tf
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


@pytest.mark.slow
def test_engine_hot_loop_sync_free_with_obs_enabled(model):
    """Transfer-guard invariant: with tracer AND profiler enabled, every
    device->host pull inside the iteration happens in a sanctioned
    window (sampling, telemetry) — zero stray syncs."""
    from repro.obs import Tracer
    from repro.obs.ledger import FlopByteLedger
    from repro.obs.profiler import Profiler
    from repro.serving.engine import Engine

    cfg, params = model
    sent = Sentinel()
    with sent:
        eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                     max_len=32, virtual_ep=4,
                     tracer=Tracer(clock=lambda: 0.0),
                     profiler=Profiler(FlopByteLedger(cfg, ep=4)),
                     sentinel=sent)
        for r in _reqs(cfg):
            eng.submit(r)
        done = eng.run()
    assert len(done) == 6
    assert sent.violations == [], [v.where for v in sent.violations]
    # the guard was genuinely live: the engine pulled through sanctioned
    # windows every iteration
    assert sent.sanctioned_pulls.get("telemetry", 0) > 0
    assert sent.sanctioned_pulls.get("sample", 0) > 0


@pytest.mark.slow
def test_engine_zero_recompiles_across_replan_kill_rejoin(model, tmp_path):
    """Warmup pass covers replans, table commits, a kill/rejoin cycle,
    async drains and every chunked-prefill bucket; an identical second
    pass must hit the jit caches exactly — zero new compilations."""
    from repro.replication import ReplicaManager, expand_moe_params
    from repro.runtime.fault_tolerance import FaultInjector
    from repro.serving.elastic import ElasticCoordinator
    from repro.serving.engine import Engine

    cfg, params = model
    mgr = ReplicaManager(cfg, ReplicationConfig(
        replan_every=4, warmup_iters=2, min_gain=0.0, per_layer=True,
        spare_per_rank=1, max_replicas=2), 4)
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path))
    fi = FaultInjector([(3, "fail", 2), (14, "rejoin", 2)])
    sent = Sentinel()
    with sent:
        eng = Engine(cfg, expand_moe_params(params, mgr.rsets),
                     ReaLBConfig(gate_gamma=4), max_slots=3, max_len=32,
                     prefill_budget=8,          # chunked prefill buckets
                     placement=mgr, migrate_async=True,
                     migrate_bytes_per_iter=1, elastic=co,
                     fault_injector=fi, sentinel=sent)
        for r in _reqs(cfg, n=8, new=6):
            eng.submit(r)
        eng.save_checkpoint(str(tmp_path), 0)
        eng.run()
        eng.drain_migrations()
        assert fi.exhausted
        warm = sent.mark_warm()
        assert sum(warm.values()) > 0
        # pass 2: identical stream on the warmed engine (replans and
        # table commits continue; shapes must all be cached)
        for r in _reqs(cfg, n=8, new=6):
            eng.submit(r)
        eng.run()
        eng.drain_migrations()
    assert sent.post_warm_recompiles() == {}, sent.compile_counts()
    assert sent.violations == [], [v.where for v in sent.violations]
    assert sent.ok
