"""Chunked-prefill engine v2: budget-invariance, continuation correctness,
prefill telemetry, decode-modality threading."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ReaLBConfig, get_config, reduced
from repro.models import transformer as tf
from repro.serving.engine import Engine
from repro.serving.scheduler import Request
from repro.serving.telemetry import Telemetry

pytestmark = pytest.mark.slow    # engine jit compiles across chunk buckets

RCFG = ReaLBConfig(gate_gamma=10 ** 9)   # gate closed: pure numerics


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _req(rng, cfg, uid, p_len=10, new=4, vis_frac=0.5):
    toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
    return Request(uid=uid, tokens=toks,
                   modality=rng.random(p_len) < vis_frac,
                   max_new_tokens=new)


def _serve(cfg, params, reqs, budget, **kw):
    eng = Engine(cfg, params, RCFG, max_slots=4, max_len=48,
                 prefill_budget=budget, **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    return {r.uid: r.generated for r in done}, eng


def test_chunked_equivalence_across_budgets(model):
    """Identical sampled tokens for every request regardless of how the
    prefill is chunked (acceptance criterion) — including the legacy
    one-shot path (budget=0)."""
    cfg, params = model
    rng = np.random.default_rng(11)
    protos = [_req(rng, cfg, i, p_len=int(p), new=4)
              for i, p in enumerate([23, 9, 17, 31, 5, 12])]

    def clone(r):
        return Request(uid=r.uid, tokens=r.tokens.copy(),
                       modality=r.modality.copy(),
                       max_new_tokens=r.max_new_tokens)

    results = {}
    for budget in (0, 4, 7, 16, 1024):
        results[budget], eng = _serve(cfg, params,
                                      [clone(r) for r in protos], budget)
        assert eng.chunked == (budget > 0)
        assert set(results[budget]) == {r.uid for r in protos}
    for budget in (4, 7, 16, 1024):
        assert results[budget] == results[0], budget


def test_chunk_continuation_with_concurrent_decode(model):
    """A long prompt prefilling over several iterations while another slot
    decodes: neither corrupts the other (the decode scatter for
    mid-prefill slots must be dropped, not land at position 0)."""
    cfg, params = model
    rng = np.random.default_rng(5)
    a = _req(rng, cfg, 0, p_len=6, new=12)
    b = _req(rng, cfg, 1, p_len=30, new=4)

    # reference: each alone, one-shot
    ref_a, _ = _serve(cfg, params, [Request(0, a.tokens.copy(),
                                            a.modality.copy(),
                                            max_new_tokens=12)], 0)
    ref_b, _ = _serve(cfg, params, [Request(1, b.tokens.copy(),
                                            b.modality.copy(),
                                            max_new_tokens=4)], 0)

    # together with a tiny budget: A decodes while B prefills chunk-by-chunk
    eng = Engine(cfg, params, RCFG, max_slots=4, max_len=48,
                 prefill_budget=8)
    eng.submit(a)
    eng.step()             # A prefills (6 <= 8), first token + one decode
    assert len(a.generated) == 2
    eng.submit(b)
    eng.step()                       # B chunk 1/4 while A decodes
    assert b.prefill_pos == 8 and not b.generated
    assert len(a.generated) == 3     # A kept decoding
    done = eng.run()
    out = {r.uid: r.generated for r in done}
    assert out[0] == ref_a[0]
    assert out[1] == ref_b[1]


def test_prefill_iterations_recorded(model):
    """v1 dropped prefill iterations from the stats; v2 must record them
    with real token counts and phase tags."""
    cfg, params = model
    rng = np.random.default_rng(3)
    reqs = [_req(rng, cfg, i, p_len=12, new=2) for i in range(3)]
    tele = Telemetry()
    _, eng = _serve(cfg, params, reqs, 16, telemetry=tele)
    pre = [s for s in eng.stats if s.phase == "prefill"]
    dec = [s for s in eng.stats if s.phase == "decode"]
    assert pre and dec
    assert sum(s.tokens for s in pre) == 3 * 12   # every prompt token once
    assert all(s.batch_tokens >= s.tokens for s in pre)
    assert tele.n_iters == len(eng.stats)
    # TTFT/TPOT recorded for every finished request
    assert tele.n_requests == 3
    assert tele.ttft_summary()["p50"] >= 0.0


def test_gate_opens_under_batched_prefill(model):
    """With a small Γ the batched prefill crosses the gate while decode
    stays below it — the regime split the engine v1 never produced."""
    cfg, params = model
    rng = np.random.default_rng(4)
    reqs = [_req(rng, cfg, i, p_len=24, new=2) for i in range(4)]
    eng = Engine(cfg, params, ReaLBConfig(gate_gamma=64), max_slots=4,
                 max_len=48, prefill_budget=96)
    for r in reqs:
        eng.submit(r)
    eng.run()
    pre = [s for s in eng.stats if s.phase == "prefill"]
    dec = [s for s in eng.stats if s.phase == "decode"]
    assert any(s.gate_open > 0 for s in pre)
    # decode batches are 4 tokens * top_k=2 << 64: gate shut
    assert all(s.gate_open == 0 for s in dec)


def test_decode_modality_threaded(model):
    """Requests generating vision tokens (decode_modality=True) must show
    up in the decode batches' vis_d — v1 hardcoded modality to zeros."""
    cfg, params = model
    rng = np.random.default_rng(6)

    def run(decode_modality):
        req = Request(uid=0,
                      tokens=rng.integers(0, cfg.vocab_size, 8)
                      .astype(np.int32),
                      modality=np.zeros(8, bool), max_new_tokens=6,
                      decode_modality=decode_modality)
        # 2 slots but 1 request: the dummy slot must not dilute vis_frac
        # (dummy rows are excluded from routing stats via the valid mask)
        eng = Engine(cfg, params, RCFG, max_slots=2, max_len=32,
                     prefill_budget=32)
        eng.submit(req)
        eng.run()
        return [s.vis_frac for s in eng.stats if s.phase == "decode"]

    assert all(v == 0.0 for v in run(False))
    assert all(v > 0.9 for v in run(True))


def test_mixed_modal_decode_vis_frac(model):
    """Half the decoding slots vision, half text: vis_frac ~ the slot mix."""
    cfg, params = model
    rng = np.random.default_rng(8)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, 6)
                    .astype(np.int32),
                    modality=np.zeros(6, bool), max_new_tokens=5,
                    decode_modality=(i % 2 == 0)) for i in range(4)]
    eng = Engine(cfg, params, RCFG, max_slots=4, max_len=32,
                 prefill_budget=64)
    for r in reqs:
        eng.submit(r)
    eng.run()
    full = [s for s in eng.stats if s.phase == "decode" and s.n_active == 4]
    assert full
    for s in full:
        assert 0.3 < s.vis_frac < 0.7


def test_zero_max_new_retires_mid_prefill(model):
    """A max_new_tokens=0 request retires before its prefill completes; the
    stale fifo slot must not crash planning or block later requests."""
    cfg, params = model
    rng = np.random.default_rng(12)
    zero = _req(rng, cfg, 0, p_len=20, new=4)
    zero.max_new_tokens = 0                  # done immediately
    live = _req(rng, cfg, 1, p_len=9, new=3)
    eng = Engine(cfg, params, RCFG, max_slots=2, max_len=48,
                 prefill_budget=8)           # 20 > 8: multi-chunk prefill
    eng.submit(zero)
    eng.submit(live)
    done = eng.run()
    out = {r.uid: r.generated for r in done}
    assert out[0] == []
    assert len(out[1]) == 3


def test_fallback_archs_use_oneshot_path():
    """MLA / SSM / enc-dec stacks can't continue caches mid-prompt: the
    engine must auto-fall back to the v1 one-shot prefill and still serve."""
    cfg = reduced(get_config("minicpm3-4b"), n_layers=2)   # MLA attention
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    eng = Engine(cfg, params, RCFG, max_slots=2, max_len=32,
                 prefill_budget=64)
    assert not eng.chunked
    for i in range(3):
        toks = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
        eng.submit(Request(uid=i, tokens=toks, modality=np.zeros(7, bool),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.generated) == 3 for r in done)
    assert any(s.phase == "prefill" for s in eng.stats)
