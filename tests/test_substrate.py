"""Substrate tests: optimizer, checkpointing, data pipeline, grad utils."""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import TrainConfig
from repro.data.pipeline import DataConfig, DataLoader, lm_batch, \
    multimodal_batch
from repro.optim import adamw
from repro.optim.grad_utils import accumulate_grads, init_error_feedback


# -- AdamW ------------------------------------------------------------------
def _numpy_adamw(p, g, m, v, step, cfg: TrainConfig):
    lr = float(adamw.lr_schedule(jnp.asarray(step), cfg))
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m2 / (1 - cfg.b1 ** step)
    vh = v2 / (1 - cfg.b2 ** step)
    delta = mh / (np.sqrt(vh) + cfg.eps)
    if p.ndim >= 2:
        delta = delta + cfg.weight_decay * p
    return p - lr * delta, m2, v2


def test_adamw_matches_numpy_reference():
    cfg = TrainConfig(lr=1e-2, grad_clip=1e9, warmup_steps=1, total_steps=10)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(0, 1, (4, 4)), jnp.float32),
         "b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.float32)}
    state = adamw.init_opt_state(p, cfg)
    np_p = {k: np.asarray(v) for k, v in p.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    for step in range(1, 4):
        g = {k: np.asarray(rng.normal(0, 0.1, v.shape), np.float32)
             for k, v in np_p.items()}
        p, state, _ = adamw.adamw_update(p, {k: jnp.asarray(v)
                                             for k, v in g.items()},
                                         state, cfg)
        for k in np_p:
            np_p[k], np_m[k], np_v[k] = _numpy_adamw(
                np_p[k], g[k], np_m[k], np_v[k], step, cfg)
    for k in np_p:
        np.testing.assert_allclose(np.asarray(p[k]), np_p[k], rtol=1e-5,
                                   atol=1e-6)


def test_grad_clip():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0 * np.sqrt(10)) < 1e-3
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_lr_schedule_shape():
    cfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(jnp.asarray(s), cfg))
           for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) <= 1.0
    assert lrs[-1] < lrs[4]          # decayed below warmup peak


# -- checkpointing ------------------------------------------------------------
def test_ckpt_roundtrip_and_gc():
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, {"state": tree}, keep=2)
        kept = sorted(p.name for p in pathlib.Path(d).iterdir())
        assert kept == ["step_00000003", "step_00000004"]
        assert ckpt.latest_step(d) == 4
        step, out = ckpt.restore(d, {"state": tree})
        assert step == 4
        np.testing.assert_array_equal(np.asarray(out["state"]["a"]),
                                      np.asarray(tree["a"]))
        assert out["state"]["nest"]["b"].dtype == jnp.bfloat16


def test_async_checkpointer():
    tree = {"a": jnp.arange(10)}
    with tempfile.TemporaryDirectory() as d:
        ac = ckpt.AsyncCheckpointer(d, keep=2)
        ac.save(5, {"state": tree})
        ac.wait()
        assert ckpt.latest_step(d) == 5


# -- data pipeline -------------------------------------------------------------
def test_lm_batch_deterministic_and_learnable():
    dc = DataConfig(vocab_size=128, seq_len=32, global_batch=8)
    b1, b2 = lm_batch(dc, 5), lm_batch(dc, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()


def test_multimodal_batch_properties():
    dc = DataConfig(vocab_size=128, seq_len=64, global_batch=32,
                    vision_frac_mean=0.6)
    b = multimodal_batch(dc, 0, d_model=16)
    mod = b["modality"]
    assert 0.3 < mod.mean() < 0.9
    # vision tokens in the top vocab half; labels masked at vision positions
    assert (b["tokens"][mod] >= 64).all()
    assert (b["labels"][mod] == -1).all()
    assert b["vision_embeds"].shape[0] == 32


def test_loader_resume():
    dc = DataConfig(vocab_size=64, seq_len=16, global_batch=4)
    a = DataLoader(dc)
    for _ in range(3):
        next(a)
    b = DataLoader(dc, start_step=3)
    np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])


# -- grad utils ---------------------------------------------------------------
def test_accumulate_grads_matches_full_batch():
    def loss_fn(p, batch):
        return ((p["w"] * batch["x"]) ** 2).mean(), {}

    p = {"w": jnp.asarray(2.0)}
    xs = jnp.arange(8.0)
    full, gfull = jax.value_and_grad(
        lambda p: ((p["w"] * xs) ** 2).mean())(p)
    micro = {"x": xs.reshape(4, 2)}
    loss, g, _ = accumulate_grads(loss_fn, p, micro, 4)
    np.testing.assert_allclose(float(loss), float(full), rtol=1e-6)
    np.testing.assert_allclose(float(g["w"]), float(gfull["w"]), rtol=1e-6)


def test_error_feedback_zero_init():
    ef = init_error_feedback({"w": jnp.ones((3, 3))})
    assert float(jnp.abs(ef["w"]).sum()) == 0.0


def test_compressed_all_reduce_contract():
    """Host-level shard_map wrapper: on a 1-rank mesh the reduction is the
    int8 quantize/dequantize of the input, and reduced + residual
    reconstructs the gradient exactly (error-feedback invariant)."""
    from jax.sharding import Mesh
    from repro.optim.grad_utils import compressed_all_reduce

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(0, 0.1, (1, 4, 8)), jnp.float32),
             "b": jnp.asarray(rng.normal(0, 1.0, (1, 8)), jnp.float32)}
    err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    red, new_err = compressed_all_reduce(grads, err, mesh, "data")
    for k in grads:
        assert red[k].shape == grads[k].shape
        # g_hat + residual == g (bitwise, per the error-feedback algebra)
        np.testing.assert_allclose(np.asarray(red[k] + new_err[k]),
                                   np.asarray(grads[k]), atol=1e-7)
        # int8 quantization error bounded by scale = amax/127
        amax = float(jnp.abs(grads[k]).max())
        assert float(jnp.abs(red[k] - grads[k]).max()) <= amax / 127.0 + 1e-9
