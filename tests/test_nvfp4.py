"""Single-source NVFP4 numerics: parity pins so the Pallas kernels and the
jnp oracle cannot drift (they all import repro.kernels.nvfp4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import fp4_matmul, nvfp4, quantize_fp4


def _sweep_values():
    """Every code point, every midpoint, boundary cases, random fill."""
    grid = np.asarray(quant.FP4_LEVELS)
    mids = np.asarray(quant.FP4_MIDPOINTS)
    eps = np.float32(1e-3)
    pts = np.concatenate([grid, -grid, mids, -mids, mids - eps, mids + eps,
                          [0.0, -0.0, 7.5, -7.5, 1e-9, -1e-9],
                          np.random.RandomState(0).randn(512) * 3])
    pad = (-len(pts)) % 16
    pts = np.concatenate([pts, pts[:pad]])
    return pts.astype(np.float32).reshape(-1, 16)


def test_modules_share_one_implementation():
    """The anti-drift pin: kernels alias nvfp4, they don't re-implement."""
    assert quantize_fp4._fp4_code is nvfp4.fp4_code
    assert quantize_fp4._e4m3_round is nvfp4.e4m3_round
    assert fp4_matmul._decode_level is nvfp4.decode_level
    assert fp4_matmul._fake_quant_a4 is nvfp4.fake_quant_a4
    assert quant.fp4_round is nvfp4.fp4_round
    assert quant.fp4_code is nvfp4.fp4_code
    assert quant.fp4_decode is nvfp4.decode_level
    assert quant.e4m3_round is nvfp4.e4m3_round


def test_compare_select_matches_level_table():
    """fp4_round / fp4_level vs an explicit FP4_LEVELS gather, bitwise."""
    x = jnp.asarray(_sweep_values())
    idx = nvfp4.fp4_index(jnp.abs(x))
    gathered = jnp.sign(x) * quant.FP4_LEVELS[idx]
    np.testing.assert_array_equal(np.asarray(nvfp4.fp4_round(x)),
                                  np.asarray(gathered))
    np.testing.assert_array_equal(np.asarray(nvfp4.fp4_level(idx)),
                                  np.asarray(quant.FP4_LEVELS[idx]))


def test_code_decode_roundtrip_all_16_codes():
    codes = jnp.arange(16, dtype=jnp.uint8)
    vals = nvfp4.decode_level(codes)
    table = np.asarray(quant.FP4_LEVELS)
    signs = np.where(np.arange(16) >= 8, -1.0, 1.0)
    np.testing.assert_array_equal(np.asarray(vals),
                                  (signs * table[np.arange(16) % 8]
                                   ).astype(np.float32))
    # re-encode returns the same code (modulo ±0 which shares a value)
    re = nvfp4.fp4_code(vals)
    np.testing.assert_array_equal(np.asarray(re)[1:8],
                                  np.asarray(codes)[1:8])
    np.testing.assert_array_equal(np.asarray(re)[9:], np.asarray(codes)[9:])


def test_fake_quant_a4_matches_ref_recipe():
    """fake_quant_a4 == the ref.py a4 recipe: dynamic per-group amax/6
    scale in exact f32, fp4_round on the scaled values."""
    x = jnp.asarray(_sweep_values())
    m, k = x.shape
    got = nvfp4.fake_quant_a4(x, 16)
    xg = x.reshape(m, k // 16, 16)
    gs = jnp.maximum(jnp.max(jnp.abs(xg), -1, keepdims=True) / 6.0, 1e-20)
    want = (quant.fp4_round(xg / gs) * gs).reshape(m, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fake_quant_a4_leading_dims():
    """Arbitrary leading shape (the decode path fake-quants [E,t,F])."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 32))
    y = nvfp4.fake_quant_a4(x, 16)
    y2 = nvfp4.fake_quant_a4(x.reshape(15, 32), 16).reshape(3, 5, 32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_e4m3_round_idempotent_on_sweep():
    x = jnp.asarray(_sweep_values()).reshape(-1) * 100.0
    y = nvfp4.e4m3_round(x)
    np.testing.assert_array_equal(np.asarray(nvfp4.e4m3_round(y)),
                                  np.asarray(y))
