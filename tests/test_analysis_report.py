"""The invariant report CLI: clean repo exits 0 with a well-formed
artifact; a tampered hot loop (injected host sync, extra collective)
exits non-zero.  Subprocess-driven: the census section needs its own
8-device XLA topology."""
import json
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow    # each run compiles the serving loop

REPORT = pathlib.Path(__file__).parents[1] / "benchmarks" \
    / "analysis_report.py"


def _run(*extra, timeout=900):
    return subprocess.run([sys.executable, str(REPORT), *extra],
                          capture_output=True, text=True, timeout=timeout)


def test_clean_report_exits_zero(tmp_path):
    out = tmp_path / "invariant_report.json"
    r = _run("--out", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    rep = json.loads(out.read_text())
    assert rep["schema"] == "repro.analysis.v1"
    assert rep["ok"] is True and rep["tamper"] is None
    assert set(rep["sections"]) == {"lint", "audit", "census", "sentinel"}
    assert all(s["ok"] for s in rep["sections"].values())
    # section-specific invariants the artifact must carry
    assert rep["sections"]["lint"]["n_findings"] == 0
    aud = rep["sections"]["audit"]["backends"]
    assert set(aud) == {"jnp", "interpret"}
    assert all(b["census"] == {} for b in aud.values())
    sent = rep["sections"]["sentinel"]
    assert sent["post_warm_recompiles"] == {}
    assert sent["violations"] == []
    assert sum(sent["warm_counts"].values()) > 0
    assert rep["sections"]["census"]["checks"]["jaxpr_eq_ledger"]


def test_tamper_sync_flips_exit(tmp_path):
    out = tmp_path / "rep.json"
    r = _run("--only", "sentinel", "--tamper", "sync", "--out", str(out))
    assert r.returncode != 0, r.stdout + r.stderr
    rep = json.loads(out.read_text())
    assert rep["ok"] is False
    sent = rep["sections"]["sentinel"]
    # the injected float() was caught inside the hot window, attributed
    # to the tamper site
    assert sent["violations"], sent
    assert any("analysis_report" in v["where"] for v in sent["violations"])


def test_tamper_psum_flips_exit():
    r = _run("--only", "census", "--tamper", "psum")
    assert r.returncode != 0, r.stdout + r.stderr
    assert "census: VIOLATION" in r.stdout


def test_unknown_section_rejected():
    r = _run("--only", "nosuch", timeout=120)
    assert r.returncode != 0
    assert "unknown section" in r.stderr
