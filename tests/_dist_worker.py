"""Subprocess worker for multi-device tests (8 fake CPU devices).

Run as: python tests/_dist_worker.py <check>
Exits 0 on success; prints diagnostics on failure.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

# same discipline as tests/conftest.py (subprocesses skip conftest)
jax.config.update("jax_numpy_rank_promotion", "raise")

from repro.configs import ReaLBConfig, get_config, reduced  # noqa: E402
from repro.core import ep_moe  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.models.common import use_mesh  # noqa: E402


def _moe_setup():
    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.2,
         "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
         "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
         "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)}
    x = jax.random.normal(ks[4], (4, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (4, 16))
    return cfg, p, x, mod


def check_ep_dispatch_matches_local():
    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    y_ref, _, _ = ep_moe.ep_moe_forward(p, x, cfg, rcfg,
                                        jnp.full((1, 1), 0.9), mod,
                                        mode="dispatch")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        y, _, aux = jax.jit(
            lambda p, x, m, mod: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="dispatch"))(p, x, m, mod)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err < 5e-5, err
    assert float(aux["drop_frac"]) == 0.0


def check_ep_broadcast_matches_local():
    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    xd, md = x[:, :1], mod[:, :1]
    y_ref, _, _ = ep_moe.ep_moe_forward(p, xd, cfg, rcfg,
                                        jnp.full((1, 1), 0.9), md,
                                        mode="broadcast")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        y, _, _ = jax.jit(
            lambda p, x, m, mod: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="broadcast"))(p, xd, m, md)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err < 5e-5, err


def check_realb_fp4_rank_activates():
    """Skew routing so one EP rank is hot + vision heavy; with M=0 the
    policy must compress it and the output must differ from bf16 by a
    small quantization-sized delta."""
    cfg, p, x, mod = _moe_setup()
    # bias router toward experts 0..1 (rank 0 when ep=4)
    p = dict(p)
    p["router"] = p["router"].at[:, 0].add(3.0).at[:, 1].add(2.5)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    vis = jnp.ones_like(mod)
    with use_mesh(mesh):
        m_on = jnp.zeros(ep_moe.moe_state_shape(mesh, 4))
        rc_on = ReaLBConfig(gate_gamma=1)
        y_on, _, aux_on = jax.jit(lambda p, x, m, mod: ep_moe.ep_moe_forward(
            p, x, cfg, rc_on, m, mod, mode="dispatch"))(p, x, m_on, vis)
        rc_off = ReaLBConfig(enabled=False)
        m_off = jnp.zeros(ep_moe.moe_state_shape(mesh, 4))
        y_off, _, _ = jax.jit(lambda p, x, m, mod: ep_moe.ep_moe_forward(
            p, x, cfg, rc_off, m, mod, mode="dispatch"))(p, x, m_off, vis)
    assert float(aux_on["fp4_ranks"]) >= 1.0, float(aux_on["fp4_ranks"])
    diff = float(jnp.max(jnp.abs(y_on - y_off)))
    rel = diff / float(jnp.max(jnp.abs(y_off)))
    assert 1e-6 < rel < 0.5, rel   # changed, but quantization-sized


def check_chunk_padding_isolated_under_ep():
    """Chunk-bucket padding on an EP>1 mesh: adversarial padding (zero
    embeddings, so every padding token routes to the same top-k experts)
    must neither crowd real tokens out of the per-rank capacity nor move
    the routing stats."""
    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    x_pad = x.at[:, 8:].set(0.0)                 # second half = padding
    valid = jnp.zeros((4, 16), bool).at[:, :8].set(True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        y, _, aux = jax.jit(
            lambda p, x, m, mod, v: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="dispatch", valid=v))(
            p, x_pad, m, mod, valid)
    y_ref, _, _ = ep_moe.ep_moe_forward(
        p, x_pad[:, :8], cfg, rcfg, jnp.full((1, 1), 0.9), mod[:, :8],
        mode="dispatch")
    err = float(jnp.max(jnp.abs(y[:, :8] - y_ref)))
    assert err < 5e-5, err
    assert float(aux["drop_frac"]) == 0.0, float(aux["drop_frac"])
    total = float(jnp.sum(jnp.asarray(aux["load_d"])))
    assert total == 4 * 8 * cfg.moe.top_k, total   # valid tokens only


def check_placement_identity_bitwise_under_ep():
    """Under a real EP mesh, the explicit identity table is bitwise-equal
    to the default (placement=None) path — dispatch and broadcast."""
    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ident = ep_moe.identity_placement(cfg.moe.num_experts, 4)
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        for mode, xx, mm in (("dispatch", x, mod),
                             ("broadcast", x[:, :1], mod[:, :1])):
            y0, m0, _ = jax.jit(lambda p, x, m, mod: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode=mode))(p, xx, m, mm)
            y1, m1, _ = jax.jit(
                lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                    p, x, cfg, rcfg, m, mod, mode=mode, placement=pl))(
                p, xx, m, mm, ident)
            assert np.array_equal(np.asarray(y0), np.asarray(y1)), mode
            assert np.array_equal(np.asarray(m0), np.asarray(m1)), mode


def check_placement_permuted_matches_local_under_ep():
    """A permutation table with correspondingly permuted weight slabs on a
    (2,4) mesh matches the identity result, with permuted per-rank stats."""
    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    e = cfg.moe.num_experts
    ep = 4
    rng = np.random.default_rng(5)
    owner = rng.permutation(e)                  # physical row -> logical
    pos = np.empty(e, np.int64)
    pos[owner] = np.arange(e)
    e_loc = e // ep
    place = (jnp.asarray(pos // e_loc, jnp.int32),
             jnp.asarray(pos % e_loc, jnp.int32))
    p_perm = dict(p, w_gate=p["w_gate"][owner], w_up=p["w_up"][owner],
                  w_down=p["w_down"][owner])
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        for mode, xx, mm in (("dispatch", x, mod),
                             ("broadcast", x[:, :1], mod[:, :1])):
            y0, _, aux0 = jax.jit(
                lambda p, x, m, mod: ep_moe.ep_moe_forward(
                    p, x, cfg, rcfg, m, mod, mode=mode))(p, xx, m, mm)
            y1, _, aux1 = jax.jit(
                lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                    p, x, cfg, rcfg, m, mod, mode=mode, placement=pl))(
                p_perm, xx, m, mm, place)
            err = float(jnp.max(jnp.abs(y1 - y0)))
            assert err < 5e-5, (mode, err)
            # global logical per-expert loads, re-aggregated by the
            # permuted table, must equal the placed per-rank loads summed
            # over EP groups
            el = np.asarray(aux0["expert_load"])
            want = np.zeros(ep)
            np.add.at(want, np.asarray(pos // e_loc), el)
            got = np.asarray(aux1["load_d"]).reshape(-1, ep).sum(0)
            np.testing.assert_allclose(got, want, rtol=1e-6,
                                       err_msg=mode)


def check_virtual_ep_policy_parity():
    """ROADMAP satellite: the single-device *virtual* EP topology must
    produce the same policy statistics as the real EP mesh on the same
    token stream — the virtual-ep serving experiments are only meaningful
    if IB_d / LB gate / FP4 duty / AIMD updates agree with the hardware
    topology they emulate.

    Batch 3 is indivisible by the data axis, so the mesh run keeps one
    replicated policy group ([1, 4] M-state) — exactly the virtual
    topology's shape — and every scalar must match, not just the counts.
    """
    cfg, p, _, _ = _moe_setup()
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    x = jax.random.normal(ks[0], (3, 16, cfg.d_model)) * 0.5
    mod = jax.random.bernoulli(ks[1], 0.6, (3, 16))
    rcfg = ReaLBConfig(gate_gamma=8)      # open the gate: policy active
    m_virt = jnp.zeros((1, 4))            # virtual 4-rank topology, M=0
    y_v, m_v, aux_v = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m_virt, mod,
                                            mode="dispatch")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        shape = ep_moe.moe_state_shape(mesh, 3)
        assert shape == (1, 4), shape     # batch 3 -> replicated group
        m = jnp.zeros(shape)
        y_d, m_d, aux_d = jax.jit(
            lambda p, x, m, mod: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="dispatch"))(p, x, m, mod)
    # routed counts are integers: exact equality across topologies
    for k in ("load_d", "vis_d", "expert_load", "expert_vis",
              "slot_load", "slot_vis"):
        a = np.asarray(aux_v[k]).reshape(-1)
        b = np.asarray(aux_d[k]).reshape(-1)
        assert np.array_equal(a, b), (k, a, b)
    # policy decisions and AIMD state evolve identically
    for k in ("ib_global", "gate_open", "fp4_ranks", "drop_frac",
              "split_frac"):
        a, b = float(aux_v[k]), float(aux_d[k])
        assert abs(a - b) < 1e-6, (k, a, b)
    assert np.allclose(np.asarray(m_v), np.asarray(m_d)), (m_v, m_d)
    # NOTE: outputs are *not* compared here — the policy decided FP4 for
    # the same virtual ranks, but a single device applies compression to
    # its whole (virtual) group while the mesh compresses per physical
    # rank; numerical output parity (policy off) is pinned by
    # ep_dispatch_matches_local.


def check_replication_identity_bitwise_under_ep():
    """Under a real EP mesh, the explicit identity replica set is
    bitwise-equal to the default (placement=None) path."""
    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ident = ep_moe.identity_replication(cfg.moe.num_experts, 4)
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        for mode, xx, mm in (("dispatch", x, mod),
                             ("broadcast", x[:, :1], mod[:, :1])):
            y0, m0, _ = jax.jit(lambda p, x, m, mod: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode=mode))(p, xx, m, mm)
            y1, m1, aux1 = jax.jit(
                lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                    p, x, cfg, rcfg, m, mod, mode=mode, placement=pl))(
                p, xx, m, mm, ident)
            assert np.array_equal(np.asarray(y0), np.asarray(y1)), mode
            assert np.array_equal(np.asarray(m0), np.asarray(m1)), mode
            assert float(aux1["split_frac"]) == 0.0, mode


def check_replication_split_under_ep():
    """A replicated hot expert on a (2,4) mesh: outputs match the
    local single-device reference, the EP ranks exchange split tokens,
    and the post-split rank loads flatten the hot rank."""
    from repro.replication import ReplicaSet, expand_moe_params

    cfg, p, x, mod = _moe_setup()
    e = cfg.moe.num_experts
    p = dict(p, router=p["router"].at[:, 0].add(4.0))    # expert 0 hot
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    # expert 0 replicated onto rank 2's spare slot (slots_per_rank=3)
    rep_pos = np.zeros((e, 2), np.int32)
    for ex in range(e):
        rep_pos[ex] = (ex // 2) * 3 + (ex % 2)
    rep_pos[0, 1] = 2 * 3 + 2
    n_rep = np.ones(e, np.int32)
    n_rep[0] = 2
    rs = ReplicaSet(rep_pos, n_rep, 4, 3)
    wrapped = {"blocks": {"l0": {"moe": p}}}
    p_rep = dict(expand_moe_params(wrapped, rs)["blocks"]["l0"]["moe"],
                 router=p["router"])
    place = tuple(jnp.asarray(a) for a in rs.as_arrays())

    y_ref, _, aux_ref = ep_moe.ep_moe_forward(
        p, x, cfg, rcfg, jnp.full((1, 1), 0.9), mod, mode="dispatch")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        y, _, aux = jax.jit(
            lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="dispatch", placement=pl))(
            p_rep, x, m, mod, place)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err < 5e-5, err
    assert float(aux["split_frac"]) > 0.0
    el = np.asarray(aux["expert_load"])
    assert np.array_equal(el, np.asarray(aux_ref["expert_load"]))
    sl = np.asarray(aux["slot_load"])
    a, b = sl[rs.rep_pos[0, 0]], sl[rs.rep_pos[0, 1]]
    assert a + b == el[0] and a > 0 and b > 0, (a, b, el[0])
    # the hot rank sheds (about) half the hot expert's load to rank 2 —
    # each of the 8 shard-local round-robin counters keeps its odd
    # remainder on the primary, so allow one assignment per shard
    load_d = np.asarray(aux["load_d"]).reshape(-1, 4).sum(0)
    want = rs.rank_loads(el)
    assert np.abs(load_d - want).max() <= 8.0, (load_d, want)
    ident = ep_moe.identity_replication(e, 4)
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        _, _, aux_i = jax.jit(
            lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="dispatch", placement=pl))(
            p, x, m, mod, ident)
    load_i = np.asarray(aux_i["load_d"]).reshape(-1, 4).sum(0)
    assert load_d[0] < load_i[0], (load_d, load_i)   # hot rank shed load


def check_perlayer_identity_bitwise_under_ep():
    """Per-layer tentpole on a real (2,4) mesh: stacked identity tables
    threaded through the layer scan are bitwise-equal to the shared
    identity table AND to the table-free path — full model, prefill and
    decode."""
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens}
    _, n_blocks, _ = tf.block_structure(cfg)
    ident = ep_moe.identity_replication(cfg.moe.num_experts, 4)
    stacked = tuple(jnp.broadcast_to(a, (n_blocks,) + a.shape)
                    for a in ident)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        outs = {}
        for name, pl in (("none", None), ("shared", tuple(ident)),
                         ("stacked", stacked)):
            res = jax.jit(lambda p, m, pl=pl: tf.prefill_forward(
                p, cfg, rcfg, batch, m, cache_len=20,
                placement=pl))(params, m)
            db = {"tokens": tokens[:, :1],
                  "pos": jnp.full((4,), 16, jnp.int32)}
            dec = jax.jit(lambda p, c, m, pl=pl: tf.decode_forward(
                p, cfg, rcfg, db, c, m, placement=pl))(
                params, res.cache, res.m_state)
            outs[name] = (np.asarray(res.logits), np.asarray(res.m_state),
                          np.asarray(dec.logits))
        for name in ("none", "shared"):
            for a, b in zip(outs[name], outs["stacked"]):
                assert np.array_equal(a, b), name


def check_perlayer_tables_matches_local_under_ep():
    """Depth-varying per-layer permutation tables (each block's weights
    permuted by its own table) on the (2,4) mesh match the local
    single-device per-layer run and the table-free reference."""
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    e = cfg.moe.num_experts
    rng = np.random.default_rng(5)
    _, n_blocks, _ = tf.block_structure(cfg)
    owners, e2r, slot = [], [], []
    for l in range(n_blocks):
        owner = rng.permutation(e)
        pos = np.empty(e, np.int64)
        pos[owner] = np.arange(e)
        owners.append(owner)
        e2r.append(pos // 2)
        slot.append(pos % 2)
    place = (jnp.asarray(np.stack(e2r), jnp.int32),
             jnp.asarray(np.stack(slot), jnp.int32))
    own = np.stack(owners)
    perm = dict(params)
    blocks = dict(perm["blocks"])
    lp = dict(blocks["layer0"])
    moe = dict(lp["moe"])
    for key in ("w_gate", "w_up", "w_down"):
        w = np.asarray(moe[key])
        moe[key] = jnp.asarray(np.take_along_axis(
            w, own.reshape(own.shape + (1, 1)), axis=1))
    lp["moe"] = moe
    blocks["layer0"] = lp
    perm["blocks"] = blocks
    rng2 = np.random.default_rng(1)
    tokens = jnp.asarray(rng2.integers(0, cfg.vocab_size, (4, 16)),
                         jnp.int32)
    batch = {"tokens": tokens}
    m1 = jnp.full((1, 4), 0.9)
    ref = tf.prefill_forward(params, cfg, rcfg, batch, m1, cache_len=20)
    loc = tf.prefill_forward(perm, cfg, rcfg, batch, m1, cache_len=20,
                             placement=place)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        res = jax.jit(lambda p, m: tf.prefill_forward(
            p, cfg, rcfg, batch, m, cache_len=20,
            placement=place))(perm, m)
    e1 = float(jnp.max(jnp.abs(loc.logits - ref.logits)))
    e2 = float(jnp.max(jnp.abs(res.logits - ref.logits)))
    assert e1 < 5e-3 and e2 < 5e-3, (e1, e2)


def check_async_migrate_chunks_match_sync_under_ep():
    """Async tentpole on a real (2,4) mesh: draining a staged per-layer
    plan chunk-by-chunk (subset gathers on the mesh-resident stacked
    weights, per-layer table commits) must leave params bitwise-equal to
    the one-shot synchronous apply — and the model must produce the same
    logits through either copy under the committed tables."""
    from repro.configs import PlacementConfig
    from repro.placement import PlacementManager, apply_to_params
    from repro.serving.async_migrate import MigrationExecutor

    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))

    def mk():
        mgr = PlacementManager(cfg, PlacementConfig(
            replan_every=2, warmup_iters=1, min_gain=0.0,
            per_layer=True), 4)
        es = np.zeros((2, 2, cfg.moe.num_experts))
        es[0, 0] = [10.0, 8, 1, 1, 1, 1, 1, 1]
        es[1, 0] = [1.0, 1, 1, 1, 1, 1, 8, 10]
        es[:, 1] = es[:, 0] * 0.5
        mgr.observe(es)
        return mgr, mgr.maybe_replan(2)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m_sync, p_sync = mk()
        m_async, p_async = mk()
        assert p_sync is not None and len(m_sync.plan_layers(p_sync)) == 2
        np.testing.assert_array_equal(p_sync.gather_idx, p_async.gather_idx)
        ref = apply_to_params(params, p_sync)
        m_sync.commit(p_sync)
        ex = MigrationExecutor(m_async, p_async, bytes_per_iter=1)
        out = params
        while ex.draining:
            out, _ = ex.drain(out)
        assert ex.n_drains == 2          # one chunk (layer) per drain
        for key in ("w_gate", "w_up", "w_down"):
            a = np.asarray(ref["blocks"]["layer0"]["moe"][key])
            b = np.asarray(out["blocks"]["layer0"]["moe"][key])
            assert np.array_equal(a, b), key
        for a, b in zip(m_sync.tables, m_async.tables):
            np.testing.assert_array_equal(a.e2r, b.e2r)
        assert m_async.bandwidth.calibrated
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                             jnp.int32)
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        place = tuple(jnp.asarray(t) for t in m_async.device_tables())
        r_ref = jax.jit(lambda p, m: tf.prefill_forward(
            p, cfg, rcfg, {"tokens": tokens}, m, cache_len=20,
            placement=place))(ref, m)
        r_out = jax.jit(lambda p, m: tf.prefill_forward(
            p, cfg, rcfg, {"tokens": tokens}, m, cache_len=20,
            placement=place))(out, m)
        assert np.array_equal(np.asarray(r_ref.logits),
                              np.asarray(r_out.logits))


def check_replica_capacity_reduced_cap():
    """Replica-aware capacity on the (2,4) mesh: at the post-split-derived
    reduced ``capacity_factor`` the skewed stream routes with zero drops
    through the replicated dispatch, while the bijective layout at the
    same cap overflows its per-rank buffer."""
    from repro.replication import ReplicaSet, expand_moe_params

    cfg, p, x, mod = _moe_setup()
    e = cfg.moe.num_experts
    # a deterministically hot expert 0: feature 0 of every token is a
    # constant 1.0 and only expert 0's router column reads it
    p = dict(p)
    p["router"] = p["router"].at[0, :].set(0.0).at[0, 0].set(8.0)
    x = x.at[..., 0].set(1.0)
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    # expert 0 replicated onto rank 2's spare slot
    rep_pos = np.zeros((e, 2), np.int32)
    for ex in range(e):
        rep_pos[ex] = (ex // 2) * 3 + (ex % 2)
    rep_pos[0, 1] = 3 * 3 + 2        # replica on the coldest rank's spare
    n_rep = np.ones(e, np.int32)
    n_rep[0] = 2
    rs = ReplicaSet(rep_pos, n_rep, 4, 3)
    # observe the skew at the generous default cap, then derive the
    # reduced factor from the post-split peak rank load
    _, _, aux = ep_moe.ep_moe_forward(
        p, x, cfg, rcfg, jnp.full((1, 1), 0.9), mod, mode="dispatch")
    el = np.asarray(aux["expert_load"])
    assert el[0] / el.sum() > 0.4, el           # genuinely hot
    f_red = rs.capacity_factor(el, margin=1.2)
    # the bijective peak does NOT fit the reduced buffer
    ident = ReplicaSet.identity(e, 4, slots_per_rank=3, max_replicas=2)
    assert ident.rank_loads(el).max() > el.sum() / 4 * f_red
    cfg_red = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=f_red))
    wrapped = {"blocks": {"l0": {"moe": p}}}
    place_rep = tuple(jnp.asarray(a) for a in rs.as_arrays())
    place_bij = tuple(jnp.asarray(a) for a in ident.as_arrays())
    p_rep = dict(expand_moe_params(wrapped, rs)["blocks"]["l0"]["moe"],
                 router=p["router"])
    p_bij = dict(expand_moe_params(wrapped, ident)["blocks"]["l0"]["moe"],
                 router=p["router"])
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        _, _, aux_rep = jax.jit(
            lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                p, x, cfg_red, rcfg, m, mod, mode="dispatch",
                placement=pl))(p_rep, x, m, mod, place_rep)
        _, _, aux_bij = jax.jit(
            lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                p, x, cfg_red, rcfg, m, mod, mode="dispatch",
                placement=pl))(p_bij, x, m, mod, place_bij)
    drop_rep = float(aux_rep["drop_frac"])
    drop_bij = float(aux_bij["drop_frac"])
    assert drop_rep == 0.0, drop_rep            # split fits the reduced cap
    assert drop_bij > 0.0, (drop_bij, f_red)    # bijective overflows it


def check_model_train_step_under_mesh():
    """Tiny full model: distributed train step ≈ single-device step."""
    from repro.optim import adamw
    from repro.configs import TrainConfig

    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    # zero the aux-loss coefficients (the LB loss is *defined* per EP group,
    # so its gradient legitimately differs between 1 global group and
    # per-data-row groups) and make capacity drop-free (cap ≥ t·k: the
    # tiny per-source-per-dest buffers would otherwise drop a few routed
    # items that the single-device ep=1 reference keeps).
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, aux_loss_coef=0.0,
                                     router_z_coef=0.0,
                                     capacity_factor=8.0))
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    tcfg = TrainConfig(lr=1e-3)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, tcfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}

    def loss_fn(params, m):
        return tf.train_loss(params, cfg, rcfg, batch, m)

    m0 = jnp.full((1, 1), 0.9)
    (l_ref, _), g_ref = jax.value_and_grad(loss_fn, has_aux=True)(params, m0)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        (l_d, _), g_d = jax.jit(jax.value_and_grad(
            lambda p, m: tf.train_loss(p, cfg, rcfg, batch, m),
            has_aux=True))(params, m)
    assert abs(float(l_d) - float(l_ref)) < 5e-3, (float(l_d), float(l_ref))
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ref, g_d)
    worst = max(jax.tree.leaves(errs))
    assert worst < 5e-3, worst


def check_decode_under_mesh():
    """Prefill + decode of a tiny model under the mesh: finite and
    consistent with the single-device path."""
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens}

    res_ref = tf.prefill_forward(params, cfg, rcfg, batch,
                                 jnp.full((1, 1), 0.9), cache_len=20)
    db = {"tokens": tokens[:, :1], "pos": jnp.full((4,), 16, jnp.int32)}
    dec_ref = tf.decode_forward(params, cfg, rcfg, db, res_ref.cache,
                                res_ref.m_state)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        res = jax.jit(lambda p, m: tf.prefill_forward(
            p, cfg, rcfg, batch, m, cache_len=20))(params, m)
        dec = jax.jit(lambda p, c, m: tf.decode_forward(
            p, cfg, rcfg, db, c, m))(params, res.cache, res.m_state)
    e1 = float(jnp.max(jnp.abs(res.logits - res_ref.logits)))
    e2 = float(jnp.max(jnp.abs(dec.logits - dec_ref.logits)))
    assert e1 < 5e-3 and e2 < 5e-3, (e1, e2)


def check_elastic_reshard():
    """Params sharded on a (2,4) mesh move to a (1,4) mesh (lost 'data'
    slice) and produce identical outputs."""
    from repro.models.common import named_sharding

    cfg = reduced(get_config("qwen1.5-0.5b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    from jax.sharding import Mesh
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    mesh_b = Mesh(np.array(jax.devices()[:4]).reshape(1, 4),
                  ("data", "model"))
    rcfg = ReaLBConfig()
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    m = jnp.full((1, 1), 0.9)
    l_ref, _ = tf.train_loss(params, cfg, rcfg, batch, m)

    # place on A, pull to host, re-place on B (checkpoint-free reshard)
    from repro.models.common import resolve_spec
    from jax.sharding import NamedSharding

    def place(tree, mesh):
        return jax.tree.map(lambda a: jax.device_put(a, NamedSharding(
            mesh, resolve_spec(a.shape, (None,) * a.ndim, mesh))), tree)

    pa = place(params, mesh_a)
    host = jax.tree.map(lambda a: np.asarray(a), pa)
    pb = place(host, mesh_b)
    with use_mesh(mesh_b):
        l_b, _ = jax.jit(lambda p, m: tf.train_loss(
            p, cfg, rcfg, batch, m))(pb, m)
    assert abs(float(l_b) - float(l_ref)) < 1e-3


def check_weighted_split_under_ep():
    """Weighted per-replica token splitting on the (2,4) mesh: an
    equal-share schedule is bitwise-identical to the 3-table round-robin
    path, and a skewed schedule shifts the replica's share of the hot
    expert's tokens to the scheduled quota (within shard quantization)."""
    from repro.replication import ReplicaSet, expand_moe_params

    cfg, p, x, mod = _moe_setup()
    e = cfg.moe.num_experts
    p = dict(p, router=p["router"].at[:, 0].add(4.0))    # expert 0 hot
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    rep_pos = np.zeros((e, 2), np.int32)
    for ex in range(e):
        rep_pos[ex] = (ex // 2) * 3 + (ex % 2)
    rep_pos[0, 1] = 2 * 3 + 2
    n_rep = np.ones(e, np.int32)
    n_rep[0] = 2
    rs = ReplicaSet(rep_pos, n_rep, 4, 3)
    wrapped = {"blocks": {"l0": {"moe": p}}}
    p_rep = dict(expand_moe_params(wrapped, rs)["blocks"]["l0"]["moe"],
                 router=p["router"])
    base = tuple(jnp.asarray(a) for a in rs.as_arrays())

    def run(place):
        with use_mesh(mesh):
            m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
            return jax.jit(
                lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                    p, x, cfg, rcfg, m, mod, mode="dispatch",
                    placement=pl))(p_rep, x, m, mod, place)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    y3, _, aux3 = run(base)
    # equal-share schedule == occ % n_rep: the 4-table path is bitwise
    # the 3-table path
    sched_eq = jnp.asarray(rs.split_schedule())
    y4, _, aux4 = run(base + (sched_eq,))
    assert np.array_equal(np.asarray(y3), np.asarray(y4))
    assert np.array_equal(np.asarray(aux3["slot_load"]),
                          np.asarray(aux4["slot_load"]))

    # skewed 2:1 schedule: the primary keeps ~2/3 of the hot expert
    w = np.zeros((e, 2))
    w[:, 0] = 1.0
    w[0] = [2.0, 1.0]
    y_w, _, aux_w = run(base + (jnp.asarray(rs.split_schedule(w)),))
    el = np.asarray(aux_w["expert_load"])
    sl = np.asarray(aux_w["slot_load"])
    a, b = sl[rs.rep_pos[0, 0]], sl[rs.rep_pos[0, 1]]
    assert a + b == el[0], (a, b, el[0])            # zero dropped tokens
    # 8 shard-local counters each quantize the 12-phase schedule: allow
    # one assignment of slack per shard around the exact 2/3 quota
    assert abs(a - 2.0 * el[0] / 3.0) <= 8.0, (a, el[0])
    assert a > b > 0
    # outputs stay correct under the skewed split (same expert math,
    # different replica routing)
    y_ref, _, _ = ep_moe.ep_moe_forward(
        p, x, cfg, rcfg, jnp.full((1, 1), 0.9), mod, mode="dispatch")
    err = float(jnp.max(jnp.abs(y_w - y_ref)))
    assert err < 5e-5, err


def check_elastic_kill_rejoin_under_ep():
    """Kill/rejoin of EP rank 2 on the (2,4) mesh, full elastic cycle:
    the replicated expert stays routable the same iteration with zero
    dropped tokens, stranded singletons land on the dead (zeroed) slots
    and are re-materialized from checkpoint through the byte-budgeted
    executor, the recovered path is bitwise-identical to a healthy
    engine on the final tables, and the rejoined rank hosts replicas
    again after its warm-up plan lands."""
    import tempfile

    from repro.checkpoint import ckpt
    from repro.configs import ReplicationConfig
    from repro.replication import ReplicaManager, ReplicaSet, \
        expand_moe_params
    from repro.serving.async_migrate import MigrationExecutor
    from repro.serving.elastic import ElasticCoordinator

    cfg, p, x, mod = _moe_setup()
    e = cfg.moe.num_experts
    p = dict(p, router=p["router"].at[:, 0].add(4.0))    # expert 0 hot
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    rpcfg = ReplicationConfig(enabled=True, spare_per_rank=1,
                              max_replicas=2, replan_every=1,
                              warmup_iters=0, min_gain=0.0)
    mgr = ReplicaManager.from_geometry(e, rpcfg, 4, bytes_per_expert=256)
    spr = mgr.slots_per_rank
    assert spr == 3
    # expert 0 replicated onto rank 2's spare; identity otherwise
    rep_pos = np.zeros((e, 2), np.int32)
    for ex in range(e):
        rep_pos[ex] = (ex // 2) * spr + (ex % 2)
    rep_pos[0, 1] = 2 * spr + 2
    n_rep = np.ones(e, np.int32)
    n_rep[0] = 2
    mgr.rsets[0] = ReplicaSet(rep_pos, n_rep, 4, spr)
    wrapped = {"blocks": {"l0": {"moe": p}}}
    params = expand_moe_params(wrapped, mgr.rset)
    params["blocks"]["l0"]["moe"]["router"] = p["router"]

    tmp = tempfile.mkdtemp()
    ckpt.save(tmp, 0, {"serving": {"params": params,
                                   "m_state": np.zeros((1, 4))},
                       mgr.ckpt_group: mgr.state_dict()})
    co = ElasticCoordinator(mgr, ckpt_dir=tmp)

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def run(params):
        place = tuple(jnp.asarray(a) for a in mgr.device_tables())
        moe = params["blocks"]["l0"]["moe"]
        with use_mesh(mesh):
            m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
            return jax.jit(
                lambda p, x, m, mod, pl: ep_moe.ep_moe_forward(
                    p, x, cfg, rcfg, m, mod, mode="dispatch",
                    placement=pl))(moe, x, m, mod, place)

    y_ref, _, aux_ref = ep_moe.ep_moe_forward(
        p, x, cfg, rcfg, jnp.full((1, 1), 0.9), mod, mode="dispatch")
    el_ref = np.asarray(aux_ref["expert_load"])

    # ---- kill rank 2: experts 4, 5 are stranded singletons; the hot
    # expert 0 keeps its rank-0 primary routable the same iteration
    params = co.fail_rank(2, params)
    assert sorted(co.lost_experts.tolist()) == [4, 5]
    assert co.state == "degraded"
    # no live expert routes to the dead rank (lost experts keep their
    # dead-slot rows by design — that is where lost tokens are counted)
    for ex in range(e):
        if ex in (4, 5):
            continue
        ranks = mgr.rset.rep_pos[ex, :mgr.rset.n_rep[ex]] // spr
        assert 2 not in ranks.tolist(), ex
    assert mgr.rset.n_rep[0] == 1                # replica masked off
    assert mgr.rset.rep_pos[0, 0] == 0           # primary survives

    y_deg, _, aux_deg = run(params)
    el = np.asarray(aux_deg["expert_load"])
    sl = np.asarray(aux_deg["slot_load"])
    assert np.array_equal(el, el_ref)            # routing itself unchanged
    # zero dropped tokens for every live expert: its slot loads sum to
    # its expert load exactly
    for ex in range(e):
        if ex in (4, 5):
            continue
        slots = np.unique(mgr.rset.rep_pos[ex, :mgr.rset.n_rep[ex]])
        assert sl[slots].sum() == el[ex], (ex, sl[slots], el[ex])
    # stranded tokens landed on the dead rank's zeroed slots, counted
    assert sl[2 * spr + 0] == el[4] and sl[2 * spr + 1] == el[5]
    es = np.stack([el, np.zeros(e)])[None]
    assert co.lost_token_count(es) == el[4] + el[5]
    # the physical mesh minus the dead model slice
    assert co.effective_mesh(mesh, lost_axis="model").devices.shape \
        == (2, 3)

    # ---- recovery: event replan onto the 3 live ranks, recovery chunks
    # first, checkpoint rows patched in pre-commit
    mgr.observe(es)
    plan = mgr.maybe_replan(1)
    assert plan is not None
    ex_mig = MigrationExecutor(mgr, plan, bytes_per_iter=1 << 30,
                               priority_layers=co.recovery_layers(plan),
                               patch_fn=co.patch_params)
    while ex_mig.draining:
        params, rep = ex_mig.drain(params)
        co.on_layers_landed(plan, rep.layers)
    assert not co.recovering
    assert co.last_recovery_s is not None
    assert not mgr.rset.hosts_rank(2)

    # bitwise parity with the healthy path: a fresh expansion of the
    # logical weights onto the recovered tables gives identical logits
    p_healthy = expand_moe_params(wrapped, mgr.rset)
    p_healthy["blocks"]["l0"]["moe"]["router"] = p["router"]
    y_rec, _, aux_rec = run(params)
    y_h, _, _ = run(p_healthy)
    assert np.array_equal(np.asarray(y_rec), np.asarray(y_h))
    err = float(jnp.max(jnp.abs(y_rec - y_ref)))
    assert err < 5e-5, err
    # every expert routable again: slot loads cover every expert load
    sl = np.asarray(aux_rec["slot_load"])
    for ex in range(e):
        slots = np.unique(mgr.rset.rep_pos[ex, :mgr.rset.n_rep[ex]])
        assert sl[slots].sum() == el_ref[ex], ex

    # ---- rejoin: plannable at once, routable only after the staged
    # warm-up plan lands
    co.rejoin_rank(2)
    assert co.state == "warming"
    assert not mgr.hosts_rank(2)
    mgr.observe(es)
    plan2 = mgr.maybe_replan(2)
    assert plan2 is not None
    assert not mgr.hosts_rank(2)                 # staged, not routable
    ex_mig2 = MigrationExecutor(mgr, plan2, bytes_per_iter=1 << 30,
                                priority_layers=co.recovery_layers(plan2),
                                patch_fn=co.patch_params)
    while ex_mig2.draining:
        params, rep = ex_mig2.drain(params)
        co.on_layers_landed(plan2, rep.layers)
    assert co.state == "healthy"
    assert mgr.hosts_rank(2)
    y_fin, _, _ = run(params)
    err = float(jnp.max(jnp.abs(y_fin - y_ref)))
    assert err < 5e-5, err


def check_collective_census_reconciles():
    """Three independent derivations of the dispatch path's collective
    traffic on the (2,4) mesh must agree: the traced jaxpr census, the
    post-XLA HLO census (while-loop trip counts multiplied through) and
    the FlopByteLedger's analytic graph prediction.  An extra psum or a
    silently widened all-to-all payload breaks one of the three."""
    from repro.analysis.jaxpr_audit import collective_census_jaxpr
    from repro.launch.hlo_analysis import collective_census
    from repro.obs.ledger import FlopByteLedger

    cfg, p, x, mod = _moe_setup()
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    L = 3
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def fwd(p, x, m):
        def step(carry, _):
            x_c, m_c = carry
            y, m_n, aux = ep_moe.ep_moe_forward(p, x_c, cfg, rcfg, m_c,
                                                mod, mode="dispatch")
            # return the full aux so no psum is dead code post-XLA
            return (y, m_n), aux
        return jax.lax.scan(step, (x, m), None, length=L)

    with use_mesh(mesh):
        m = jnp.full(ep_moe.moe_state_shape(mesh, 4), 0.9)
        closed = jax.make_jaxpr(fwd)(p, x, m)
        hlo = jax.jit(fwd).lower(p, x, m).compile().as_text()

    jx = collective_census_jaxpr(closed)
    # per-device tokens entering the layer: batch 4/2 x seq 16/4
    led = FlopByteLedger(cfg, ep=4).predict_graph_census(
        t_local=8, layers=L, itemsize=x.dtype.itemsize)
    # jaxpr == ledger, exactly: same capacity formula, same shapes
    for kind in ("all_to_all", "psum"):
        assert jx.get(kind) == led[kind], (kind, jx.get(kind), led[kind])

    hl = collective_census(hlo)
    # program-issued collectives only ("user"): the partitioner also
    # inserts all-reduces to aggregate the harness's sharded aux outputs
    a2a = hl["user"].get("all-to-all", {"count": 0, "bytes": 0})
    ar = hl["user"].get("all-reduce", {"count": 0, "bytes": 0})
    assert a2a["count"] == led["all_to_all"]["count"], (a2a, led)
    assert a2a["bytes"] == led["all_to_all"]["bytes"], (a2a, led)
    # psum lowers to all-reduce; XLA may merge several and hoist
    # loop-invariant scalar psums out of the scan (count <=, bytes
    # within a few hoisted scalars of the prediction)
    assert 0 < ar["count"] <= led["psum"]["count"], (ar, led)
    pred_b = led["psum"]["bytes"]
    assert abs(ar["bytes"] - pred_b) / pred_b <= 0.05, (ar, led)
    # the steady-state body is loop-carried with the full trip count
    assert hl["layers"] == L, hl["layers"]
    # and the ledger's *routed* ICI bytes never exceed the graph's
    # capacity-buffer bytes (the buffers are what actually moves)
    t_global = 4 * 16
    a2a_routed = (t_global * cfg.moe.top_k / 4 * 3 / 4
                  * cfg.d_model * 2.0) * 4 * 2 * L
    graph_global = led["all_to_all"]["bytes"] * 8  # 8 devices
    assert a2a_routed <= graph_global, (a2a_routed, graph_global)


def check_kernel_fp4_parity_under_ep():
    """Pallas grouped FP4 FFN + quantize kernels wired into the hot loop
    (interpret mode on CPU): FP4 genuinely fires on the (2,4) mesh and the
    kernel output matches the jnp fallback *at the same sharding* to
    float-reassociation noise.  (Local-vs-mesh is NOT compared under FP4:
    the per-tensor global scale is computed per weight slab, so the local
    one-slab and mesh four-slab quantizations legitimately differ.)"""
    from repro.kernels import ops as kops
    cfg, p, x, mod = _moe_setup()
    p = dict(p)   # skew routing: rank 0 hot + all-vision -> FP4 fires
    p["router"] = p["router"].at[:, 0].add(3.0).at[:, 1].add(2.5)
    vis = jnp.ones_like(mod)
    rcfg = ReaLBConfig(gate_gamma=1)
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def run(local):
        if local:
            return ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, jnp.zeros((1, 4)), vis, mode="dispatch")
        with use_mesh(mesh):
            m = jnp.zeros(ep_moe.moe_state_shape(mesh, 4))
            return jax.jit(lambda p, x, m, mod: ep_moe.ep_moe_forward(
                p, x, cfg, rcfg, m, mod, mode="dispatch"))(p, x, m, vis)

    kops.set_ffn_backend("interpret")
    try:
        assert kops.ffn_fused()
        y_loc_k, _, aux_loc = run(local=True)
        y_mesh_k, _, aux_mesh = run(local=False)
    finally:
        kops.set_ffn_backend(None)
    assert float(aux_loc["fp4_ranks"]) >= 1.0, float(aux_loc["fp4_ranks"])
    assert float(aux_mesh["fp4_ranks"]) >= 1.0, float(aux_mesh["fp4_ranks"])
    y_loc_j, _, _ = run(local=True)          # default backend: jnp on CPU
    y_mesh_j, _, _ = run(local=False)
    d_loc = float(jnp.max(jnp.abs(y_loc_k - y_loc_j)))
    d_mesh = float(jnp.max(jnp.abs(y_mesh_k - y_mesh_j)))
    assert d_loc < 1e-3, d_loc
    assert d_mesh < 1e-3, d_mesh
    # and the quantization really happened: FP4 output != a bf16 run
    y_off, _, _ = ep_moe.ep_moe_forward(
        p, x, cfg, ReaLBConfig(enabled=False), jnp.zeros((1, 4)), vis,
        mode="dispatch")
    assert float(jnp.max(jnp.abs(y_mesh_k - y_off))) > 1e-6


CHECKS = {k[len("check_"):]: v for k, v in list(globals().items())
          if k.startswith("check_")}

if __name__ == "__main__":
    name = sys.argv[1]
    CHECKS[name]()
    print(f"OK {name}")
