"""Per-layer placement/replication tables: stacked tables threaded
through the layer scan (identity ≡ bitwise to the shared path),
layer-diff migration (bytes ∝ changed layers only), per-layer planning
beating shared-table planning on depth-varying skew, decode-window
prediction, replica-aware capacity, the calibrated replan cost gate and
the per-layer checkpoint round-trip + per-layer↔shared refusal."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (PlacementConfig, ReaLBConfig, ReplicationConfig,
                           get_config, reduced)
from repro.core import ep_moe
from repro.models import transformer as tf
from repro.placement import (EWMAPredictor, LayerMigrationPlan,
                             PlacementManager, PlacementTable,
                             apply_to_params, diff_layers,
                             plan_least_loaded)
from repro.replication import (ReplicaManager, ReplicaSet,
                               expand_moe_params, plan_replication)
from repro.replication import diff_layers as rep_diff_layers


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _skew_stats(skews, e=8):
    """[L, 2, E] per-layer (load, vis) stats from per-layer load rows."""
    es = np.zeros((len(skews), 2, e))
    for l, row in enumerate(skews):
        es[l, 0] = row
        es[l, 1] = np.asarray(row) * 0.5
    return es


SKEW = [10.0, 8, 1, 1, 1, 1, 1, 1]
FLAT = [1.0] * 8


# --------------------------------------------------------------------------
# stacked tables through the layer scan (tentpole identity parity)
# --------------------------------------------------------------------------
def test_split_placement_shapes():
    ident = ep_moe.identity_replication(8, 4)
    shared, stacked = tf.split_placement(tuple(ident), 3)
    assert stacked is None and len(shared) == 3
    st = tuple(np.broadcast_to(np.asarray(a), (3,) + a.shape)
               for a in ident)
    shared, stacked = tf.split_placement(st, 3)
    assert shared is None and stacked[0].shape == (3, 8, 1)
    with pytest.raises(AssertionError):
        tf.split_placement(st, 4)           # wrong leading axis
    assert tf.split_placement(None, 3) == (None, None)


def test_perlayer_identity_bitwise_full_model(model):
    """Stacked identity tables threaded through the scan must be bitwise
    equal to the shared identity table AND to the table-free path, for
    prefill and decode."""
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)),
                         jnp.int32)
    m = jnp.full((1, 4), 0.9)
    _, n_blocks, _ = tf.block_structure(cfg)
    ident = ep_moe.identity_replication(cfg.moe.num_experts, 4)
    stacked = tuple(jnp.broadcast_to(a, (n_blocks,) + a.shape)
                    for a in ident)
    batch = {"tokens": tokens}
    r0 = tf.prefill_forward(params, cfg, rcfg, batch, m, cache_len=16)
    r1 = tf.prefill_forward(params, cfg, rcfg, batch, m, cache_len=16,
                            placement=stacked)
    r2 = tf.prefill_forward(params, cfg, rcfg, batch, m, cache_len=16,
                            placement=tuple(ident))
    for a, b in ((r0, r1), (r2, r1)):
        assert np.array_equal(np.asarray(a.logits), np.asarray(b.logits))
        assert np.array_equal(np.asarray(a.m_state), np.asarray(b.m_state))
    db = {"tokens": tokens[:, :1], "pos": jnp.full((2,), 12, jnp.int32)}
    d0 = tf.decode_forward(params, cfg, rcfg, db, r0.cache, r0.m_state)
    d1 = tf.decode_forward(params, cfg, rcfg, db, r1.cache, r1.m_state,
                           placement=stacked)
    assert np.array_equal(np.asarray(d0.logits), np.asarray(d1.logits))


def test_perlayer_tables_route_each_block_through_its_own_table(model):
    """Two different per-layer permutations (weights permuted per block)
    must reproduce the identity outputs — each block consumed its own
    slice, not a shared one."""
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    e = cfg.moe.num_experts
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)),
                         jnp.int32)
    m = jnp.full((1, 4), 0.9)
    _, n_blocks, _ = tf.block_structure(cfg)
    tables = [PlacementTable.identity(e, 4)]
    for l in range(1, n_blocks):
        owner = rng.permutation(e)
        pos = np.empty(e, np.int64)
        pos[owner] = np.arange(e)
        tables.append(PlacementTable(pos // 2, pos % 2, 4))
    place = (jnp.asarray(np.stack([t.e2r for t in tables]), jnp.int32),
             jnp.asarray(np.stack([t.local_slot for t in tables]),
                         jnp.int32))
    # permute each block's weight slab by its own table
    perm = dict(params)
    blocks = dict(perm["blocks"])
    lp = dict(blocks["layer0"])
    moe = dict(lp["moe"])
    own = np.stack([t.owner for t in tables])          # [L, E]
    for key in ("w_gate", "w_up", "w_down"):
        w = np.asarray(moe[key])
        moe[key] = jnp.asarray(np.take_along_axis(
            w, own.reshape(own.shape + (1, 1)), axis=1))
    lp["moe"] = moe
    blocks["layer0"] = lp
    perm["blocks"] = blocks
    batch = {"tokens": tokens}
    r0 = tf.prefill_forward(params, cfg, rcfg, batch, m, cache_len=16)
    r1 = tf.prefill_forward(perm, cfg, rcfg, batch, m, cache_len=16,
                            placement=place)
    np.testing.assert_allclose(np.asarray(r1.logits),
                               np.asarray(r0.logits), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# layer-diff migration: bytes ∝ changed layers only
# --------------------------------------------------------------------------
def test_diff_layers_bytes_proportional_to_changed_layers():
    ident = PlacementTable.identity(8, 4)
    skewed = plan_least_loaded(np.asarray(SKEW), 4)
    assert not np.array_equal(skewed.e2r, ident.e2r)
    old = [ident, ident, ident]
    one = diff_layers(old, [skewed, ident, ident], bytes_per_expert=7)
    two = diff_layers(old, [skewed, ident, skewed], bytes_per_expert=7)
    assert isinstance(one, LayerMigrationPlan)
    assert one.changed_layers.tolist() == [0]
    assert two.changed_layers.tolist() == [0, 2]
    assert one.moved_per_layer[1] == one.moved_per_layer[2] == 0
    assert one.moved_bytes == 7 * one.n_moved
    assert two.moved_bytes == 2 * one.moved_bytes       # ∝ changed layers
    # unchanged layers carry the identity gather row
    np.testing.assert_array_equal(one.gather_idx[1], np.arange(8))
    assert diff_layers(old, old, 7).is_noop


def test_apply_to_params_per_layer_gather():
    ident = PlacementTable.identity(8, 4)
    skewed = plan_least_loaded(np.asarray(SKEW), 4)
    plan = diff_layers([ident, ident, ident], [ident, skewed, ident], 5)
    w = np.arange(3 * 8 * 2 * 4, dtype=np.float32).reshape(3, 8, 2, 4)
    params = {"blocks": {"layer0": {"moe": {
        "router": np.zeros((2, 8)), "w_gate": w, "w_up": w + 1,
        "w_down": np.swapaxes(w, 2, 3)}}}}
    out = apply_to_params(params, plan)
    got = out["blocks"]["layer0"]["moe"]["w_gate"]
    np.testing.assert_array_equal(got[0], w[0])         # unchanged layers
    np.testing.assert_array_equal(got[2], w[2])
    for p_new in range(8):
        np.testing.assert_array_equal(got[1, p_new],
                                      w[1, skewed.owner[p_new]])


def test_replication_diff_layers_and_expand():
    ident = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=2)
    hot = plan_replication(np.asarray(SKEW), 4, 3, max_replicas=2)
    plan = rep_diff_layers([ident, ident], [hot, ident], bytes_per_expert=7)
    assert plan.changed_layers.tolist() == [0]
    assert plan.crossrank_per_layer[1] == 0
    assert plan.moved_bytes == 7 * plan.n_crossrank > 0
    np.testing.assert_array_equal(plan.gather_idx[1], np.arange(12))
    # per-layer expansion: each block laid out by its own set
    w = np.arange(2 * 8 * 2 * 3, dtype=np.float32).reshape(2, 8, 2, 3)
    params = {"blocks": {"layer0": {"moe": {
        "router": np.zeros((2, 8)), "w_gate": w, "w_up": w,
        "w_down": np.swapaxes(w, 2, 3)}}}}
    out = expand_moe_params(params, [ident, hot])
    got = out["blocks"]["layer0"]["moe"]["w_gate"]
    assert got.shape == (2, 12, 2, 3)
    for l, rs in enumerate((ident, hot)):
        own = rs.slot_owner
        for s in range(12):
            want = w[l, own[s]] if own[s] >= 0 else 0.0
            np.testing.assert_array_equal(got[l, s], want)


# --------------------------------------------------------------------------
# per-layer managers
# --------------------------------------------------------------------------
def test_perlayer_manager_replans_only_skewed_layers():
    pcfg = PlacementConfig(replan_every=2, warmup_iters=1, min_gain=0.0,
                           per_layer=True)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=7,
                                         n_layers=3)
    assert mgr.n_tables == 3 and mgr.per_layer
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    assert mgr.maybe_replan(1) is None                  # off-cadence
    plan = mgr.maybe_replan(2)
    assert isinstance(plan, LayerMigrationPlan)
    assert plan.moved_per_layer[1] == 0                 # flat layer kept
    assert plan.moved_per_layer[0] > 0 and plan.moved_per_layer[2] > 0
    # staged: routable tables unchanged until the slabs land + commit
    assert mgr.in_flight is plan
    np.testing.assert_array_equal(mgr.tables[0].e2r,
                                  PlacementTable.identity(8, 4).e2r)
    mgr.commit(plan)
    # the two skewed layers got different tables (depth-varying skew)
    assert not np.array_equal(mgr.tables[0].e2r, mgr.tables[2].e2r)
    np.testing.assert_array_equal(mgr.tables[1].e2r,
                                  PlacementTable.identity(8, 4).e2r)
    assert mgr.migrated_bytes == plan.moved_bytes == 7 * plan.n_moved
    assert mgr.migrated_bytes_per_layer[1] == 0
    assert mgr.migrated_bytes_per_layer.sum() == mgr.migrated_bytes
    # same prediction again: layer-diff is a no-op
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    assert mgr.maybe_replan(4) is None


def test_perlayer_replica_manager_staged_commit():
    rp = ReplicationConfig(replan_every=2, warmup_iters=1, min_gain=0.0,
                           per_layer=True)
    mgr = ReplicaManager.from_geometry(8, rp, 4, bytes_per_expert=7,
                                       n_layers=2)
    mgr.observe(_skew_stats([SKEW, FLAT]))
    before = [a.copy() for a in mgr.device_tables()]
    plan = mgr.maybe_replan(2)
    assert plan is not None and plan.changed_layers.tolist() == [0]
    for a, b in zip(before, mgr.device_tables()):       # staged: unchanged
        np.testing.assert_array_equal(a, b)
    assert mgr.maybe_replan(4) is None                  # one in flight
    mgr.commit(plan)
    assert mgr.n_migrations == 1
    assert (mgr.rsets[0].n_rep.max() > 1) and (mgr.rsets[1].n_rep == 1).all()
    assert mgr.migrated_bytes_per_layer[1] == 0
    tables = mgr.device_tables()
    assert tables[0].shape[0] == 2 and tables[2].shape == (2, 12)


def test_perlayer_manager_state_roundtrip_and_shared_mismatch():
    pcfg = PlacementConfig(replan_every=1, warmup_iters=1, min_gain=0.0,
                           per_layer=True)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=3,
                                         n_layers=2)
    mgr.observe(_skew_stats([SKEW, SKEW[::-1]]))
    plan = mgr.maybe_replan(1)
    assert plan is not None
    mgr.commit(plan)
    sd = {k: np.asarray(v) for k, v in mgr.state_dict().items()}
    m2 = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=3,
                                        n_layers=2)
    m2.load_state_dict(sd)
    for a, b in zip(m2.tables, mgr.tables):
        np.testing.assert_array_equal(a.e2r, b.e2r)
    np.testing.assert_array_equal(m2.migrated_bytes_per_layer,
                                  mgr.migrated_bytes_per_layer)
    # per-layer state refused by a shared manager (and vice versa)
    shared = PlacementManager.from_geometry(
        8, PlacementConfig(), 4, bytes_per_expert=3)
    with pytest.raises(ValueError, match="table"):
        shared.load_state_dict(sd)
    with pytest.raises(ValueError, match="table"):
        m2.load_state_dict(
            {k: np.asarray(v) for k, v in shared.state_dict().items()})


def test_perlayer_replica_state_mismatch_refused():
    rp_pl = ReplicationConfig(per_layer=True)
    rp_sh = ReplicationConfig()
    pl = ReplicaManager.from_geometry(8, rp_pl, 4, n_layers=2)
    sh = ReplicaManager.from_geometry(8, rp_sh, 4)
    sd = {k: np.asarray(v) for k, v in pl.state_dict().items()}
    with pytest.raises(ValueError, match="replica set"):
        sh.load_state_dict(sd)
    with pytest.raises(ValueError, match="replica set"):
        pl.load_state_dict(
            {k: np.asarray(v) for k, v in sh.state_dict().items()})


# --------------------------------------------------------------------------
# decode-aware prediction
# --------------------------------------------------------------------------
def test_predictor_decode_window_not_drowned_by_prefill():
    """An interleaved prefill-dominated stream (5 prefill : 1 decode, the
    serving engine's usual mix): the shared-window predictor's decode
    view decays back toward the prefill skew after every decode burst,
    while the separate decode window preserves the decode-regime skew."""
    def feed(pred):
        for _ in range(10):
            for _ in range(5):
                pred.observe(np.array([[100.0, 0, 0, 0]]))
            pred.observe(np.array([[0, 0, 0, 8.0]]), decode=True)
        for _ in range(5):                    # stream ends prefill-heavy
            pred.observe(np.array([[100.0, 0, 0, 0]]))

    pred = EWMAPredictor(4, alpha=0.25, decode_halflife=2.0)
    feed(pred)
    mixed, _ = pred.predict()
    decode, _ = pred.predict(regime="decode")
    assert np.argmax(mixed) == 0              # main window: prefill skew
    assert np.argmax(decode) == 3             # decode window: decode skew
    assert decode[0] == 0.0
    assert pred.n_obs_decode == 10
    # without a decode window the same stream drowns the decode skew
    plain = EWMAPredictor(4, alpha=0.25)
    feed(plain)
    assert np.argmax(plain.predict(regime="decode")[0]) == 0


def test_predictor_decode_state_roundtrip():
    pred = EWMAPredictor(4, alpha=0.3, decode_halflife=4.0)
    pred.observe(np.array([[1.0, 2, 3, 4]]))
    pred.observe(np.array([[4.0, 3, 2, 1]]), decode=True)
    sd = {k: np.asarray(v) for k, v in pred.state_dict().items()}
    p2 = EWMAPredictor(4, decode_halflife=4.0)
    p2.load_state_dict(sd)
    np.testing.assert_allclose(p2.predict(regime="decode")[0],
                               pred.predict(regime="decode")[0])
    assert p2.n_obs_decode == 1 and p2.decode_halflife == 4.0
    # decode_halflife is config, not state: a window-less restorer drops
    # the (would-be-stale) decode window instead of serving it forever
    p3 = EWMAPredictor(4)
    p3.load_state_dict(sd)
    assert p3.decode_halflife == 0.0 and p3.n_obs_decode == 0
    assert p3.load_dec is None
    np.testing.assert_allclose(p3.predict(regime="decode")[0],
                               pred.predict()[0])     # falls back to main
    # ... and a decode-enabled restorer keeps its window even when the
    # checkpoint was written by a window-less run
    sd_plain = {k: np.asarray(v)
                for k, v in EWMAPredictor(4).state_dict().items()}
    p4 = EWMAPredictor(4, decode_halflife=8.0)
    p4.load_state_dict(sd_plain)
    assert p4.decode_halflife == 8.0 and p4.decode_alpha > 0


def test_manager_decode_cadence_replans_from_decode_window():
    """A decode-skewed stream triggers a decode-cadence replan planned
    from the decode window, off the prefill cadence."""
    pcfg = PlacementConfig(replan_every=1000, warmup_iters=1, min_gain=0.0,
                           decode_halflife=2.0, decode_replan_every=3)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=1)
    mgr.observe(_skew_stats([FLAT]))                    # flat prefill
    assert mgr.maybe_replan(7) is None                  # no decode obs yet
    for _ in range(3):
        mgr.observe(_skew_stats([SKEW]), decode=True)
    plan = mgr.maybe_replan(9)                          # off prefill cadence
    assert plan is not None and plan.n_moved > 0
    assert mgr._decode_since_replan == 0                # counter reset
    mgr.commit(plan)
    # a decode cadence point whose plan is REJECTED (no gain: the decode
    # skew is already balanced) must also consume the window — otherwise
    # the full planner would re-run on every subsequent iteration
    for _ in range(3):
        mgr.observe(_skew_stats([SKEW]), decode=True)
    assert mgr.maybe_replan(11) is None                 # already balanced
    assert mgr._decode_since_replan == 0                # window consumed
    assert mgr._cadence(12) is None                     # quiet until due
    # decode cadence WITHOUT a decode window (decode_halflife=0): still
    # fires, planning from the shared window (predict's fallback) —
    # never a silently dead configuration
    pcfg2 = PlacementConfig(replan_every=1000, warmup_iters=1,
                            min_gain=0.0, decode_replan_every=2)
    m2 = PlacementManager.from_geometry(8, pcfg2, 4, bytes_per_expert=1)
    for _ in range(2):
        m2.observe(_skew_stats([SKEW]), decode=True)
    assert m2.predictor.n_obs_decode == 2
    assert m2.maybe_replan(5) is not None
    # and the plan balanced the DECODE skew, not the flat prefill view
    load = np.asarray(SKEW)
    ident = PlacementTable.identity(8, 4)
    assert mgr.table.rank_loads(load).max() < \
        ident.rank_loads(load).max()


# --------------------------------------------------------------------------
# replica-aware capacity
# --------------------------------------------------------------------------
def test_replica_capacity_factor_shrinks_with_split():
    load = np.array([40.0, 1, 1, 1, 1, 1, 1, 1])
    ident = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=4)
    rs = plan_replication(load, 4, 3, max_replicas=4)
    f_ident = ident.capacity_factor(load, margin=1.25)
    f_split = rs.capacity_factor(load, margin=1.25)
    assert f_split < f_ident                    # buffer shrinks
    # the reduced cap still fits the post-split peak rank load: the
    # per-rank buffer holds tot/ep * factor entries
    tot = load.sum()
    assert rs.rank_loads(load).max() <= tot / 4 * f_split
    # ... while the bijective peak would overflow it
    assert ident.rank_loads(load).max() > tot / 4 * f_split
    assert ident.capacity_factor(np.zeros(8)) == 1.0    # floor


def test_replica_manager_capacity_factor_tracks_post_split_loads():
    """The manager derives the effective dispatch factor from its
    predicted post-split loads: identity sets price the bijective peak,
    committed replication prices the flattened one (per-layer managers
    take the worst layer).  The real-dispatch no-drop check at the
    reduced cap runs on the (2,4) mesh (``replica_capacity_reduced_cap``
    in tests/_dist_worker.py)."""
    rp = ReplicationConfig(replan_every=1, warmup_iters=1, min_gain=0.0,
                           max_replicas=4, spare_per_rank=2)
    mgr = ReplicaManager.from_geometry(8, rp, 4)
    # no observation = no evidence to shrink on: +inf (engine clamps to
    # its static provision), NOT the most aggressive floor
    assert mgr.capacity_factor(margin=1.25) == float("inf")
    hot = [40.0, 1, 1, 1, 1, 1, 1, 1]
    mgr.observe(_skew_stats([hot]))
    f_before = mgr.capacity_factor(margin=1.25)
    plan = mgr.maybe_replan(1)
    assert plan is not None
    mgr.commit(plan)
    f_after = mgr.capacity_factor(margin=1.25)
    assert f_after < f_before                           # buffer shrinks
    # ... and still covers the post-split peak rank load with margin
    load = np.asarray(hot)
    assert mgr.rset.rank_loads(load).max() <= \
        load.sum() / 4 * f_after
    # per-layer manager: the worst layer prices the buffer
    rp_pl = ReplicationConfig(replan_every=1, warmup_iters=1,
                              min_gain=0.0, max_replicas=4,
                              spare_per_rank=2, per_layer=True)
    mpl = ReplicaManager.from_geometry(8, rp_pl, 4, n_layers=2)
    mpl.observe(_skew_stats([hot, FLAT]))
    plan = mpl.maybe_replan(1)
    mpl.commit(plan)
    f_pl = mpl.capacity_factor(margin=1.25)
    assert f_pl >= mpl.rsets[0].capacity_factor(
        mpl.predictor.predict_layers()[0][0], 1.25)
    # decode-regime drift the (frozen) main window cannot see must still
    # re-grow the buffer: the worst prediction window prices it
    rp_dec = ReplicationConfig(replan_every=1, warmup_iters=1,
                               min_gain=0.0, max_replicas=4,
                               spare_per_rank=2, decode_halflife=2.0)
    md = ReplicaManager.from_geometry(8, rp_dec, 4)
    md.observe(_skew_stats([FLAT]))                     # flat prefill view
    f_flat = md.capacity_factor(margin=1.25)
    for _ in range(3):                                  # decode goes hot
        md.observe(_skew_stats([hot]), decode=True)
    assert md.capacity_factor(margin=1.25) > f_flat


# --------------------------------------------------------------------------
# calibrated replan cost gate
# --------------------------------------------------------------------------
def test_calibrated_cost_gate_tracks_iteration_history():
    from benchmarks import costmodel as cm
    g = cm.KIMI_VL
    gate = cm.CalibratedReplanCostGate(g, 8, horizon_iters=100,
                                       default_tokens=4096.0, window=8)
    assert gate.tokens_per_iter == 4096.0       # pre-calibration fallback
    skew = np.array([8.0, 1, 1, 1, 1, 1, 1, 1])
    flat = np.full(8, skew.sum() / 8)
    assert gate.accept(skew, flat, 4)           # big batches: worth it
    # a synthetic history of tiny decode iterations: savings shrink with
    # tokens/iter, so the same plan stops amortizing
    for i in range(16):
        gate.observe_iter(4.0, t_wall=0.1 * i)
    assert gate.tokens_per_iter == 4.0          # window mean (last 8)
    assert gate.tokens_per_s > 0
    assert not gate.accept(skew, flat, 4)
    # back to large measured batches: accepts again
    for i in range(16):
        gate.observe_iter(8192.0, t_wall=2.0 + 0.1 * i)
    assert gate.accept(skew, flat, 4)
    # per-layer plans route through the same calibrated constant
    assert gate.accept_layers(np.stack([skew] * 4), np.stack([flat] * 4),
                              4)


def test_perlayer_gate_charges_per_layer_transfer_cost():
    """A single skewed layer: diluted into the 47-layer aggregate, the
    shared-table gate sees savings too small to pay for whole-stack
    slabs; the per-layer gate sees the full layer-0 saving against only
    that layer's slab cost — accept_layers charges changed layers only."""
    from benchmarks import costmodel as cm
    g = cm.KIMI_VL
    gate = cm.ReplanCostGate(g, 8, horizon_iters=4, tokens_per_iter=4096.0)
    skew = np.array([8.0, 1, 1, 1, 1, 1, 1, 1])
    flat = np.full(8, skew.sum() / 8)
    # shared view: the one skewed layer vanishes into the depth average,
    # but a shared-table migration still ships every layer's slabs
    agg_old = (skew + 46 * flat) / 47
    assert not gate.accept(agg_old, flat, 8)
    # per-layer view: same physical situation, 8 (expert, layer) pairs in
    # the one changed layer — full saving, 1/47th of the bytes
    old = np.tile(flat, (47, 1))
    new = old.copy()
    old[0] = skew
    assert gate.accept_layers(old, new, 8)
    assert not gate.accept_layers(old, old, 8)  # no savings -> reject
    assert gate.accept_layers(old, new, 0)      # free moves always ok
    assert cm.migration_bytes_layers(8, g, 47) < cm.migration_bytes(8, g)


# --------------------------------------------------------------------------
# per-layer beats shared on depth-varying skew (cost-model acceptance)
# --------------------------------------------------------------------------
def test_perlayer_planning_beats_shared_on_depth_varying_trace():
    from benchmarks import costmodel as cm
    from benchmarks import traces as tr
    cfg = tr.TraceConfig(name="depth", iters=240, jump_every=80,
                         zipf_a=1.3, vision_frac_mean=0.7, seed=5)
    g = cm.KIMI_VL
    shared = cm.sim_placement_layers(cfg, g, n_layers=4, per_layer=False)
    perlay = cm.sim_placement_layers(cfg, g, n_layers=4, per_layer=True)
    ib_s = float(np.mean(shared.extra["ib_global"]))
    ib_p = float(np.mean(perlay.extra["ib_global"]))
    assert ib_p < ib_s, (ib_p, ib_s)            # strictly lower peak IB
    rs = cm.sim_replication_layers(cfg, g, n_layers=4, per_layer=False)
    rp = cm.sim_replication_layers(cfg, g, n_layers=4, per_layer=True)
    assert float(np.mean(rp.extra["ib_global"])) < \
        float(np.mean(rs.extra["ib_global"]))


# --------------------------------------------------------------------------
# engine end-to-end (slow)
# --------------------------------------------------------------------------
def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


def _bias_routers_by_depth(params, biases):
    """biases: [n_blocks, E] logit offsets — depth-varying router skew."""
    out = dict(params)
    blocks = dict(out["blocks"])
    lp = dict(blocks["layer0"])
    moe = dict(lp["moe"])
    moe["router"] = moe["router"] + jnp.asarray(biases)[:, None, :]
    lp["moe"] = moe
    blocks["layer0"] = lp
    out["blocks"] = blocks
    return out


@pytest.mark.slow
def test_engine_perlayer_identity_matches_baseline(model):
    """A per-layer identity-planner engine generates exactly what a
    manager-free engine does — the n_blocks-stacked degenerate case."""
    from repro.serving.engine import Engine
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)
    eng0 = Engine(cfg, params, rcfg, max_slots=3, max_len=32, virtual_ep=4)
    for r in _reqs(cfg):
        eng0.submit(r)
    g0 = [r.generated for r in sorted(eng0.run(), key=lambda r: r.uid)]
    mgr = PlacementManager(cfg, PlacementConfig(planner="identity",
                                                per_layer=True), 4)
    assert mgr.n_tables == tf.block_structure(cfg)[1] == 2
    eng1 = Engine(cfg, params, rcfg, max_slots=3, max_len=32, placement=mgr)
    for r in _reqs(cfg):
        eng1.submit(r)
    g1 = [r.generated for r in sorted(eng1.run(), key=lambda r: r.uid)]
    assert g0 == g1
    assert mgr.n_migrations == 0


@pytest.mark.slow
def test_engine_perlayer_beats_shared_on_depth_antisymmetric_skew(model):
    """Depth-antisymmetric router skew (layer 0 and layer 1 hot on
    complementary experts, so the depth-summed load is near-uniform):
    the shared planner sees nothing to fix while per-layer planning
    flattens each layer — strictly lower prefill IB, and migration
    traffic only for the layers that changed."""
    from repro.serving.engine import Engine
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)
    b0 = np.array([3.0, 2.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0])
    params = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))

    def run(per_layer):
        mgr = PlacementManager(cfg, PlacementConfig(
            planner="least_loaded", replan_every=3, warmup_iters=2,
            min_gain=0.02, per_layer=per_layer), 4)
        eng = Engine(cfg, params, rcfg, max_slots=4, max_len=32,
                     placement=mgr, virtual_ep=4)
        for r in _reqs(cfg, n=16, seed=3):
            eng.submit(r)
        assert len(eng.run()) == 16
        pre = [s.ib_global for s in eng.stats if s.phase == "prefill"]
        return float(np.mean(pre)), mgr

    ib_shared, mgr_s = run(False)
    ib_perlayer, mgr_p = run(True)
    assert mgr_p.n_migrations >= 1
    assert ib_perlayer < ib_shared, (ib_perlayer, ib_shared)
    # layer-diff accounting: bytes land on the layers that moved
    assert mgr_p.migrated_bytes == mgr_p.migrated_bytes_per_layer.sum()


@pytest.mark.slow
def test_engine_perlayer_replication_checkpoint_roundtrip(model):
    """Per-layer replica engine: live replans, checkpoint resume with the
    exact per-layer sets, refusal by shared-table and manager-free
    readers."""
    from repro.serving.engine import Engine
    cfg, params = model
    b0 = np.array([3.0, 2.0, 0, 0, 0, 0, 0, 0])
    params_b = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))
    rcfg = ReaLBConfig(gate_gamma=4)
    mgr = ReplicaManager(cfg, ReplicationConfig(
        replan_every=3, warmup_iters=2, min_gain=0.0, per_layer=True), 4)
    assert mgr.n_tables == 2
    eng = Engine(cfg, expand_moe_params(params_b, mgr.rsets), rcfg,
                 max_slots=3, max_len=32, placement=mgr)
    for r in _reqs(cfg, n=10):
        eng.submit(r)
    eng.run()
    assert mgr.n_migrations >= 1

    with tempfile.TemporaryDirectory() as d:
        eng.save_checkpoint(d, 5)
        mgr2 = ReplicaManager(cfg, ReplicationConfig(per_layer=True), 4)
        eng2 = Engine(cfg, expand_moe_params(params_b, mgr2.rsets), rcfg,
                      max_slots=3, max_len=32, placement=mgr2)
        assert eng2.load_checkpoint(d) == 5
        for a, b in zip(mgr2.rsets, mgr.rsets):
            np.testing.assert_array_equal(a.rep_pos, b.rep_pos)
            np.testing.assert_array_equal(a.n_rep, b.n_rep)
        np.testing.assert_array_equal(mgr2.migrated_bytes_per_layer,
                                      mgr.migrated_bytes_per_layer)
        w0 = np.asarray(eng.params["blocks"]["layer0"]["moe"]["w_gate"])
        w1 = np.asarray(eng2.params["blocks"]["layer0"]["moe"]["w_gate"])
        assert np.array_equal(w0, w1)
        # a shared-table replica engine refuses the per-layer checkpoint
        mgr3 = ReplicaManager(cfg, ReplicationConfig(), 4)
        eng3 = Engine(cfg, expand_moe_params(params_b, mgr3.rsets), rcfg,
                      max_slots=3, max_len=32, placement=mgr3)
        with pytest.raises(ValueError, match="replica set"):
            eng3.load_checkpoint(d)
        # ... and a manager-free engine refuses it entirely
        eng4 = Engine(cfg, params_b, rcfg, max_slots=3, max_len=32)
        with pytest.raises(ValueError, match="replication"):
            eng4.load_checkpoint(d)
