"""Multi-device correctness, each check in a subprocess with 8 fake CPU
devices (jax locks the device count at first init, so the main pytest
process must stay single-device for the smoke tests)."""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow    # each check compiles a mesh subprocess

WORKER = pathlib.Path(__file__).parent / "_dist_worker.py"

CHECKS = [
    "ep_dispatch_matches_local",
    "ep_broadcast_matches_local",
    "realb_fp4_rank_activates",
    "chunk_padding_isolated_under_ep",
    "placement_identity_bitwise_under_ep",
    "placement_permuted_matches_local_under_ep",
    "virtual_ep_policy_parity",
    "replication_identity_bitwise_under_ep",
    "replication_split_under_ep",
    "perlayer_identity_bitwise_under_ep",
    "perlayer_tables_matches_local_under_ep",
    "async_migrate_chunks_match_sync_under_ep",
    "replica_capacity_reduced_cap",
    "model_train_step_under_mesh",
    "decode_under_mesh",
    "elastic_reshard",
    "weighted_split_under_ep",
    "elastic_kill_rejoin_under_ep",
    "kernel_fp4_parity_under_ep",
    "collective_census_reconciles",
]


@pytest.mark.parametrize("check", CHECKS)
def test_distributed(check):
    r = subprocess.run([sys.executable, str(WORKER), check],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"{check}\n--- stdout ---\n{r.stdout}" \
                              f"\n--- stderr ---\n{r.stderr[-3000:]}"
