"""Fault-tolerance loop: checkpointing cadence, NaN guard + rollback,
restart resume."""
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.runtime.fault_tolerance import TrainLoop


def _mk_step(poison_at=None):
    def step_fn(state, batch):
        s = state["x"]
        loss = float(jnp.sum(s)) * 0 + float(batch["v"])
        if poison_at is not None and batch["step"] == poison_at:
            loss = float("nan")
        return {"x": s + 1}, {"loss": loss}
    return step_fn


def _data(n):
    for i in range(n):
        yield {"v": 1.0 + 0.01 * i, "step": i}


def test_loop_checkpoints_and_finishes():
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(_mk_step(), ckpt_dir=d, checkpoint_every=5,
                         log_every=1000, logger=lambda *_: None)
        state = loop.run({"x": jnp.zeros(3)}, iter(_data(100)), 12)
        assert float(state["x"][0]) == 12
        assert ckpt.latest_step(d) == 12


def test_nan_guard_skips_poisoned_update():
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(_mk_step(poison_at=4), ckpt_dir=d,
                         checkpoint_every=100, nan_tolerance=10,
                         log_every=1000, logger=lambda *_: None)
        # data yields step ids 0..; step 4 poisons once, then is consumed
        state = loop.run({"x": jnp.zeros(1)}, iter(_data(100)), 8)
        # 8 good updates happened; the poisoned batch didn't update
        assert float(state["x"][0]) == 8


def test_restart_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(_mk_step(), ckpt_dir=d, checkpoint_every=5,
                         log_every=1000, logger=lambda *_: None)
        loop.run({"x": jnp.zeros(1)}, iter(_data(100)), 10)
        # "crash" and restart from disk
        loop2 = TrainLoop(_mk_step(), ckpt_dir=d, checkpoint_every=5,
                          log_every=1000, logger=lambda *_: None)
        start, state = loop2.restore_or_init({"x": jnp.zeros(1)})
        assert start == 10
        state = loop2.run(state, iter(_data(100)), 15, start_step=start)
        assert float(state["x"][0]) == 15
