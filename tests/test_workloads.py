"""repro.workloads: arrival determinism, stream synthesis, replay,
telemetry percentile math, modality-aware admission."""
import numpy as np
import pytest

from repro.serving.scheduler import Request, Scheduler
from repro.serving.telemetry import Telemetry, percentile, summarize
from repro.workloads import (ArrivalConfig, ClosedLoop, IterationCostModel,
                             VirtualClock, arrival_times, load_stream,
                             make_stream, profile, save_stream,
                             stream_stats)
from repro.workloads.profiles import WORKLOADS

OPEN_KINDS = ("poisson", "bursty", "diurnal")


# -- arrivals ---------------------------------------------------------------
@pytest.mark.parametrize("kind", OPEN_KINDS)
def test_arrivals_deterministic(kind):
    cfg = ArrivalConfig(kind=kind, n_requests=64, rate=10.0, seed=7)
    a, b = arrival_times(cfg), arrival_times(cfg)
    np.testing.assert_array_equal(a, b)
    c = arrival_times(ArrivalConfig(kind=kind, n_requests=64, rate=10.0,
                                    seed=8))
    assert not np.array_equal(a, c)
    assert len(a) == 64
    assert np.all(np.diff(a) >= 0) and np.all(a > 0)


def test_arrivals_rate_calibration():
    # long poisson stream: realized rate within 20% of nominal
    cfg = ArrivalConfig(kind="poisson", n_requests=2000, rate=10.0, seed=0)
    t = arrival_times(cfg)
    assert abs(len(t) / t[-1] - 10.0) < 2.0


def test_bursty_is_burstier_than_poisson():
    # squared coefficient of variation of inter-arrival gaps: ~1 for
    # poisson, > 1 for the MMPP (deterministic given the fixed seeds)
    n = 2000
    tp = arrival_times(ArrivalConfig(kind="poisson", n_requests=n, seed=1))
    tb = arrival_times(ArrivalConfig(kind="bursty", n_requests=n, seed=1))
    cv2 = lambda t: float(np.var(np.diff(t)) / np.mean(np.diff(t)) ** 2)
    assert cv2(tb) > 1.5 * cv2(tp)


def test_closed_loop_feedback():
    cfg = ArrivalConfig(kind="closed", n_requests=10, concurrency=4, seed=0)
    first = arrival_times(cfg)
    assert len(first) == 4 and np.all(first == 0.0)
    loop = ClosedLoop(cfg)
    times = []
    t = 1.0
    while True:
        nxt = loop.next_arrival(t)
        if nxt is None:
            break
        assert nxt >= t
        times.append(nxt)
        t = nxt + 0.5
    assert len(times) == 6            # 10 total - 4 initial


def test_virtual_clock_and_cost_model():
    clk = VirtualClock()
    assert clk() == 0.0
    cm = IterationCostModel(fixed=1e-3, per_token=1e-5)
    clk.advance(cm.cost(1000))
    assert clk() == pytest.approx(1e-3 + 1e-2)


# -- multimodal synthesis ---------------------------------------------------
def test_stream_deterministic_and_calibrated():
    arr = arrival_times(ArrivalConfig(kind="poisson", n_requests=60, seed=2))
    s1 = make_stream(profile("MMMU"), arr, 512, seed=5)
    s2 = make_stream(profile("MMMU"), arr, 512, seed=5)
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.modality, b.modality)
        assert a.arrival == b.arrival
    # MMMU is vision-heavy, TextVQA is not: the shared calibration shows
    st_m = stream_stats(s1)
    st_t = stream_stats(make_stream(profile("TextVQA"), arr, 512, seed=5))
    assert st_m["mean_vision_frac"] > st_t["mean_vision_frac"]
    # vision tokens live in the upper half of the vocab
    for s in s1[:8]:
        if s.modality.any():
            assert np.all(s.tokens[s.modality] >= 256)
        assert np.all(s.tokens[~s.modality] < 256)


def test_profile_shares_trace_calibration():
    p = profile("DynaMath")
    assert p.vision_frac_mean == WORKLOADS["DynaMath"]["vision_frac_mean"]
    assert p.vision_frac_std == WORKLOADS["DynaMath"]["vision_frac_std"]


def test_prompt_length_bounds():
    arr = np.zeros(100)
    specs = make_stream(profile("MMMU"), arr, 512, seed=0, max_prompt=64)
    for s in specs:
        assert 16 <= len(s.tokens) <= 64
        assert len(s.modality) == len(s.tokens)


# -- replay -----------------------------------------------------------------
def test_replay_roundtrip_exact(tmp_path):
    arr = arrival_times(ArrivalConfig(kind="bursty", n_requests=20, seed=3))
    specs = make_stream(profile("InfoVQA"), arr, 1024, seed=9,
                        with_embeds=True)
    path = tmp_path / "stream.jsonl"
    save_stream(path, specs, meta={"workload": "InfoVQA", "seed": 9})
    meta, back = load_stream(path)
    assert meta == {"workload": "InfoVQA", "seed": 9}
    assert len(back) == len(specs)
    for a, b in zip(specs, back):
        assert a.uid == b.uid and a.arrival == b.arrival
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.modality, b.modality)
        assert a.max_new_tokens == b.max_new_tokens
        assert a.decode_modality == b.decode_modality
        assert a.embed_seed == b.embed_seed
    # embeds regenerate identically from the recorded seed
    ra = specs[0].to_request(d_model=16)
    rb = back[0].to_request(d_model=16)
    if ra.vision_embeds is not None:
        np.testing.assert_array_equal(ra.vision_embeds, rb.vision_embeds)


def test_replay_rejects_foreign_file(tmp_path):
    p = tmp_path / "bogus.jsonl"
    p.write_text('{"something": "else"}\n')
    with pytest.raises(ValueError):
        load_stream(p)


# -- telemetry percentile math ----------------------------------------------
def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 101):
        xs = rng.exponential(1.0, n).tolist()
        for q in (0, 25, 50, 90, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)), rel=1e-12)


def test_percentile_edge_cases():
    assert percentile([5.0], 99) == 5.0
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)
    s = summarize([])
    assert s == {}
    s = summarize([1.0, 2.0, 3.0])
    assert s["p50"] == 2.0 and s["mean"] == pytest.approx(2.0)


def test_telemetry_window_and_duty():
    from repro.serving.engine import IterStats
    t = Telemetry(window=10)
    for i in range(25):
        t.record_iter(IterStats(n_active=1, tokens=1, ib_global=float(i),
                                fp4_ranks=0.0,
                                gate_open=1.0 if i % 2 == 0 else 0.0,
                                phase="prefill" if i % 2 == 0 else "decode"))
    assert t.n_iters == 25 and len(t.iters) == 10   # rolling window
    assert t.gate_duty("prefill") == 1.0
    assert t.gate_duty("decode") == 0.0
    assert t.gate_duty(None) == 0.5
    # ib summary over the window only (last 10 records: 15..24)
    assert t.ib_summary(None)["p50"] == pytest.approx(19.5)


def test_telemetry_request_latencies():
    t = Telemetry()
    r = Request(uid=0, tokens=np.zeros(4, np.int32),
                modality=np.zeros(4, bool), max_new_tokens=3,
                arrival_time=1.0)
    r.generated = [1, 2, 3]
    r.first_token_time = 1.5
    r.finish_time = 2.5
    t.record_request(r)
    assert t.ttft_summary()["p50"] == pytest.approx(0.5)
    assert t.tpot_summary()["p50"] == pytest.approx(0.5)
    # unfinished request (no first token) is ignored, not crashed on
    t.record_request(Request(uid=1, tokens=np.zeros(4, np.int32),
                             modality=np.zeros(4, bool)))
    assert t.n_requests == 1


# -- modality-aware admission ----------------------------------------------
def _req(uid, vis, p_len=8):
    mod = np.full(p_len, bool(vis))
    return Request(uid=uid, tokens=np.zeros(p_len, np.int32), modality=mod)


def test_admission_text_jumps_vision_burst():
    s = Scheduler(4, text_reserve=1)
    for i in range(6):
        s.submit(_req(i, vis=True))
    s.submit(_req(100, vis=False))     # one text request behind the burst
    admitted = s.admit()
    # vision may take at most 3 of 4 slots while text waits: the text
    # request jumps the queue into the reserved slot
    assert [r.uid for r in admitted] == [0, 1, 2, 100]
    assert sum(r.is_vision for r in s.active.values()) == 3


def test_admission_work_conserving_without_text():
    s = Scheduler(4, text_reserve=1)
    for i in range(6):
        s.submit(_req(i, vis=True))
    admitted = s.admit()               # no text queued: fill all slots
    assert [r.uid for r in admitted] == [0, 1, 2, 3]


def test_admission_fifo_when_reserve_disabled():
    s = Scheduler(2, text_reserve=0)
    s.submit(_req(0, vis=True))
    s.submit(_req(1, vis=True))
    s.submit(_req(2, vis=False))
    assert [r.uid for r in s.admit()] == [0, 1]
