"""Hot-loop profiler (repro.obs.ledger / repro.obs.profiler): FLOP/byte
ledger math vs hand counts (BF16 + FP4 arms), costmodel formula pinning,
instrumented-prefix bitwise parity with the fused MoE layer, disabled-
profiler engine parity, drift/reconciliation under the virtual clock,
cost-gate time_scale wiring, and profile_report exit codes."""
import json

import numpy as np
import pytest

from repro.configs import (PlacementConfig, ReaLBConfig, get_config,
                           reduced)
from repro.configs.hw import HBM_BW, PEAK_BF16, PEAK_INT8
from repro.obs import (MOE_STAGES, NULL_PROFILER, PHASES, FlopByteLedger,
                       MetricsRegistry, Profiler, time_moe_phases)
from repro.obs.ledger import BYTES_BF16, BYTES_FP4, FIXED_US
from repro.serving.telemetry import Telemetry

EP = 4


@pytest.fixture(scope="module")
def lcfg():
    return reduced(get_config("olmoe-1b-7b"), n_layers=2)


def _stats(loads):
    """[L, 2, ep] moe_stats with the given [L, ep] routed loads."""
    loads = np.asarray(loads, np.float64)
    ms = np.zeros((loads.shape[0], 2, loads.shape[1]))
    ms[:, 0] = loads
    ms[:, 1] = loads * 0.5
    return ms


# --------------------------------------------------------------------------
# ledger vs hand counts
# --------------------------------------------------------------------------
def test_ledger_bf16_hand_counts(lcfg):
    led = FlopByteLedger(lcfg, ep=EP)
    loads = np.array([[6.0, 2.0, 1.0, 1.0], [2.5, 2.5, 2.5, 2.5]])
    tokens, batch = 10.0, 16.0
    it = led.account(_stats(loads), fp4_layers=0.0, tokens=tokens,
                     batch_tokens=batch)
    d, dff, E, k = led.d, led.d_ff, led.n_experts, led.top_k
    gemm_per_tok = 2.0 * led.mult * d * dff
    w_slab = led.e_loc * led.mult * d * dff
    L = loads.shape[0]

    assert it.flops["route"] == pytest.approx(L * tokens * d * E * 2.0)
    assert it.flops["expert_gemm"] == pytest.approx(
        loads.sum() * gemm_per_tok)
    assert it.flops_by_rate["int8"] == 0.0
    assert it.flops_by_rate["bf16"] == pytest.approx(
        loads.sum() * gemm_per_tok)
    # every rank streams its BF16 slab + its routed activations
    assert it.hbm_bytes["expert_gemm"] == pytest.approx(
        L * EP * w_slab * BYTES_BF16
        + loads.sum() * d * BYTES_BF16 * 4.0)
    assert it.hbm_bytes["quantize_fp4"] == 0.0
    assert it.pred_s["quantize_fp4"] == 0.0
    a2a = tokens * k / EP * (EP - 1) / EP * d * BYTES_BF16 * EP
    assert it.ici_bytes["dispatch"] == pytest.approx(L * a2a)
    assert it.ici_bytes["combine"] == pytest.approx(L * a2a)
    # MFU numerator: useful work at real (non-padded) tokens
    assert it.model_flops == pytest.approx(
        2.0 * lcfg.active_param_count() * tokens)
    assert it.tokens == tokens and it.batch_tokens == batch
    # exhaustive phase vocabulary, plain-float JSON-serializable
    assert set(it.pred_s) == set(PHASES) == set(it.flops)
    json.dumps([it.flops, it.hbm_bytes, it.ici_bytes, it.pred_s,
                it.flops_by_rate])
    # expert-GEMM predicted time is the straggler rank at BF16 rates
    worst = max(loads[l].max() for l in range(L))
    t_straggler = max(
        worst * gemm_per_tok / PEAK_BF16,
        (w_slab * BYTES_BF16 + worst * d * BYTES_BF16 * 4.0) / HBM_BW)
    assert it.pred_s["expert_gemm"] >= t_straggler - 1e-12


def test_ledger_fp4_hot_rank_attribution(lcfg):
    """fp4_layers=k attributes FP4 (int8-rate flops, 4.25-bit slabs,
    quantize traffic) to the k most-loaded ranks of each layer.  Runs
    fused (the kernel-wired hot loop): packed slabs stream with no BF16
    round-trip and the transformation hides inside the dispatch window."""
    from repro.configs.base import MIGRATION_BW_DEFAULT
    led = FlopByteLedger(lcfg, ep=EP, fused=True)
    loads = np.array([[6.0, 2.0, 1.0, 1.0]])
    it = led.account(_stats(loads), fp4_layers=1.0, tokens=10.0,
                     batch_tokens=16.0)
    gemm_per_tok = 2.0 * led.mult * led.d * led.d_ff
    w_slab = led.e_loc * led.mult * led.d * led.d_ff
    assert it.flops_by_rate["int8"] == pytest.approx(6.0 * gemm_per_tok)
    assert it.flops_by_rate["bf16"] == pytest.approx(4.0 * gemm_per_tok)
    # BF16-read + packed-write traffic is real either way; fusion only
    # changes the *visible seconds* (excess over the dispatch window)
    q_bytes = w_slab * (BYTES_BF16 + BYTES_FP4)
    assert it.hbm_bytes["quantize_fp4"] == pytest.approx(q_bytes)
    disp = led._dispatch_s(10.0 * led.top_k, MIGRATION_BW_DEFAULT)
    assert it.pred_s["quantize_fp4"] == pytest.approx(
        max(0.0, q_bytes / HBM_BW - disp))
    # the hot rank streams the packed slab, the cold ranks BF16
    assert it.hbm_bytes["expert_gemm"] == pytest.approx(
        3 * w_slab * BYTES_BF16 + w_slab * BYTES_FP4
        + loads.sum() * led.d * BYTES_BF16 * 4.0)
    # int8 MXU rate on the hot rank: all-FP4 predicted gemm is faster
    it_all = led.account(_stats(loads), fp4_layers=EP, tokens=10.0,
                         batch_tokens=16.0)
    assert it_all.flops_by_rate["bf16"] == 0.0
    assert it_all.pred_s["expert_gemm"] <= it.pred_s["expert_gemm"]
    assert PEAK_INT8 > PEAK_BF16


def test_ledger_unfused_charges_dequant_round_trip(lcfg):
    """fused=False (the jnp fallback): every FP4 rank pays the dequantized
    BF16 slab round-trip on expert_gemm, and the transformation is a fully
    visible standalone stage (bytes + per-stage launch overhead)."""
    loads = np.array([[6.0, 2.0, 1.0, 1.0]])
    kw = dict(fp4_layers=1.0, tokens=10.0, batch_tokens=16.0)
    led_f = FlopByteLedger(lcfg, ep=EP, fused=True)
    led_u = FlopByteLedger(lcfg, ep=EP)      # fused defaults to False
    assert led_f.fused and not led_u.fused
    it_f = led_f.account(_stats(loads), **kw)
    it_u = led_u.account(_stats(loads), **kw)
    w_slab = led_u.e_loc * led_u.mult * led_u.d * led_u.d_ff
    # exactly one FP4 rank -> exactly one slab's write+read round-trip
    assert (it_u.hbm_bytes["expert_gemm"] - it_f.hbm_bytes["expert_gemm"]
            ) == pytest.approx(w_slab * 2.0 * BYTES_BF16)
    assert it_u.pred_s["quantize_fp4"] == pytest.approx(
        led_u._quantize_s() + FIXED_US * 1e-6)
    assert it_u.pred_s["quantize_fp4"] > it_f.pred_s["quantize_fp4"]
    # the quantize traffic itself is identical — only visibility differs
    assert it_u.hbm_bytes["quantize_fp4"] == pytest.approx(
        it_f.hbm_bytes["quantize_fp4"])
    assert it_u.flops == it_f.flops and it_u.flops_by_rate == it_f.flops_by_rate


def test_ledger_mirrors_costmodel_formulas(lcfg):
    """The ledger's private per-phase predictors are formula-for-formula
    the benchmarks/costmodel.py public ones (same single-sourced hw
    constants) — the invariant that makes costmodel drift meaningful."""
    from benchmarks import costmodel as cm
    assert FIXED_US == cm.FIXED_US
    assert BYTES_BF16 == cm.BYTES_BF16 and BYTES_FP4 == cm.BYTES_FP4
    n_moe = sum(1 for f in lcfg.ffn_kinds() if f == "moe")
    g = cm.MoEGeometry(lcfg.name, lcfg.d_model, lcfg.moe.d_ff,
                       lcfg.moe.num_experts, lcfg.moe.top_k, n_moe)
    led = FlopByteLedger(lcfg, ep=EP)
    assert led.mult == 3  # olmoe is swiglu; costmodel hardcodes 3.0
    for fused in (False, True):
        led_x = FlopByteLedger(lcfg, ep=EP, fused=fused)
        for t in (0.0, 7.0, 513.0):
            for fp4 in (False, True):
                assert led_x._expert_gemm_s(t, fp4) == pytest.approx(
                    cm.expert_gemm_time(t, g, EP, fp4, fused=fused))
        for disp in (0.0, 3e-6, 1e-3):
            assert led_x._quantize_visible_s(disp) == pytest.approx(
                cm.quantize_visible_time(g, EP, disp, fused=fused))
    for t in (0.0, 7.0, 513.0):
        assert led._dispatch_s(t, cm.ICI_BW) == pytest.approx(
            cm.dispatch_time(t, EP, g.d_model))
        assert led._nongemm_s(t) == pytest.approx(cm.nongemm_time(t, g))
    assert led._quantize_s() == pytest.approx(cm.quantize_time(g, EP))
    # costmodel default fused=True == what the kernel-wired hot loop runs
    assert cm.expert_gemm_time(7.0, g, EP, True) == pytest.approx(
        FlopByteLedger(lcfg, ep=EP, fused=True)._expert_gemm_s(7.0, True))


def test_hw_constants_single_sourced():
    """roofline + costmodel compute/HBM rates come from repro.configs.hw;
    ICI_BW deliberately stays MIGRATION_BW_DEFAULT in the costmodel."""
    from benchmarks import costmodel as cm
    from repro.configs import hw
    from repro.configs.base import MIGRATION_BW_DEFAULT
    from repro.launch import roofline
    assert roofline.PEAK_FLOPS is hw.PEAK_FLOPS is hw.PEAK_BF16
    assert roofline.HBM_BW is hw.HBM_BW
    assert cm.PEAK_BF16 is hw.PEAK_BF16 and cm.PEAK_INT8 is hw.PEAK_INT8
    assert cm.HBM_BW is hw.HBM_BW
    assert cm.ICI_BW == MIGRATION_BW_DEFAULT


# --------------------------------------------------------------------------
# profiler accounting: attribution, EWMA drift, registry gauges
# --------------------------------------------------------------------------
def _profiler(lcfg, registry=None):
    return Profiler(FlopByteLedger(lcfg, ep=EP), registry=registry)


def test_profiler_exhaustive_attribution_and_time_scale(lcfg):
    reg = MetricsRegistry()
    prof = _profiler(lcfg, registry=reg)
    ms = _stats([[6.0, 2.0, 1.0, 1.0], [2.5, 2.5, 2.5, 2.5]])
    led = prof.ledger.account(ms, 0.0, 10.0, 16.0)
    fwd = 2.0 * led.pred_total        # constant measured/predicted ratio
    for _ in range(4):
        prof.observe_iter(moe_stats=ms, fp4_layers=0.0, tokens=10.0,
                          batch_tokens=16.0, fwd_s=fwd)
    # exhaustive attribution: phases partition the measured seconds
    assert sum(prof.phase_seconds().values()) == pytest.approx(
        prof.fwd_s_total)
    # EWMA of a constant ratio is the ratio, and every phase drifts by it
    assert prof.time_scale() == pytest.approx(2.0)
    for ph, r in prof.drift().items():
        if prof.phase_seconds_pred()[ph] > 0:
            assert r == pytest.approx(2.0)
    assert prof.mfu() == pytest.approx(
        4 * led.model_flops / (prof.fwd_s_total * PEAK_BF16))
    assert 0.0 < prof.roofline_fraction() <= 1.0
    # the registry carries what Telemetry.summary() will surface
    assert reg.gauge("mfu").value() == pytest.approx(prof.mfu())
    assert reg.gauge("costmodel_time_scale").value() == pytest.approx(2.0)
    assert reg.counter("model_flops").total() == pytest.approx(
        prof.model_flops_total)
    assert reg.counter("phase_seconds", labels=("phase",)).total() \
        == pytest.approx(prof.fwd_s_total)
    assert reg.gauge("costmodel_drift", labels=("phase",)).value(
        phase="expert_gemm") == pytest.approx(2.0)
    args = prof.span_args()
    assert args["model_flops"] == pytest.approx(led.model_flops)


def test_profiler_measured_phase_override_rescales(lcfg):
    """An instrumented caller's per-phase seconds are rescaled to sum to
    fwd_s so the attribution invariant survives unoverlapped timings."""
    prof = _profiler(lcfg)
    ms = _stats([[4.0, 2.0, 1.0, 1.0]])
    prof.observe_iter(moe_stats=ms, fp4_layers=0.0, tokens=8.0,
                      batch_tokens=8.0, fwd_s=0.01,
                      measured_phases={"route": 3.0, "dispatch": 1.0})
    ps = prof.phase_seconds()
    assert ps["route"] == pytest.approx(0.0075)
    assert ps["dispatch"] == pytest.approx(0.0025)
    assert sum(ps.values()) == pytest.approx(0.01)


def test_null_profiler_is_inert_singleton():
    assert NULL_PROFILER.enabled is False
    NULL_PROFILER.observe_iter(moe_stats=None, fwd_s=-1.0)
    assert NULL_PROFILER.time_scale() == 1.0
    assert NULL_PROFILER.mfu() == 0.0
    assert NULL_PROFILER.span_args() == {}


# --------------------------------------------------------------------------
# cost-gate calibration: time_scale scales the savings side
# --------------------------------------------------------------------------
def test_cost_gate_time_scale_scales_layer_seconds(lcfg):
    from benchmarks import costmodel as cm
    g = cm.MoEGeometry(lcfg.name, lcfg.d_model, lcfg.moe.d_ff,
                       lcfg.moe.num_experts, lcfg.moe.top_k, 2)
    kw = dict(horizon_iters=8, tokens_per_iter=256.0)
    base = cm.ReplanCostGate(g, EP, **kw)
    loads = np.array([100.0, 50.0, 25.0, 25.0])
    t1 = base.layer_seconds(loads)
    assert t1 > 0
    assert cm.ReplanCostGate(g, EP, time_scale=2.0, **kw).layer_seconds(
        loads) == pytest.approx(2.0 * t1)
    # callables (the profiler's bound EWMA method) work the same way
    assert cm.ReplanCostGate(g, EP, time_scale=lambda: 3.0,
                             **kw).layer_seconds(loads) \
        == pytest.approx(3.0 * t1)
    # the calibrated gate forwards its wired time_scale to the inner gate
    cal = cm.CalibratedReplanCostGate(g, EP, horizon_iters=8,
                                      default_tokens=256.0)
    assert cal.time_scale is None
    cal.time_scale = 2.0
    assert cal.layer_seconds(loads) == pytest.approx(2.0 * t1)


# --------------------------------------------------------------------------
# instrumented execution mode: prefix timings, bitwise ≡ fused
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def moe_setup():
    import jax
    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.2,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
        "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F),
    }
    x = jax.random.normal(ks[4], (2, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (2, 16))
    return cfg, p, x, mod


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["dispatch", "broadcast"])
@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_instrumented_prefixes_bitwise_match_fused(moe_setup, mode, backend):
    """The final stop_stage prefix IS the fused layer: y / m_state are
    bitwise identical, and every stage gets a non-negative timing.  Runs
    once on the jnp fallback and once with the Pallas grouped FP4 FFN /
    quantize kernels wired in (interpret mode) — the stop_stage prefix
    machinery must stay bitwise-transparent either way."""
    import jax
    import jax.numpy as jnp

    from repro.core import ep_moe
    from repro.kernels import ops as kops
    cfg, p, x, mod = moe_setup
    # virtual 4-rank EP group (m_state trailing dim), gate_gamma=1 opens
    # the LB gate and m=0 drops the modality threshold so quantize_fp4
    # really runs on the hot ranks
    rcfg = ReaLBConfig(gate_gamma=1)
    m = jnp.zeros((1, EP))
    kops.set_ffn_backend(backend)
    try:
        seconds, out = time_moe_phases(p, x, cfg, rcfg, m, mode=mode,
                                       modality=mod, repeats=1, warmup=1)
        assert set(seconds) == set(MOE_STAGES[mode])
        assert all(v >= 0.0 for v in seconds.values())
        y, m2, aux = out

        fused = jax.jit(lambda p_, x_, m_: ep_moe.ep_moe_forward(
            p_, x_, cfg, rcfg, m_, mod, mode=mode))
        y_ref, m_ref, aux_ref = fused(p, x, m)
    finally:
        kops.set_ffn_backend(None)
    assert np.asarray(y).tobytes() == np.asarray(y_ref).tobytes()
    assert np.asarray(m2).tobytes() == np.asarray(m_ref).tobytes()
    assert set(aux) == set(aux_ref)
    for k2 in aux:
        np.testing.assert_array_equal(np.asarray(aux[k2]),
                                      np.asarray(aux_ref[k2]))
    assert float(aux["fp4_ranks"]) > 0    # the gate really opened


def test_stop_stage_returns_prefix_boundaries(moe_setup):
    """Early stops return raw boundary values (not the (y, m, aux)
    triple) so each prefix keeps its phase outputs live."""
    import jax.numpy as jnp

    from repro.core import ep_moe
    cfg, p, x, mod = moe_setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 1), 0.9)
    out = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode="dispatch",
                                stop_stage="route")
    assert isinstance(out, tuple) and len(out) == 5
    full = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode="dispatch",
                                 stop_stage=None)
    assert len(full) == 3 and full[0].shape == x.shape


# --------------------------------------------------------------------------
# engine end-to-end (slow): parity, gate wiring, reconciliation, report
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    import jax

    from repro.models import transformer as tf
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


def _engine(cfg, params, profiler=None, cost_gate=None):
    from repro.placement import PlacementManager
    from repro.serving.engine import Engine
    from repro.workloads import IterationCostModel, VirtualClock
    mgr = PlacementManager(cfg, PlacementConfig(
        planner="least_loaded", replan_every=3, warmup_iters=2,
        min_gain=0.0, per_layer=True), EP, cost_gate=cost_gate)
    tel = Telemetry()
    eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                 max_len=32, placement=mgr, telemetry=tel,
                 clock=VirtualClock(), cost_model=IterationCostModel(),
                 profiler=profiler)
    return eng, mgr, tel


@pytest.mark.slow
def test_engine_disabled_profiler_bitwise_parity(model):
    """An engine without a profiler produces bitwise-identical
    generations and identical plans/tables to one profiling every
    iteration (no cost gate wired, so nothing feeds back)."""
    cfg, params = model
    outs = []
    for profiled in (False, True):
        prof = Profiler(FlopByteLedger(cfg, ep=EP)) if profiled else None
        eng, mgr, tel = _engine(cfg, params, profiler=prof)
        assert (eng.profiler is NULL_PROFILER) == (not profiled)
        for r in _reqs(cfg, n=8, seed=5):
            eng.submit(r)
        eng.run()
        eng.drain_migrations()
        outs.append((
            {r.uid: list(r.generated) for r in eng.scheduler.finished},
            eng.migration_bytes_moved, mgr.n_migrations,
            [list(t.e2r) for t in mgr.tables],
        ))
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_engine_profiler_reconciles_and_reports(model, tmp_path):
    """Virtual-clock run: the profiler's attribution reconciles, the
    drift EWMA is live, Telemetry.summary() surfaces mfu /
    model_flops_total / phase seconds, the profile JSON round-trips
    through profile_report with exit 0, and an injected drift (tampered
    phase seconds) exits 2."""
    from benchmarks import profile_report
    cfg, params = model
    tel_reg = MetricsRegistry()
    # share one registry between Telemetry and Profiler, like serve_bench
    tel = Telemetry(registry=tel_reg)
    prof = Profiler(FlopByteLedger(cfg, ep=EP), registry=tel_reg)
    eng, mgr, _ = _engine(cfg, params, profiler=prof)
    eng.telemetry = tel
    for r in _reqs(cfg, n=8, seed=5):
        eng.submit(r)
    eng.run()
    assert prof.n_iters > 0 and prof.fwd_s_total > 0
    assert sum(prof.phase_seconds().values()) == pytest.approx(
        prof.fwd_s_total)
    assert prof.time_scale() > 0 and prof.mfu() > 0

    s = tel.summary()
    assert s["mfu"] == pytest.approx(prof.mfu())
    assert s["model_flops_total"] == pytest.approx(prof.model_flops_total)
    assert s["costmodel_time_scale"] == pytest.approx(prof.time_scale())
    assert set(s["phase_seconds"]) <= set(PHASES)
    assert sum(s["phase_seconds"].values()) == pytest.approx(
        prof.fwd_s_total)
    # legacy keys untouched
    assert "ttft" in s and "migration_bytes_total" in s
    # an unprofiled telemetry grows no new keys
    assert "mfu" not in Telemetry().summary()

    p = tmp_path / "profile.json"
    doc = prof.write(str(p), metadata={"arm": "test"})
    assert doc["schema"] == "repro.profile.v1"
    assert profile_report.report(str(p)) == 0

    # injected drift: break the attribution invariant -> exit 2
    doc = json.loads(p.read_text())
    doc["phases"]["route"]["measured_s"] += 0.5
    p2 = tmp_path / "drift.json"
    p2.write_text(json.dumps(doc))
    assert profile_report.report(str(p2)) == 2

    # schema violation -> exit 1
    doc["schema"] = "bogus"
    p3 = tmp_path / "bad.json"
    p3.write_text(json.dumps(doc))
    assert profile_report.report(str(p3)) == 1


@pytest.mark.slow
def test_engine_wires_profiler_time_scale_into_cost_gate(model):
    """Engine init auto-wires the profiler's drift EWMA into an unwired
    cost gate (same idiom as the managers' bandwidth wiring); a gate the
    caller already calibrated is left alone."""
    from benchmarks import costmodel as cm
    cfg, params = model
    g = cm.MoEGeometry(cfg.name, cfg.d_model, cfg.moe.d_ff,
                       cfg.moe.num_experts, cfg.moe.top_k, 2)
    gate = cm.ReplanCostGate(g, EP, horizon_iters=3,
                             tokens_per_iter=64.0)
    assert gate.time_scale is None
    prof = Profiler(FlopByteLedger(cfg, ep=EP))
    eng, mgr, tel = _engine(cfg, params, profiler=prof, cost_gate=gate)
    assert gate.time_scale == prof.time_scale     # bound EWMA method
    assert gate._time_scale() == 1.0              # no observations yet
    # pre-calibrated gates are not overwritten
    gate2 = cm.ReplanCostGate(g, EP, horizon_iters=3,
                              tokens_per_iter=64.0, time_scale=1.5)
    _engine(cfg, params, profiler=prof, cost_gate=gate2)
    assert gate2.time_scale == 1.5
    # no profiler -> gate untouched
    gate3 = cm.ReplanCostGate(g, EP, horizon_iters=3,
                              tokens_per_iter=64.0)
    _engine(cfg, params, profiler=None, cost_gate=gate3)
    assert gate3.time_scale is None
