"""Elastic serving: rank loss/rejoin as first-class serving events.

Host-side tests cover the ElasticCoordinator state machine, dead-rank-
masked planning, recovery-chunk priority, checkpoint re-materialization,
rejoin warm-up staged commit, the churn budget and weighted token
splitting; the slow engine tests drive the full event loop (fault
injection, degraded dispatch accounting, mid-recovery checkpoint
refusal) on a reduced model.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.checkpoint import ckpt
from repro.configs import ReaLBConfig, get_config, reduced
from repro.configs.base import ReplicationConfig
from repro.placement import PlacementManager
from repro.placement.migrate import MOE_WEIGHT_KEYS
from repro.replication import (ReplicaManager, ReplicaSet,
                               expand_moe_params, plan_replication)
from repro.runtime.fault_tolerance import FaultEvent, FaultInjector
from repro.serving.async_migrate import MigrationExecutor
from repro.serving.elastic import (STATE_DEGRADED, STATE_HEALTHY,
                                   STATE_SHRUNK, STATE_WARMING,
                                   ElasticCoordinator, zero_rank_slabs)
from repro.serving.telemetry import Telemetry

E, EP, SPR = 8, 4, 3          # 8 experts over 4 ranks, 1 spare slot each


def _rpcfg(**kw):
    base = dict(enabled=True, spare_per_rank=1, max_replicas=3,
                replan_every=1, warmup_iters=0, min_gain=0.0)
    base.update(kw)
    return ReplicationConfig(**base)


def _mgr(**kw):
    return ReplicaManager.from_geometry(E, _rpcfg(**kw), EP,
                                        bytes_per_expert=64)


def _params(rsets, d=4, n_layers=2, seed=0):
    """(logical tree, expanded tree) with stacked [L, S, d, d] weights."""
    rng = np.random.default_rng(seed)
    logical = {"blocks": {"layer0": {"moe": {
        k: rng.normal(size=(n_layers, E, d, d)).astype(np.float32)
        for k in MOE_WEIGHT_KEYS}}}}
    return logical, expand_moe_params(logical, rsets)


def _observe(mgr, load):
    mgr.observe(np.stack([np.asarray(load, np.float64),
                          np.zeros(E)])[None])


def _drain_all(mgr, co, plan, params):
    ex = MigrationExecutor(mgr, plan, bytes_per_iter=1 << 30,
                           priority_layers=co.recovery_layers(plan),
                           patch_fn=co.patch_params)
    while ex.draining:
        params, rep = ex.drain(params)
        co.on_layers_landed(plan, rep.layers)
    return params


def _save(mgr, params, tmp, step=0):
    ckpt.save(str(tmp), step, {
        "serving": {"params": params, "m_state": np.zeros((1, EP))},
        mgr.ckpt_group: mgr.state_dict()})


# --------------------------------------------------------------------------
# masked sets + dead-rank-aware planning
# --------------------------------------------------------------------------
def test_masked_set_drops_dead_replicas_and_reports_lost():
    rep_pos = np.zeros((E, 2), np.int32)
    for ex in range(E):
        rep_pos[ex] = (ex // 2) * SPR + (ex % 2)
    rep_pos[0, 1] = 2 * SPR + 2          # expert 0 replicated on rank 2
    n_rep = np.ones(E, np.int32)
    n_rep[0] = 2
    rs = ReplicaSet(rep_pos, n_rep, EP, SPR)

    alive = np.ones(EP, bool)
    alive[0] = False                     # rank 0 hosts experts 0, 1
    masked, lost = rs.masked(alive)
    # expert 0 survives on rank 2 (distinct-rank invariant), re-padded
    assert masked.n_rep[0] == 1
    assert masked.rep_pos[0, 0] == 2 * SPR + 2
    assert (masked.rep_pos[0] == 2 * SPR + 2).all()      # pad = primary
    # expert 1 was a singleton on rank 0: lost, row untouched
    assert lost.tolist() == [1]
    assert masked.rep_pos[1, 0] == rep_pos[1, 0]
    # everyone else untouched
    for ex in range(2, E):
        assert masked.n_rep[ex] == 1
        assert masked.rep_pos[ex, 0] == rep_pos[ex, 0]


def test_masked_requires_full_shape():
    rs = ReplicaSet.identity(E, EP, slots_per_rank=SPR)
    with pytest.raises(ValueError):
        rs.masked(np.ones(EP - 1, bool))


def test_planner_places_nothing_on_dead_ranks():
    load = np.ones(E)
    load[0] = 40.0
    alive = np.ones(EP, bool)
    alive[2] = False
    rs = plan_replication(load, EP, SPR, max_replicas=3, rank_alive=alive)
    assert not rs.hosts_rank(2)
    # every expert placed, distinct live ranks per expert
    for ex in range(E):
        ranks = rs.rep_pos[ex, :rs.n_rep[ex]] // SPR
        assert len(set(ranks.tolist())) == rs.n_rep[ex]
        assert alive[ranks].all()
    # the hot expert still gets replicas (on live ranks only)
    assert rs.n_rep[0] >= 2


def test_planner_dead_rank_capacity_floor():
    # 8 experts on 3 live ranks x 3 slots = 9 slots: tight but feasible
    alive = np.ones(EP, bool)
    alive[1] = False
    rs = plan_replication(np.ones(E), EP, SPR, max_replicas=3,
                          rank_alive=alive)
    assert not rs.hosts_rank(1)
    placed = set()
    for ex in range(E):
        placed.update(rs.rep_pos[ex, :rs.n_rep[ex]].tolist())
    assert len(placed) <= 9


def test_capacity_factor_ignores_dead_ranks():
    rs = ReplicaSet.identity(E, EP, slots_per_rank=SPR)
    load = np.ones(E)
    alive = np.ones(EP, bool)
    alive[3] = False
    # identity: rank 3 hosts experts 6,7 -> dead rank excluded from both
    # the peak and the mean of the live ranks
    f_all = rs.capacity_factor(load, margin=1.0, floor=0.0)
    f_live = rs.capacity_factor(load, margin=1.0, floor=0.0,
                                rank_alive=alive)
    assert f_all == pytest.approx(1.0)
    assert f_live == pytest.approx(1.0)


# --------------------------------------------------------------------------
# fault injection + slab zeroing
# --------------------------------------------------------------------------
def test_fault_injector_fires_once_in_order():
    fi = FaultInjector([(9, "rejoin", 2), FaultEvent(4, "fail", 2)])
    assert fi.due(3) == []
    evs = fi.due(5)
    assert [(e.it, e.kind, e.rank) for e in evs] == [(4, "fail", 2)]
    assert fi.due(5) == []               # fires exactly once
    assert not fi.exhausted
    evs = fi.due(20)
    assert [(e.kind, e.rank) for e in evs] == [("rejoin", 2)]
    assert fi.exhausted


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(AssertionError):
        FaultEvent(1, "explode", 0)


def test_zero_rank_slabs_zeroes_exactly_that_rank():
    mgr = _mgr()
    _, params = _params(mgr.rsets)
    out = zero_rank_slabs(params, 2, SPR)
    for k in MOE_WEIGHT_KEYS:
        w = out["blocks"]["layer0"]["moe"][k]
        w0 = params["blocks"]["layer0"]["moe"][k]
        assert (w[:, 2 * SPR:3 * SPR] == 0).all()
        keep = [s for s in range(mgr.n_slots)
                if not 2 * SPR <= s < 3 * SPR]
        assert np.array_equal(w[:, keep], w0[:, keep])
        assert w is not w0               # original untouched


# --------------------------------------------------------------------------
# coordinator state machine
# --------------------------------------------------------------------------
def test_coordinator_requires_replica_manager():
    from repro.configs import PlacementConfig
    pm = PlacementManager.from_geometry(E, PlacementConfig(), EP)
    with pytest.raises(TypeError, match="ReplicaManager"):
        ElasticCoordinator(pm)


def test_fail_refusals():
    mgr = _mgr()
    co = ElasticCoordinator(mgr)         # no checkpoint
    # identity sets: every rank hosts singletons -> refused w/o ckpt,
    # and the refusal happens BEFORE any state mutation
    with pytest.raises(RuntimeError, match="no checkpoint"):
        co.fail_rank(1)
    assert mgr.rank_alive.all() and co.state == STATE_HEALTHY


def test_fail_last_rank_and_double_fail_refused(tmp_path):
    mgr = _mgr()
    _, params = _params(mgr.rsets)
    _save(mgr, params, tmp_path)
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path))
    for r in range(EP - 1):
        co.fail_rank(r)
    with pytest.raises(ValueError, match="already dead"):
        co.fail_rank(0)
    with pytest.raises(ValueError, match="last live rank"):
        co.fail_rank(EP - 1)


def test_replicated_only_loss_never_degrades():
    """Every expert on the lost rank has a surviving replica: the fail
    is a pure table flip — no lost experts, recovery_s == 0."""
    rpcfg = _rpcfg(spare_per_rank=2, max_replicas=2)
    mgr = ReplicaManager.from_geometry(E, rpcfg, EP, bytes_per_expert=64)
    # replicate everything: 2 replicas per expert fit 4 * 4 = 16 slots
    new = plan_replication(np.ones(E), EP, mgr.slots_per_rank,
                           max_replicas=2)
    assert (new.n_rep == 2).all()
    mgr.rsets[0] = new
    tel = Telemetry()
    co = ElasticCoordinator(mgr, telemetry=tel)   # no ckpt needed
    t0 = len(tel.recoveries)
    co.fail_rank(1)
    assert co.state == STATE_SHRUNK
    assert not co.recovering and co.lost_experts.size == 0
    assert co.last_recovery_s == 0.0
    assert len(tel.recoveries) == t0 + 1
    # survivors re-padded off the dead rank the same "iteration"
    assert not mgr.hosts_rank(1)


def test_kill_recover_rejoin_full_cycle(tmp_path):
    """fail -> degraded -> (recovery chunks land) -> shrunk -> rejoin ->
    warming -> healthy, with bitwise re-materialization from ckpt."""
    mgr = _mgr()
    logical, params = _params(mgr.rsets)
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path))

    # replicate the hot expert first so the distinct-rank invariant has
    # something to protect, then checkpoint the replicated layout
    load = np.ones(E)
    load[0] = 50.0
    _observe(mgr, load)
    plan = mgr.maybe_replan(1)
    assert plan is not None
    params = _drain_all(mgr, co, plan, params)
    _save(mgr, params, tmp_path)

    # pick a victim hosting at least one singleton primary
    rs = mgr.rset
    victim = next(r for r in range(EP)
                  if any(rs.n_rep[e] == 1 and rs.rep_pos[e, 0] // SPR == r
                         for e in range(E)))
    hot_ranks = set((rs.rep_pos[0, :rs.n_rep[0]] // SPR).tolist())

    params = co.fail_rank(victim, params)
    assert co.state == STATE_DEGRADED and co.recovering
    lost = set(co.lost_experts.tolist())
    assert lost
    # replicated expert 0 stays routable iff it had a surviving replica
    if victim in hot_ranks and len(hot_ranks) > 1:
        assert 0 not in lost
    # dead slabs zeroed; live experts never route to the dead rank
    w = params["blocks"]["layer0"]["moe"]["w_up"]
    assert (w[:, victim * SPR:(victim + 1) * SPR] == 0).all()
    for e in range(E):
        if e in lost:
            continue
        ranks = mgr.rset.rep_pos[e, :mgr.rset.n_rep[e]] // SPR
        assert victim not in ranks.tolist()
    # recovery layers are forced into the next (event-triggered) plan
    assert mgr.must_layers == set(co.lost)

    # mid-recovery: the saved-state cache must answer from the pre-kill
    # checkpoint; recovery drains through the executor with the patch
    _observe(mgr, load)
    plan2 = mgr.maybe_replan(2)
    assert plan2 is not None, "event replan must fire"
    assert co.recovery_layers(plan2) == [0]
    params = _drain_all(mgr, co, plan2, params)
    assert co.state == STATE_SHRUNK and not co.recovering
    assert co.last_recovery_s is not None and co.last_recovery_s >= 0
    assert mgr.must_layers == set()
    assert not mgr.rset.hosts_rank(victim)

    # bitwise parity: every routable slot holds the true logical rows
    for k in MOE_WEIGHT_KEYS:
        w = params["blocks"]["layer0"]["moe"][k]
        lw = logical["blocks"]["layer0"]["moe"][k]
        for e in range(E):
            for j in range(mgr.rset.n_rep[e]):
                slot = int(mgr.rset.rep_pos[e, j])
                assert np.array_equal(w[:, slot], lw[:, e]), (k, e, slot)

    # rejoin: plannable immediately, routable only after the plan lands
    co.rejoin_rank(victim)
    assert co.state == STATE_WARMING
    assert mgr.rank_alive[victim]
    assert not mgr.hosts_rank(victim)     # staged-commit: not yet routable
    _observe(mgr, load)
    plan3 = mgr.maybe_replan(3)
    assert plan3 is not None
    assert not mgr.hosts_rank(victim)     # still staged, still unroutable
    params = _drain_all(mgr, co, plan3, params)
    assert co.state == STATE_HEALTHY
    assert mgr.hosts_rank(victim)
    kinds = [e["kind"] for e in co.events]
    assert kinds == ["fail", "recovered", "rejoin", "warm"]


def test_rejoin_refused_while_live():
    mgr = _mgr()
    co = ElasticCoordinator(mgr)
    with pytest.raises(ValueError, match="already live"):
        co.rejoin_rank(0)


def test_effective_mesh_drops_dead_slices(tmp_path):
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    mgr = ReplicaManager.from_geometry(4, _rpcfg(), 2, bytes_per_expert=8)
    _, params = _params([ReplicaSet.identity(E, EP, slots_per_rank=SPR)])
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path))
    state = {"serving": {"params": {}, "m_state": np.zeros((1, 2))},
             mgr.ckpt_group: mgr.state_dict()}
    ckpt.save(str(tmp_path), 0, state)
    co.fail_rank(1)
    small = co.effective_mesh(mesh, lost_axis="model")
    assert small.devices.shape == (1, 1)


# --------------------------------------------------------------------------
# recovery-chunk priority + executor integration
# --------------------------------------------------------------------------
def test_recovery_chunks_drain_first():
    rpcfg = _rpcfg(per_layer=True)
    mgr = ReplicaManager.from_geometry(E, rpcfg, EP, bytes_per_expert=16,
                                       n_layers=3)
    # make all three layers want a replan (distinct hot experts)
    loads = np.ones((3, E))
    loads[0, 1] = 30.0
    loads[1, 3] = 30.0
    loads[2, 5] = 30.0
    mgr.observe(np.stack([np.stack([loads[l], np.zeros(E)])
                          for l in range(3)]))
    plan = mgr.maybe_replan(1)
    assert plan is not None
    layers = mgr.plan_layers(plan)
    assert len(layers) == 3
    prio = [layers[-1]]                  # pretend the last layer is lost
    ex = MigrationExecutor(mgr, plan, bytes_per_iter=1,
                           priority_layers=prio)
    order = [c.layer for c in ex.queue]
    assert order[0] == layers[-1]
    assert order[1:] == layers[:-1]      # stable within each class
    mgr.abort()


def test_patch_params_missing_checkpoint_raises(tmp_path):
    mgr = _mgr()
    _, params = _params(mgr.rsets)
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path))  # empty dir
    co.lost = {0: np.array([3])}
    plan = type("P", (), {"new_set": mgr.rset, "new_sets": None})()
    with pytest.raises(RuntimeError, match="no checkpoint"):
        co.patch_params(params, plan, [0])


def test_mid_recovery_checkpoint_state(tmp_path):
    """The coordinator reports ``recovering`` while lost experts are
    pending — the engine's checkpoint refusal keys off it."""
    mgr = _mgr()
    _, params = _params(mgr.rsets)
    _save(mgr, params, tmp_path)
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path))
    co.fail_rank(0)
    assert co.recovering                 # identity: rank 0 lost singletons
    _observe(mgr, np.ones(E))
    plan = mgr.maybe_replan(1)
    assert plan is not None
    params = _drain_all(mgr, co, plan, params)
    assert not co.recovering


# --------------------------------------------------------------------------
# churn budget
# --------------------------------------------------------------------------
def _perlayer_mgr(n_layers=3, **kw):
    rpcfg = _rpcfg(per_layer=True, **kw)
    return ReplicaManager.from_geometry(E, rpcfg, EP, bytes_per_expert=16,
                                        n_layers=n_layers)


def _skewed_obs(mgr, hots, mag=30.0):
    loads = np.ones((len(hots), E))
    for l, h in enumerate(hots):
        loads[l, h] = mag
    mgr.observe(np.stack([np.stack([loads[l], np.zeros(E)])
                          for l in range(len(hots))]))
    return loads


def test_churn_budget_caps_changed_layers():
    mgr = _perlayer_mgr(max_changed_layers=1)
    # layer 1 has the hottest expert -> highest predicted gain
    loads = np.ones((3, E))
    loads[0, 1] = 10.0
    loads[1, 3] = 60.0
    loads[2, 5] = 10.0
    mgr.observe(np.stack([np.stack([loads[l], np.zeros(E)])
                          for l in range(3)]))
    plan = mgr.maybe_replan(1)
    assert plan is not None
    assert mgr.plan_layers(plan) == [1]  # only the highest-gain layer
    mgr.abort()

    # unlimited budget: all three layers change
    mgr2 = _perlayer_mgr(max_changed_layers=0)
    mgr2.observe(np.stack([np.stack([loads[l], np.zeros(E)])
                           for l in range(3)]))
    plan2 = mgr2.maybe_replan(1)
    assert plan2 is not None
    assert len(mgr2.plan_layers(plan2)) == 3
    mgr2.abort()


def test_churn_budget_exempts_recovery_layers():
    mgr = _perlayer_mgr(max_changed_layers=1)
    loads = np.ones((3, E))
    loads[0, 1] = 60.0
    loads[1, 3] = 30.0
    loads[2, 5] = 20.0
    mgr.observe(np.stack([np.stack([loads[l], np.zeros(E)])
                          for l in range(3)]))
    # layer 2 carries lost experts: must replan on top of the budget
    mgr.must_layers = {2}
    mgr.request_replan()
    plan = mgr.maybe_replan(1)
    assert plan is not None
    changed = set(mgr.plan_layers(plan))
    assert 2 in changed                  # recovery layer always included
    assert len(changed) <= 2             # budget 1 + the mandatory layer
    mgr.abort()


def test_event_replan_bypasses_cadence_and_gain():
    mgr = _mgr(replan_every=1000, min_gain=0.9, warmup_iters=0)
    _observe(mgr, np.ones(E) + np.arange(E) * 0.01)
    # off-cadence, gain below min_gain: nothing fires normally
    assert mgr.maybe_replan(7) is None
    mgr.request_replan()
    plan = mgr.maybe_replan(8)           # event bypasses both guards
    assert plan is not None
    mgr.abort()
    # the request was consumed
    assert mgr.maybe_replan(9) is None


# --------------------------------------------------------------------------
# weighted per-replica token splitting
# --------------------------------------------------------------------------
def test_split_schedule_equal_matches_round_robin():
    rs = ReplicaSet.identity(E, EP, slots_per_rank=SPR, max_replicas=3)
    rep_pos = rs.rep_pos.copy()
    n_rep = rs.n_rep.copy()
    rep_pos[0, 1], n_rep[0] = 2 * SPR + 2, 2
    rep_pos[1, 1], rep_pos[1, 2], n_rep[1] = 3 * SPR + 2, 1 * SPR + 2, 3
    rs = ReplicaSet(rep_pos, n_rep, EP, SPR)
    sched = rs.split_schedule()
    q = ReplicaSet.SPLIT_QUANTUM
    assert sched.shape == (E, q)
    for e in range(E):
        want = np.arange(q) % max(int(n_rep[e]), 1)
        assert np.array_equal(sched[e], want), e


def test_split_schedule_weighted_quota():
    rep_pos = np.zeros((E, 3), np.int32)
    for ex in range(E):
        rep_pos[ex] = (ex // 2) * SPR + (ex % 2)
    rep_pos[0] = [0, 2 * SPR + 2, 3 * SPR + 2]
    n_rep = np.ones(E, np.int32)
    n_rep[0] = 3
    rs = ReplicaSet(rep_pos, n_rep, EP, SPR)
    w = np.zeros((E, 3))
    w[:, 0] = 1.0
    w[0] = [3.0, 2.0, 1.0]               # 6 units over Q=12 -> 6/4/2
    sched = rs.split_schedule(w)
    counts = np.bincount(sched[0], minlength=3)
    assert counts.tolist() == [6, 4, 2]
    # interleaved, not blocked: the first half already mixes replicas
    assert len(set(sched[0, :6].tolist())) == 3
    # singletons always schedule replica 0
    assert (sched[1:] == 0).all()


def test_residual_split_weights_shed_to_spare_capacity():
    rep_pos = np.zeros((E, 2), np.int32)
    for ex in range(E):
        rep_pos[ex] = (ex // 2) * SPR + (ex % 2)
    rep_pos[0, 1] = 2 * SPR + 2
    n_rep = np.ones(E, np.int32)
    n_rep[0] = 2
    rs = ReplicaSet(rep_pos, n_rep, EP, SPR)
    load = np.ones(E)
    load[0] = 10.0
    load[4], load[5] = 6.0, 6.0          # rank 2 (host of the replica) busy
    w = rs.residual_split_weights(load)
    # rank 2 is loaded -> the replica there gets LESS than the primary
    assert w[0, 0] > w[0, 1] > 0
    # symmetric case: idle rank 3 instead
    rep_pos2 = rep_pos.copy()
    rep_pos2[0, 1] = 3 * SPR + 2
    rs2 = ReplicaSet(rep_pos2, n_rep, EP, SPR)
    w2 = rs2.residual_split_weights(load)
    assert w2[0, 1] > w[0, 1]            # idler host -> bigger share
    # dead host -> zero share
    alive = np.ones(EP, bool)
    alive[3] = False
    w3 = rs2.residual_split_weights(load, rank_alive=alive)
    assert w3[0, 1] == 0.0 and w3[0, 0] > 0


def test_weighted_device_tables_have_schedule_entry():
    mgr = _mgr(weighted_split=True)
    tables = mgr.device_tables()
    assert len(tables) == 4
    q = ReplicaSet.SPLIT_QUANTUM
    assert tables[3].shape == (E, q)
    # before any observation: equal-share schedule
    assert (tables[3] == 0).all()        # identity sets: n_rep == 1
    assert mgr.wants_table_refresh(1)    # replan_every == 1
    mgr_plain = _mgr()
    assert len(mgr_plain.device_tables()) == 3
    assert not mgr_plain.wants_table_refresh(1)

    mgr_pl = _perlayer_mgr(weighted_split=True)
    t = mgr_pl.device_tables()
    assert len(t) == 4 and t[3].shape == (3, E, q)


# --------------------------------------------------------------------------
# telemetry + degraded accounting
# --------------------------------------------------------------------------
def test_telemetry_availability_and_recovery():
    from repro.serving.engine import IterStats
    tel = Telemetry()
    assert tel.availability == 1.0

    def it(n_unroutable=0, lost=0.0):
        return IterStats(n_active=1, tokens=4, ib_global=1.0,
                         fp4_ranks=0.0, gate_open=0.0,
                         n_unroutable=n_unroutable, lost_tokens=lost)

    for _ in range(8):
        tel.record_iter(it())
    for _ in range(2):
        tel.record_iter(it(n_unroutable=2, lost=3.0))
    tel.record_recovery(0.25)
    assert tel.degraded_iters == 2
    assert tel.availability == pytest.approx(0.8)
    assert tel.lost_tokens_total == pytest.approx(6.0)
    s = tel.summary()
    assert s["availability"] == pytest.approx(0.8)
    assert s["degraded_iters"] == 2
    assert s["n_recoveries"] == 1
    assert s["recovery_s"] == pytest.approx(0.25)
    assert Telemetry().summary()["recovery_s"] is None


def test_lost_token_count_per_layer_and_shared():
    mgr = _perlayer_mgr(n_layers=2)
    co = ElasticCoordinator(mgr)
    es = np.zeros((2, 2, E))
    es[0, 0, 3] = 5.0
    es[1, 0, 3] = 7.0
    es[1, 0, 6] = 2.0
    assert co.lost_token_count(es) == 0.0
    co.lost = {1: np.array([3, 6])}
    assert co.lost_token_count(es) == pytest.approx(9.0)

    mgr_s = _mgr()
    co_s = ElasticCoordinator(mgr_s)
    co_s.lost = {0: np.array([3])}
    assert co_s.lost_token_count(es) == pytest.approx(12.0)


# --------------------------------------------------------------------------
# engine end-to-end (slow): fault injection under load
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    import jax
    import repro.models.transformer as tf
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


@pytest.mark.slow
def test_engine_weighted_split_identity_bitwise(model):
    """The 4-table traced path with an equal-share schedule generates
    exactly what the 3-table (and table-free) engines do."""
    from repro.serving.engine import Engine
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)
    eng0 = Engine(cfg, params, rcfg, max_slots=3, max_len=32, virtual_ep=4)
    for r in _reqs(cfg):
        eng0.submit(r)
    g0 = [r.generated for r in sorted(eng0.run(), key=lambda r: r.uid)]

    mgr = ReplicaManager(cfg, ReplicationConfig(
        enabled=False, spare_per_rank=1, weighted_split=True), 4)
    eng1 = Engine(cfg, expand_moe_params(params, mgr.rset), rcfg,
                  max_slots=3, max_len=32, placement=mgr)
    for r in _reqs(cfg):
        eng1.submit(r)
    g1 = [r.generated for r in sorted(eng1.run(), key=lambda r: r.uid)]
    assert g0 == g1


@pytest.mark.slow
def test_engine_kill_rejoin_under_load(model, tmp_path):
    """Scripted kill + rejoin while serving: the engine masks the dead
    rank the same iteration, refuses checkpoints mid-recovery, streams
    recovery chunks ahead of optimization, and ends healthy."""
    from repro.serving.engine import Engine
    cfg, params = model
    mgr = ReplicaManager(cfg, ReplicationConfig(
        replan_every=4, warmup_iters=2, min_gain=0.0, per_layer=True,
        spare_per_rank=1, max_replicas=2), 4)
    tel = Telemetry()
    co = ElasticCoordinator(mgr, ckpt_dir=str(tmp_path), telemetry=tel)
    # kill BEFORE the first cadence replan (it=4): the sets are still
    # identity, so rank 2's primaries are singletons and the loss opens
    # a real degraded window (a later kill could land after replication
    # already covered them)
    fi = FaultInjector([(3, "fail", 2), (14, "rejoin", 2)])
    # per-layer chunks + a 1-byte budget: one recovery chunk lands per
    # iteration, so the degraded window spans recorded iterations
    eng = Engine(cfg, expand_moe_params(params, mgr.rsets),
                 ReaLBConfig(gate_gamma=4), max_slots=3, max_len=32,
                 placement=mgr, telemetry=tel, migrate_async=True,
                 migrate_bytes_per_iter=1,
                 elastic=co, fault_injector=fi)
    for r in _reqs(cfg, n=10, new=6):
        eng.submit(r)
    eng.save_checkpoint(str(tmp_path), 0)     # pre-kill re-mat source

    # drive manually so the mid-recovery refusal is observable
    saw_refusal = False
    for _ in range(200):
        if eng.scheduler.idle:
            break
        eng.step()
        if co.recovering and not saw_refusal:
            # refused either way: the recovery plan is draining AND the
            # params still hold zeroed slabs
            with pytest.raises(RuntimeError,
                               match="draining|mid-recovery"):
                eng.save_checkpoint(str(tmp_path), 1)
            saw_refusal = True
    assert eng.scheduler.idle
    eng.drain_migrations()
    assert fi.exhausted
    assert saw_refusal, "the kill never produced a degraded window"
    # recovery completed and was stamped
    assert not co.recovering
    assert co.last_recovery_s is not None and co.last_recovery_s >= 0.0
    assert tel.recoveries
    assert tel.summary()["recovery_s"] is not None
    assert tel.degraded_iters >= 1
    assert tel.availability < 1.0
    # degraded iterations were visible in the stats stream
    assert any(s.n_unroutable > 0 for s in eng.stats)
    # the rejoined rank ended healthy (possibly still warming if the
    # tail had no replan; drain state must at least be consistent)
    assert mgr.rank_alive.all()
    assert co.state in (STATE_HEALTHY, STATE_WARMING)
    # the dedicated mid-recovery refusal (no migration draining): a
    # pending lost expert alone blocks the save
    co.lost = {0: np.array([1])}
    with pytest.raises(RuntimeError, match="mid-recovery"):
        eng.save_checkpoint(str(tmp_path), 1)
    co.lost = {}
    # a healthy checkpoint can be written again after recovery
    eng.save_checkpoint(str(tmp_path), 2)
