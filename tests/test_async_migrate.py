"""Async overlapped migration: per-layer slab streaming with
measured-bandwidth budgeting (repro.serving.async_migrate), the staged
commit protocol shared by both managers, the migration-accounting
bugfixes (measured wall seconds under wall clocks, single-sourced
bandwidth, guarded replan-while-pending, integral byte counts) and the
bounded-stall property of the async serving arms."""
import tempfile

import numpy as np
import pytest

from repro.configs import (PlacementConfig, ReaLBConfig, ReplicationConfig,
                           get_config, reduced)
from repro.configs.base import MIGRATION_BW_DEFAULT
from repro.placement import (MigrationBandwidth, PlacementManager,
                             apply_layers_to_params, apply_to_params,
                             subset_plan)
from repro.replication import ReplicaManager
from repro.serving.async_migrate import MigrationExecutor, SlabChunk

SKEW = [10.0, 8, 1, 1, 1, 1, 1, 1]
FLAT = [1.0] * 8


def _skew_stats(skews, e=8):
    es = np.zeros((len(skews), 2, e))
    for l, row in enumerate(skews):
        es[l, 0] = row
        es[l, 1] = np.asarray(row) * 0.5
    return es


def _np_params(n_layers=3, e=8):
    w = np.arange(n_layers * e * 2 * 4, dtype=np.float32)
    w = w.reshape(n_layers, e, 2, 4)
    return {"blocks": {"layer0": {"moe": {
        "router": np.zeros((2, e)), "w_gate": w, "w_up": w + 1,
        "w_down": np.swapaxes(w, 2, 3)}}}}


def _perlayer_mgr(n_layers=3, bpe=7, **kw):
    pcfg = PlacementConfig(replan_every=2, warmup_iters=1, min_gain=0.0,
                           per_layer=True, **kw)
    return PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=bpe,
                                          n_layers=n_layers)


# --------------------------------------------------------------------------
# measured-bandwidth EWMA
# --------------------------------------------------------------------------
def test_bandwidth_ewma_measures_and_prices():
    bw = MigrationBandwidth(50e9, alpha=0.5)
    assert float(bw) == 50e9 and not bw.calibrated
    assert bw.seconds(100e9) == 2.0             # prior prices transfers
    bw.observe(1000, 1.0)                       # first obs REPLACES prior
    assert bw.calibrated and float(bw) == 1000.0
    bw.observe(3000, 1.0)                       # then EWMA
    assert float(bw) == 2000.0
    bw.observe(0, 1.0)                          # degenerate obs ignored
    bw.observe(10, 0.0)
    assert float(bw) == 2000.0 and bw.n_obs == 2
    assert bw.seconds(4000) == 2.0
    bw.reset()
    assert float(bw) == 50e9 and not bw.calibrated


def test_bandwidth_single_sourced_across_configs_and_costmodel():
    """Bugfix: sims, gates and managers price migration bytes at the SAME
    bandwidth — one constant, one live EWMA object."""
    from benchmarks import costmodel as cm
    assert cm.ICI_BW == MIGRATION_BW_DEFAULT
    assert PlacementConfig().migration_bw == MIGRATION_BW_DEFAULT
    assert ReplicationConfig().migration_bw == MIGRATION_BW_DEFAULT
    g = cm.KIMI_VL
    # a live bandwidth object re-prices migration_time everywhere
    slow = MigrationBandwidth(1e6)
    assert cm.migration_time(4, g, bw=slow) == \
        pytest.approx(cm.migration_bytes(4, g) / 1e6)
    assert cm.migration_time(4, g) == \
        pytest.approx(cm.migration_bytes(4, g) / cm.ICI_BW)
    # the gate's migration side tracks the EWMA: at measured 1 MB/s the
    # same plan that amortizes at ICI speed no longer does
    skew = np.array([8.0, 1, 1, 1, 1, 1, 1, 1])
    flat = np.full(8, skew.sum() / 8)
    fast = cm.ReplanCostGate(g, 8, horizon_iters=100)
    assert fast.accept(skew, flat, 4)
    assert not cm.ReplanCostGate(g, 8, horizon_iters=100,
                                 bandwidth=slow).accept(skew, flat, 4)
    cal = cm.CalibratedReplanCostGate(g, 8, horizon_iters=100)
    assert cal.accept(skew, flat, 4)
    cal.bandwidth = slow
    assert not cal.accept(skew, flat, 4)
    assert not cal.accept_layers(np.tile(skew, (4, 1)),
                                 np.tile(flat, (4, 1)), 4)


def test_manager_wires_its_bandwidth_into_the_gate():
    from benchmarks import costmodel as cm
    g = cm.KIMI_VL
    gate = cm.CalibratedReplanCostGate(g, 4, horizon_iters=32)
    assert gate.bandwidth is None
    pcfg = PlacementConfig()
    mgr = PlacementManager.from_geometry(8, pcfg, 4, cost_gate=gate)
    assert gate.bandwidth is mgr.bandwidth
    rgate = cm.ReplanCostGate(g, 4, horizon_iters=32)
    rmgr = ReplicaManager.from_geometry(8, ReplicationConfig(), 4,
                                        cost_gate=rgate)
    assert rgate.bandwidth is rmgr.bandwidth
    # measured applies move the manager's pricing
    mgr.bandwidth.observe(10_000, 2.0)
    assert mgr.migration_seconds(5_000) == 1.0


# --------------------------------------------------------------------------
# chunked subset apply
# --------------------------------------------------------------------------
def test_apply_layers_union_equals_full_apply():
    mgr = _perlayer_mgr()
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    plan = mgr.maybe_replan(2)
    assert sorted(mgr.plan_layers(plan)) == [0, 2]
    params = _np_params()
    ref = apply_to_params(params, plan)
    # one chunk at a time, any order, same result bitwise
    out = apply_layers_to_params(params, plan, [2])
    mid = out["blocks"]["layer0"]["moe"]["w_gate"]
    np.testing.assert_array_equal(mid[0],
                                  params["blocks"]["layer0"]["moe"]
                                  ["w_gate"][0])   # layer 0 untouched
    out = apply_layers_to_params(out, plan, [0])
    for k in ("w_gate", "w_up", "w_down"):
        np.testing.assert_array_equal(out["blocks"]["layer0"]["moe"][k],
                                      ref["blocks"]["layer0"]["moe"][k])


def test_subset_plan_shared_is_single_chunk():
    pcfg = PlacementConfig(replan_every=2, warmup_iters=1, min_gain=0.0)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=7)
    mgr.observe(_skew_stats([SKEW]))
    plan = mgr.maybe_replan(2)
    assert mgr.plan_layers(plan) == [0]
    assert mgr.layer_bytes(plan, 0) == plan.moved_bytes
    assert subset_plan(plan, [0]) is plan
    with pytest.raises(AssertionError):
        subset_plan(plan, [1])


# --------------------------------------------------------------------------
# the executor: async drain == synchronous apply, budget packing
# --------------------------------------------------------------------------
def test_executor_drained_result_bitwise_equals_sync():
    params = _np_params()
    m_sync, m_async = _perlayer_mgr(), _perlayer_mgr()
    for m in (m_sync, m_async):
        m.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    p_sync, p_async = m_sync.maybe_replan(2), m_async.maybe_replan(2)
    np.testing.assert_array_equal(p_sync.gather_idx, p_async.gather_idx)
    ref = apply_to_params(params, p_sync)
    m_sync.commit(p_sync)

    ex = MigrationExecutor(m_async, p_async, bytes_per_iter=1)
    assert ex.total_bytes == p_async.moved_bytes
    out, drains = params, 0
    while ex.draining:
        out, rep = ex.drain(out)
        drains += 1
        assert len(rep.layers) == 1            # budget 1: chunk at a time
        assert rep.excess_bytes == rep.nbytes - 1
        # landed layers' tables flip immediately; pending stay old
        for l in rep.layers:
            np.testing.assert_array_equal(
                m_async.tables[l].e2r, p_async.new_tables[l].e2r)
    assert drains == 2 and rep.done
    for k in ("w_gate", "w_up", "w_down"):
        np.testing.assert_array_equal(out["blocks"]["layer0"]["moe"][k],
                                      ref["blocks"]["layer0"]["moe"][k])
    for a, b in zip(m_async.tables, m_sync.tables):
        np.testing.assert_array_equal(a.e2r, b.e2r)
    assert m_async.n_migrations == m_sync.n_migrations == 1
    assert m_async.migrated_bytes == m_sync.migrated_bytes
    assert m_async.bandwidth.calibrated       # timed applies observed


def test_executor_budget_packs_multiple_chunks():
    mgr = _perlayer_mgr(n_layers=4, bpe=10)
    mgr.observe(_skew_stats([SKEW, SKEW[::-1], FLAT, SKEW]))
    plan = mgr.maybe_replan(2)
    assert len(mgr.plan_layers(plan)) == 3
    ex = MigrationExecutor(mgr, plan, bytes_per_iter=10 ** 9)
    out, rep = ex.drain(_np_params(n_layers=4))
    assert rep.done and len(rep.layers) == 3   # all chunks fit one budget
    assert rep.nbytes == plan.moved_bytes and rep.excess_bytes == 0
    assert mgr.in_flight is None and mgr.n_migrations == 1


def test_executor_chunk_queue_and_partial_commit_state():
    mgr = _perlayer_mgr()
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    plan = mgr.maybe_replan(2)
    ex = MigrationExecutor(mgr, plan, bytes_per_iter=1)
    assert [c.layer for c in ex.queue] == [0, 2]
    assert all(isinstance(c, SlabChunk) and c.nbytes > 0 for c in ex.queue)
    out, rep = ex.drain(_np_params())
    assert rep.layers == [0] and ex.draining
    assert mgr.in_flight is plan               # still mid-flight
    assert mgr._pending_remaining == {2}
    assert mgr.n_migrations == 0               # counted only when landed
    assert mgr.migrated_bytes_per_layer[0] > 0
    assert mgr.migrated_bytes_per_layer[2] == 0
    out, rep = ex.drain(out)
    assert rep.done and mgr.in_flight is None and mgr.n_migrations == 1


# --------------------------------------------------------------------------
# staged-commit protocol regressions (both managers)
# --------------------------------------------------------------------------
def test_second_replan_while_pending_is_guarded_noop():
    """Bugfix: a replan arriving while a staged plan is pending must not
    overwrite it (the engine would gather slabs for one plan and flip
    tables for another)."""
    pcfg = PlacementConfig(replan_every=1, warmup_iters=1, min_gain=0.0)
    rpcfg = ReplicationConfig(replan_every=1, warmup_iters=1, min_gain=0.0)
    for mgr in (PlacementManager.from_geometry(8, pcfg, 4,
                                               bytes_per_expert=3),
                ReplicaManager.from_geometry(8, rpcfg, 4,
                                             bytes_per_expert=3)):
        mgr.observe(_skew_stats([SKEW]))
        plan = mgr.maybe_replan(1)
        assert plan is not None and mgr.in_flight is plan
        # new (different!) skew while the plan drains: guarded no-op
        mgr.observe(_skew_stats([SKEW[::-1]]))
        assert mgr.maybe_replan(2) is None
        assert mgr.maybe_replan(3) is None
        assert mgr.in_flight is plan               # not overwritten
        with pytest.raises(AssertionError, match="in-flight"):
            mgr._stage(plan)                       # belt and braces
        mgr.commit(plan)
        assert mgr.in_flight is None
        assert mgr.maybe_replan(4) is not None     # replans flow again


def test_abort_mid_drain_keeps_landed_layers_routable():
    mgr = _perlayer_mgr()
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    plan = mgr.maybe_replan(2)
    mgr.commit_layers(plan, [0])                   # layer 0 landed
    mgr.abort()                                    # layer 2 never lands
    np.testing.assert_array_equal(mgr.tables[0].e2r,
                                  plan.new_tables[0].e2r)
    assert not np.array_equal(mgr.tables[2].e2r, plan.new_tables[2].e2r)
    assert mgr.in_flight is None and mgr.n_migrations == 0
    assert mgr.migrated_bytes_per_layer[2] == 0
    # commit of an aborted plan is refused
    with pytest.raises(AssertionError, match="not staged"):
        mgr.commit_layers(plan, [2])


def test_commit_of_wrong_layer_refused():
    mgr = _perlayer_mgr()
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    plan = mgr.maybe_replan(2)
    with pytest.raises(AssertionError):
        mgr.commit_layers(plan, [1])               # layer 1 never changed
    mgr.commit_layers(plan, [0])
    with pytest.raises(AssertionError):
        mgr.commit_layers(plan, [0])               # double commit


# --------------------------------------------------------------------------
# integral byte counts end-to-end
# --------------------------------------------------------------------------
def test_byte_accounting_is_integral():
    mgr = _perlayer_mgr()
    mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]]))
    plan = mgr.maybe_replan(2)
    assert isinstance(plan.moved_bytes, int)
    assert all(isinstance(mgr.layer_bytes(plan, l), int)
               for l in mgr.plan_layers(plan))
    mgr.commit(plan)
    assert isinstance(mgr.migrated_bytes, int)
    assert mgr.migrated_bytes_per_layer.dtype == np.int64


# --------------------------------------------------------------------------
# cost-model async sims: bounded per-iteration stall
# --------------------------------------------------------------------------
def test_sim_async_bounds_per_iteration_stall():
    from benchmarks import costmodel as cm
    from benchmarks import traces as tr
    cfg = tr.TraceConfig(name="depth", iters=240, jump_every=80,
                         zipf_a=1.3, vision_frac_mean=0.7, seed=5)
    g = cm.KIMI_VL
    sync = cm.sim_placement_layers(cfg, g, n_layers=4, per_layer=True)
    azn = cm.sim_placement_async(cfg, g, n_layers=4)
    assert float(sync.extra["moved_bytes"][0]) > 0
    assert float(azn.extra["moved_bytes"][0]) > 0
    # sync charges whole transfers in single iterations; async never
    # stalls more than the budget excess (here: 0 — chunks fit exactly)
    assert max(sync.extra["mig_stall_s"]) > 0
    assert max(azn.extra["mig_stall_s"]) == 0.0
    assert sum(azn.extra["mig_hidden_s"]) > 0
    assert sum(sync.extra["mig_hidden_s"]) == 0.0
    # overlap does not cost balance quality: still beats the shared arm
    shared = cm.sim_placement_layers(cfg, g, n_layers=4, per_layer=False)
    assert float(np.mean(azn.extra["ib_global"])) < \
        float(np.mean(shared.extra["ib_global"]))
    razn = cm.sim_replication_async(cfg, g, n_layers=4)
    assert max(razn.extra["mig_stall_s"]) == 0.0
    assert float(razn.extra["moved_bytes"][0]) > 0


# --------------------------------------------------------------------------
# engine end-to-end (slow): async serving arms + mid-flight checkpoint
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    import jax

    from repro.models import transformer as tf
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


def _bias_routers_by_depth(params, biases):
    import jax.numpy as jnp
    out = dict(params)
    blocks = dict(out["blocks"])
    lp = dict(blocks["layer0"])
    moe = dict(lp["moe"])
    moe["router"] = moe["router"] + jnp.asarray(biases)[:, None, :]
    lp["moe"] = moe
    blocks["layer0"] = lp
    out["blocks"] = blocks
    return out


def _async_engine(cfg, params, budget, clocked=True):
    from repro.serving.engine import Engine
    from repro.serving.telemetry import Telemetry
    from repro.workloads import IterationCostModel, VirtualClock
    mgr = PlacementManager(cfg, PlacementConfig(
        planner="least_loaded", replan_every=3, warmup_iters=2,
        min_gain=0.0, per_layer=True), 4)
    tel = Telemetry()
    kw = dict(clock=VirtualClock(), cost_model=IterationCostModel()) \
        if clocked else {}
    eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                 max_len=32, placement=mgr, telemetry=tel,
                 migrate_async=True, migrate_bytes_per_iter=budget, **kw)
    return eng, mgr, tel


@pytest.mark.slow
def test_engine_async_bounded_stall_and_consistency(model):
    """Async serving: per-iteration stall bounded by the byte budget
    (chunks that fit are hidden, never charged), per-layer tables flip
    exactly as their slabs land, accounting matches the sync twin."""
    from repro.placement import migrate as pmigrate
    from repro.serving.engine import Engine
    from repro.serving.telemetry import Telemetry
    from repro.workloads import IterationCostModel, VirtualClock
    cfg, params = model
    b0 = np.array([3.0, 2.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0])
    params = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))
    layer_bytes = pmigrate.expert_bytes(cfg, 1) * cfg.moe.num_experts

    # sync twin
    mgr_s = PlacementManager(cfg, PlacementConfig(
        planner="least_loaded", replan_every=3, warmup_iters=2,
        min_gain=0.0, per_layer=True), 4)
    tel_s = Telemetry()
    eng_s = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                   max_len=32, placement=mgr_s, telemetry=tel_s,
                   clock=VirtualClock(), cost_model=IterationCostModel())
    for r in _reqs(cfg, n=12, seed=3):
        eng_s.submit(r)
    eng_s.run()
    assert mgr_s.n_migrations >= 1
    assert sum(st.migration_s for st in eng_s.stats) > 0      # sync stalls
    assert eng_s.migration_hidden_s == 0.0

    # async: budget = one layer's slab -> every chunk fits, zero stall
    eng_a, mgr_a, tel_a = _async_engine(cfg, params, layer_bytes)
    for r in _reqs(cfg, n=12, seed=3):
        eng_a.submit(r)
    while not eng_a.scheduler.idle:
        eng_a.step()
        plan = mgr_a.in_flight
        if plan is not None:
            # consistency: landed layers route the new table, in-flight
            # layers still route the old one
            landed = set(mgr_a.plan_layers(plan)) - mgr_a._pending_remaining
            for l in landed:
                np.testing.assert_array_equal(mgr_a.tables[l].e2r,
                                              plan.new_tables[l].e2r)
            for l in mgr_a._pending_remaining:
                assert not np.array_equal(mgr_a.tables[l].e2r,
                                          plan.new_tables[l].e2r)
    eng_a.drain_migrations()
    assert mgr_a.n_migrations >= 1
    # bounded stall: no iteration charged any migration seconds (every
    # chunk fit the budget — the transfer hid under the forwards) and no
    # iteration moved more than the budget + one chunk
    assert all(st.migration_s == 0.0 for st in eng_a.stats)
    assert eng_a.migration_hidden_s > 0.0
    assert all(st.migration_bytes <= 2 * layer_bytes for st in eng_a.stats)
    assert all(isinstance(st.migration_bytes, int) for st in eng_a.stats)
    assert isinstance(tel_a.migration_bytes_total, int)
    assert mgr_a.migrated_bytes == mgr_a.migrated_bytes_per_layer.sum()
    assert mgr_a.bandwidth.calibrated
    s = tel_a.summary()
    assert s["migration_stall_s"] == 0.0
    assert s["migration_hidden_s"] > 0.0


@pytest.mark.slow
def test_engine_async_mid_flight_checkpoint_refused(model):
    cfg, params = model
    b0 = np.array([3.0, 2.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0])
    params = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))
    eng, mgr, _ = _async_engine(cfg, params, budget=1)  # 1 chunk per iter
    for r in _reqs(cfg, n=12, seed=3):
        eng.submit(r)
    saw_draining = False
    with tempfile.TemporaryDirectory() as d:
        while not eng.scheduler.idle:
            eng.step()
            if eng.migration_draining and not saw_draining:
                saw_draining = True
                with pytest.raises(RuntimeError, match="drain"):
                    eng.save_checkpoint(d, 1)
                with pytest.raises(RuntimeError, match="drain"):
                    eng.load_checkpoint(d)
        assert saw_draining, "no migration drained mid-run"
        eng.drain_migrations()
        assert not eng.migration_draining and mgr.in_flight is None
        eng.save_checkpoint(d, 5)                 # clean state: accepted
        mgr2 = PlacementManager(cfg, PlacementConfig(
            planner="least_loaded", per_layer=True), 4)
        from repro.serving.engine import Engine
        eng2 = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                      max_len=32, placement=mgr2)
        assert eng2.load_checkpoint(d) == 5
        for a, b in zip(mgr2.tables, mgr.tables):
            np.testing.assert_array_equal(a.e2r, b.e2r)


@pytest.mark.slow
def test_engine_sync_wall_clock_records_measured_seconds(model):
    """Bugfix: under wall clocks the synchronous apply used to record 0
    charged seconds — it must record the measured apply wall time."""
    from repro.serving.engine import Engine
    from repro.serving.telemetry import Telemetry
    cfg, params = model
    b0 = np.array([3.0, 2.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0])
    params = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))
    mgr = PlacementManager(cfg, PlacementConfig(
        planner="least_loaded", replan_every=3, warmup_iters=2,
        min_gain=0.0, per_layer=True), 4)
    tel = Telemetry()
    eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                 max_len=32, placement=mgr, telemetry=tel)  # wall clock
    for r in _reqs(cfg, n=12, seed=3):
        eng.submit(r)
    eng.run()
    assert mgr.n_migrations >= 1
    assert sum(st.migration_s for st in eng.stats) > 0
    assert tel.migration_s_total > 0
