"""EP MoE correctness: dispatch/broadcast paths vs a brute-force per-token
dense reference; conservation, drops, ReaLB activation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ReaLBConfig, get_config, reduced
from repro.core import ep_moe


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.2,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
        "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F),
    }
    x = jax.random.normal(ks[4], (2, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (2, 16))
    return cfg, p, x, mod


def dense_reference(cfg, p, x):
    """Per-token exact MoE: route, run top-k experts densely, combine."""
    e = cfg.moe
    b, s, d = x.shape
    t = x.reshape(b * s, d)
    logits = t @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, e.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    # all experts on all tokens (tiny), then select
    gg = jnp.einsum("td,edf->etf", t, p["w_gate"])
    uu = jnp.einsum("td,edf->etf", t, p["w_up"])
    hh = jax.nn.silu(gg) * uu
    yy = jnp.einsum("etf,efd->etd", hh, p["w_down"])     # [E,T,D]
    out = jnp.zeros_like(t)
    n_tok = t.shape[0]
    for k in range(e.top_k):
        idxk = jnp.broadcast_to(idx[:, k][None, :, None], (1, n_tok, d))
        sel = jnp.take_along_axis(yy, idxk, axis=0)[0]   # [T,D]
        out = out + gates[:, k:k + 1] * sel
    return out.reshape(b, s, d)


def test_dispatch_matches_dense_reference(setup):
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)   # gate closed: pure bf16 path
    m = jnp.full((1, 1), 0.9)
    y, m2, aux = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod,
                                       mode="dispatch")
    y_ref = dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux["drop_frac"]) == 0.0


def test_broadcast_matches_dense_reference(setup):
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 1), 0.9)
    y, _, _ = ep_moe.ep_moe_forward(p, x[:, :1], cfg, rcfg, m, mod[:, :1],
                                    mode="broadcast")
    y_ref = dense_reference(cfg, p, x[:, :1])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)


def test_dispatch_broadcast_agree(setup):
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 1), 0.9)
    y1, _, _ = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod,
                                     mode="dispatch")
    y2, _, _ = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod,
                                     mode="broadcast")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_capacity_drops_accounted(setup):
    cfg, p, x, mod = setup
    small = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 1), 0.9)
    y, _, aux = ep_moe.ep_moe_forward(p, x, small, rcfg, m, mod,
                                      mode="dispatch")
    assert float(aux["drop_frac"]) > 0.0
    assert bool(jnp.isfinite(y).all())


def test_fp4_activation_changes_output_but_small(setup):
    """Force the policy on (tiny Γ, skewed router) and check the fp4 branch
    numerics: output differs from bf16 but within quantization error."""
    cfg, p, x, mod = setup
    # skew the router hard toward expert 0 (one hot rank w/ ep=1 won't
    # trigger; use the local path trick: policy sees 1 rank => IB=1, so
    # instead call the internal policy-driven compute by lowering gate and
    # checking gate_open statistic).
    rcfg = ReaLBConfig(gate_gamma=1)
    m = jnp.zeros((1, 1))
    y_fp4, _, aux = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m,
                                          jnp.ones_like(mod),
                                          mode="dispatch")
    assert float(aux["gate_open"]) == 1.0
    # ep=1 locally -> never a hotspot -> bf16 result identical to reference
    y_ref = dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_fp4), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_aux_losses_finite_and_scaled(setup):
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig()
    m = jnp.full((1, 1), 0.9)
    _, _, aux = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod,
                                      mode="dispatch", train=True)
    lb = float(aux["lb_loss"])
    assert np.isfinite(lb) and 0.5 < lb < 64.0   # ~E for uniform routing
    assert np.isfinite(float(aux["z_loss"]))
