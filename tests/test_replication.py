"""Replication subsystem: replica sets, the EPLB-style planner, slab
add/drop migration with the staged-commit consistency rule, the
token-split MoE dispatch (identity ≡ bitwise, replicated ≡ allclose with
post-split stats), the cost-model replan gate and the serving engine's
replica loop + checkpoint round-trips."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ReaLBConfig, ReplicationConfig, get_config,
                           reduced)
from repro.core import ep_moe
from repro.placement.table import PlacementTable
from repro.replication import (ReplicaManager, ReplicaSet, diff,
                               expand_moe_params, plan_replication)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.2,
         "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
         "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
         "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)}
    x = jax.random.normal(ks[4], (2, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (2, 16))
    return cfg, p, x, mod


def hot_expert_set(e: int = 8, ep: int = 4, s_loc: int = 3) -> ReplicaSet:
    """Expert 0 replicated onto rank 2's spare slot; everything else in
    identity-with-spare layout."""
    rep_pos = np.zeros((e, 2), np.int32)
    for ex in range(e):
        rep_pos[ex] = (ex // 2) * s_loc + (ex % 2)
    rep_pos[0, 1] = 2 * s_loc + 2
    n_rep = np.ones(e, np.int32)
    n_rep[0] = 2
    return ReplicaSet(rep_pos, n_rep, ep, s_loc)


def expand_flat(p, rset):
    """Expand a flat single-layer param dict into slot order."""
    wrapped = {"blocks": {"layer0": {"moe": p}}}
    return expand_moe_params(wrapped, rset)["blocks"]["layer0"]["moe"]


# --------------------------------------------------------------------------
# replica set
# --------------------------------------------------------------------------
def test_identity_set_is_bijective_placement():
    rs = ReplicaSet.identity(8, 4)
    assert rs.is_bijective and rs.n_spare == 0
    assert np.array_equal(rs.slot_owner, np.arange(8))
    t = PlacementTable.identity(8, 4)
    rs2 = ReplicaSet.from_placement(t)
    assert np.array_equal(rs2.rep_pos[:, 0], t.pos)


def test_identity_with_spare_layout():
    rs = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=2)
    assert rs.n_slots == 12 and rs.n_spare == 4 and not rs.is_bijective
    own = rs.slot_owner
    assert (own[[2, 5, 8, 11]] == -1).all()         # spare tails empty
    assert np.array_equal(own[[0, 1, 3, 4]], [0, 1, 2, 3])


def test_set_rejects_same_rank_replicas():
    # expert 0's two replicas both land on rank 0 (slots 0 and 1 of a
    # 3-slot slab); splitting within one rank balances nothing
    rep_pos = np.array([[0, 1]] + [[e + 3, e + 3] for e in range(7)],
                       np.int32)
    n_rep = np.ones(8, np.int32)
    n_rep[0] = 2
    with pytest.raises(ValueError, match="one rank"):
        ReplicaSet(rep_pos, n_rep, 4, 3)


def test_set_rejects_shared_slot():
    rep_pos = np.arange(8, dtype=np.int32)[:, None].repeat(2, 1)
    rep_pos[0, 1] = 3                                # also expert 3's slot
    n_rep = np.ones(8, np.int32)
    n_rep[0] = 2
    with pytest.raises(ValueError, match="distinct"):
        ReplicaSet(rep_pos, n_rep, 4, 2)


def test_post_split_rank_and_slot_loads():
    rs = hot_expert_set()
    load = np.zeros(8)
    load[0] = 10.0
    load[4] = 4.0
    rl = rs.rank_loads(load)
    np.testing.assert_allclose(rl, [5.0, 0.0, 9.0, 0.0])
    sl = rs.slot_loads(load)
    assert sl[rs.rep_pos[0, 0]] == 5.0 and sl[rs.rep_pos[0, 1]] == 5.0
    mat = rs.ownership_matrix()
    np.testing.assert_allclose(mat.sum(1), np.ones(8))
    np.testing.assert_allclose(load @ mat, rl)


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------
def test_planner_replicates_hottest_and_balances():
    load = np.array([10, 8, 1, 1, 1, 1, 1, 1.0])
    rs = plan_replication(load, 4, 3, max_replicas=2)
    assert rs.n_rep[0] == 2 and rs.n_rep[1] == 2
    ident = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=2)
    assert rs.rank_loads(load).max() < ident.rank_loads(load).max()


def test_planner_beats_bijective_on_single_hot_expert():
    """One expert hotter than a rank's fair share: un-placeable by any
    bijection, but replication splits it below that bound."""
    load = np.array([40, 1, 1, 1, 1, 1, 1, 1.0])
    from repro.placement import plan_least_loaded
    biject = plan_least_loaded(load, 4)
    rs = plan_replication(load, 4, 3, max_replicas=4)
    assert rs.rank_loads(load).max() < biject.rank_loads(load).max()
    assert rs.rank_loads(load).max() < load[0]       # actually split


def test_planner_vision_weight_prefers_vision_heavy():
    load = np.array([5.0, 5.0, 1, 1, 1, 1, 1, 1])
    vis = np.array([0.0, 5.0, 0, 0, 0, 0, 0, 0])
    rs = plan_replication(load, 4, 3, max_replicas=2, vis=vis,
                          vis_weight=2.0)
    # only 4 spare slots; the vision-heavy twin must be replicated
    assert rs.n_rep[1] == 2


def test_planner_deterministic_and_valid():
    rng = np.random.default_rng(0)
    load = rng.random(16)
    a = plan_replication(load, 4, 5, max_replicas=3)
    b = plan_replication(load.copy(), 4, 5, max_replicas=3)
    assert np.array_equal(a.rep_pos, b.rep_pos)
    assert np.array_equal(a.n_rep, b.n_rep)
    assert int(a.n_rep.sum()) <= a.n_slots


# --------------------------------------------------------------------------
# migration (diff / expand)
# --------------------------------------------------------------------------
def test_diff_identity_is_noop():
    rs = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=2)
    plan = diff(rs, rs, bytes_per_expert=10)
    assert plan.is_noop and plan.moved_bytes == 0


def test_diff_add_replica_sources_primary_cross_rank():
    old = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=2)
    new = hot_expert_set()
    plan = diff(old, new, bytes_per_expert=7)
    s = 2 * 3 + 2                                   # rank 2's spare slot
    assert plan.changed_slots.tolist() == [s]
    assert plan.crossrank_slots.tolist() == [s]
    assert plan.gather_idx[s] == new.rep_pos[0, 0]  # copy of the primary
    assert plan.moved_bytes == 7


def test_diff_retire_is_free_and_same_rank_copy_zero_bytes():
    old = hot_expert_set()
    # retire expert 0's replica -> back to identity-with-spare
    ident = ReplicaSet.identity(8, 4, slots_per_rank=3, max_replicas=2)
    plan = diff(old, ident, bytes_per_expert=7)
    assert plan.is_noop and plan.moved_bytes == 0   # slot just goes dark
    # move expert 4 into rank 2's spare (same rank as its primary):
    # an HBM-local copy, no cross-rank bytes
    rep_pos = ident.rep_pos.copy()
    n_rep = ident.n_rep.copy()
    rep_pos[4, 1] = 2 * 3 + 2
    n_rep[4] = 2
    with pytest.raises(ValueError, match="one rank"):
        ReplicaSet(rep_pos, n_rep, 4, 3)            # invalid: same rank
    rep_pos[4, 1] = 3 * 3 + 2                       # rank 3 instead
    new = ReplicaSet(rep_pos, n_rep, 4, 3)
    plan = diff(ident, new, bytes_per_expert=7)
    assert plan.moved_bytes == 7 and plan.n_moved == 1


def test_expand_moe_params_slot_layout():
    rs = hot_expert_set()
    w = np.arange(2 * 8 * 3 * 5, dtype=np.float32).reshape(2, 8, 3, 5)
    params = {"blocks": {"layer0": {"moe": {
        "router": np.zeros((3, 8)), "w_gate": w, "w_up": w + 1,
        "w_down": np.swapaxes(w, 2, 3)}}}}
    out = expand_moe_params(params, rs)
    got = out["blocks"]["layer0"]["moe"]["w_gate"]
    assert got.shape == (2, 12, 3, 5)
    own = rs.slot_owner
    for s in range(12):
        want = w[:, own[s]] if own[s] >= 0 else 0.0
        np.testing.assert_array_equal(got[:, s], want)
    # router stays logical
    assert out["blocks"]["layer0"]["moe"]["router"] is \
        params["blocks"]["layer0"]["moe"]["router"]


# --------------------------------------------------------------------------
# token-split MoE layer
# --------------------------------------------------------------------------
def test_occurrence_index_round_robin():
    flat = jnp.asarray([3, 0, 3, 3, 0, 1], jnp.int32)
    occ = np.asarray(ep_moe._occurrence_index(flat, 4))
    assert occ.tolist() == [0, 0, 1, 2, 1, 0]


@pytest.mark.parametrize("mode", ["dispatch", "broadcast"])
def test_identity_replication_bitwise_equal(setup, mode):
    """The replica-threaded layer with the identity set must be bitwise-
    identical to the default (placement=None) path."""
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 4), 0.9)
    ident = ep_moe.identity_replication(cfg.moe.num_experts, 4)
    y0, m0, aux0 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode=mode)
    y1, m1, aux1 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode=mode,
                                         placement=ident)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))
    assert np.array_equal(np.asarray(m0), np.asarray(m1))
    for k in ("load_d", "vis_d", "drop_frac", "lb_loss", "split_frac"):
        assert np.array_equal(np.asarray(aux0[k]), np.asarray(aux1[k])), k
    assert float(aux1["split_frac"]) == 0.0


@pytest.mark.parametrize("mode", ["dispatch", "broadcast"])
def test_replicated_dispatch_allclose_with_split_stats(setup, mode):
    """A replicated hot expert yields allclose outputs (replicas hold the
    same weights) while the physical loads split across its slots."""
    cfg, p, x, mod = setup
    p = dict(p, router=p["router"].at[:, 0].add(4.0))   # expert 0 hot
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    rs = hot_expert_set()
    m = jnp.full((1, 4), 0.9)
    p_rep = dict(expand_flat(p, rs), router=p["router"])
    place = tuple(jnp.asarray(a) for a in rs.as_arrays())
    y0, _, aux0 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode=mode)
    y1, _, aux1 = ep_moe.ep_moe_forward(p_rep, x, cfg, rcfg, m, mod,
                                        mode=mode, placement=place)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-5,
                               atol=2e-5)
    el = np.asarray(aux1["expert_load"])
    sl = np.asarray(aux1["slot_load"])
    # logical stats are replication-invariant; slot stats sum to them
    np.testing.assert_allclose(el, np.asarray(aux0["expert_load"]))
    np.testing.assert_allclose(sl.sum(), el.sum())
    # expert 0's load round-robins across its two replica slots
    a, b = sl[rs.rep_pos[0, 0]], sl[rs.rep_pos[0, 1]]
    assert a + b == el[0] and abs(a - b) <= 1.0
    if el[0] >= 2:
        assert float(aux1["split_frac"]) > 0.0
    # post-split rank loads match the host-side equal-split model up to
    # the round-robin integer remainder (±1 assignment per replica)
    np.testing.assert_allclose(np.asarray(aux1["load_d"]),
                               rs.rank_loads(el), atol=1.0)
    # empty spare slots never receive tokens
    assert (sl[rs.slot_owner < 0] == 0).all()


def test_replicated_split_ignores_padding(setup):
    """Chunk-bucket padding must not shift which replica serves a real
    token: the post-split slot stats (and load_d) of a padded batch equal
    those of the truncated batch exactly, with the hot expert split."""
    cfg, p, x, mod = setup
    p = dict(p, router=p["router"].at[:, 0].add(4.0))
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    rs = hot_expert_set()
    m = jnp.full((1, 4), 0.9)
    p_rep = dict(expand_flat(p, rs), router=p["router"])
    place = tuple(jnp.asarray(a) for a in rs.as_arrays())
    x_pad = x.at[:, 8:].set(0.0)          # adversarial: identical padding
    valid = jnp.zeros(x.shape[:2], bool).at[:, :8].set(True)
    y_pad, _, aux_pad = ep_moe.ep_moe_forward(
        p_rep, x_pad, cfg, rcfg, m, mod, mode="dispatch", valid=valid,
        placement=place)
    y_ref, _, aux_ref = ep_moe.ep_moe_forward(
        p_rep, x_pad[:, :8], cfg, rcfg, m, mod[:, :8], mode="dispatch",
        placement=place)
    for k in ("slot_load", "slot_vis", "load_d", "vis_d", "split_frac"):
        np.testing.assert_array_equal(np.asarray(aux_pad[k]),
                                      np.asarray(aux_ref[k]), err_msg=k)
    assert float(aux_pad["split_frac"]) > 0.0
    np.testing.assert_allclose(np.asarray(y_pad[:, :8]),
                               np.asarray(y_ref), rtol=2e-5, atol=2e-5)


def test_replicated_dispatch_flattens_policy_loads(setup):
    """With the hot expert split, the max policy-rank load (what IB_d and
    the FP4 gate see) must not exceed the unsplit one."""
    cfg, p, x, mod = setup
    p = dict(p, router=p["router"].at[:, 0].add(4.0))
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 4), 0.9)
    rs = hot_expert_set()
    p_rep = dict(expand_flat(p, rs), router=p["router"])
    place = tuple(jnp.asarray(a) for a in rs.as_arrays())
    _, _, aux0 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod,
                                       mode="dispatch")
    _, _, aux1 = ep_moe.ep_moe_forward(p_rep, x, cfg, rcfg, m, mod,
                                       mode="dispatch", placement=place)
    el = np.asarray(aux0["expert_load"])
    # rank 0 held experts 0+1 before; after the split half of expert 0
    # moved to rank 2
    l0 = np.asarray(aux0["load_d"])
    l1 = np.asarray(aux1["load_d"])
    assert l1[0] < l0[0]
    assert l1.sum() == l0.sum() == el.sum()


# --------------------------------------------------------------------------
# manager (staged commit, gating, state round-trip)
# --------------------------------------------------------------------------
def _skew_stats(e=8, hot=10.0):
    es = np.zeros((4, 2, e))
    es[:, 0] = np.array([hot, hot * 0.8, 1, 1, 1, 1, 1, 1.0])
    es[:, 1] = es[:, 0] * 0.7
    return es


def test_manager_stages_then_commits():
    rp = ReplicationConfig(replan_every=2, warmup_iters=1, min_gain=0.0)
    mgr = ReplicaManager.from_geometry(8, rp, 4, bytes_per_expert=7)
    mgr.observe(_skew_stats())
    assert mgr.maybe_replan(1) is None              # off-cadence
    before = mgr.device_tables()
    plan = mgr.maybe_replan(2)
    assert plan is not None and plan.n_moved > 0
    # consistency rule: the routable set is unchanged until commit
    after_stage = mgr.device_tables()
    for a, b in zip(before, after_stage):
        assert np.array_equal(a, b)
    assert mgr.n_migrations == 0
    assert mgr.maybe_replan(4) is None              # one plan in flight
    mgr.commit(plan)
    assert mgr.n_migrations == 1
    assert mgr.migrated_bytes == plan.moved_bytes > 0
    assert (mgr.rset.n_rep == plan.new_set.n_rep).all()
    # replanning from the same prediction is a no-op now
    mgr.observe(_skew_stats())
    assert mgr.maybe_replan(6) is None


def test_manager_abort_keeps_old_set():
    rp = ReplicationConfig(replan_every=1, warmup_iters=1, min_gain=0.0)
    mgr = ReplicaManager.from_geometry(8, rp, 4)
    mgr.observe(_skew_stats())
    plan = mgr.maybe_replan(1)
    assert plan is not None
    mgr.abort()
    assert mgr.n_migrations == 0 and (mgr.rset.n_rep == 1).all()
    # a later cadence point can restage
    assert mgr.maybe_replan(2) is not None


def test_manager_cost_gate_blocks_unprofitable_replans():
    class Reject:
        calls = 0

        def accept(self, old, new, n_moved):
            self.calls += 1
            return False

    gate = Reject()
    rp = ReplicationConfig(replan_every=1, warmup_iters=1, min_gain=0.0)
    mgr = ReplicaManager.from_geometry(8, rp, 4, cost_gate=gate)
    mgr.observe(_skew_stats())
    assert mgr.maybe_replan(1) is None
    assert gate.calls == 1 and mgr.n_migrations == 0


def test_costmodel_replan_gate_amortization():
    """Satellite: the ReplanCostGate accepts a replan exactly when the
    predicted layer-time savings over the horizon beat migration_time."""
    from benchmarks import costmodel as cm
    g = cm.KIMI_VL
    gate = cm.ReplanCostGate(g, 8, horizon_iters=100)
    skew = np.array([8.0, 1, 1, 1, 1, 1, 1, 1])
    flat = np.full(8, skew.sum() / 8)
    assert gate.accept(skew, flat, 4)               # big win, few slabs
    assert not gate.accept(skew, skew * 0.999, 64)  # no win, many slabs
    assert gate.accept(skew, flat, 0)               # free moves always ok
    # a one-iteration horizon cannot amortize a full-stack migration
    assert not cm.ReplanCostGate(g, 8, horizon_iters=1).accept(
        skew, flat, 16)


def test_manager_state_roundtrip():
    rp = ReplicationConfig(replan_every=1, warmup_iters=1, min_gain=0.0)
    mgr = ReplicaManager.from_geometry(8, rp, 4, bytes_per_expert=5)
    mgr.observe(_skew_stats())
    plan = mgr.maybe_replan(1)
    mgr.commit(plan)
    mgr.observe_slots(np.ones((2, 2, mgr.n_slots)))
    sd = {k: np.asarray(v) for k, v in mgr.state_dict().items()}
    m2 = ReplicaManager.from_geometry(8, rp, 4, bytes_per_expert=5)
    m2.load_state_dict(sd)
    assert np.array_equal(m2.rset.rep_pos, mgr.rset.rep_pos)
    assert np.array_equal(m2.rset.n_rep, mgr.rset.n_rep)
    assert m2.n_migrations == mgr.n_migrations
    assert np.array_equal(m2.cum_slot_load, mgr.cum_slot_load)
    assert m2.predictor.n_obs == mgr.predictor.n_obs
    m2.reset()
    assert (m2.rset.n_rep == 1).all() and m2.n_migrations == 0


# --------------------------------------------------------------------------
# engine end-to-end (identity bitwise, live replication, checkpoints)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    import repro.models.transformer as tf
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


def _bias_router(params, hot=3.0):
    out = dict(params)
    blocks = dict(out["blocks"])
    for lname, lp in blocks.items():
        if isinstance(lp, dict) and "moe" in lp:
            lp = dict(lp)
            moe = dict(lp["moe"])
            moe["router"] = moe["router"].at[..., 0].add(hot) \
                .at[..., 1].add(hot * 0.7)
            lp["moe"] = moe
        blocks[lname] = lp
    out["blocks"] = blocks
    return out


@pytest.mark.slow
def test_engine_identity_replication_matches_baseline(model):
    """A replica engine that never replans generates exactly what a
    manager-free engine does — with and without spare slots."""
    from repro.serving.engine import Engine
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)

    eng0 = Engine(cfg, params, rcfg, max_slots=3, max_len=32, virtual_ep=4)
    for r in _reqs(cfg):
        eng0.submit(r)
    g0 = [r.generated for r in sorted(eng0.run(), key=lambda r: r.uid)]

    for spare, reps in ((0, 1), (1, 2)):
        mgr = ReplicaManager(cfg, ReplicationConfig(
            enabled=False, spare_per_rank=spare, max_replicas=reps), 4)
        p = expand_moe_params(params, mgr.rset) if spare else params
        eng1 = Engine(cfg, p, rcfg, max_slots=3, max_len=32, placement=mgr)
        for r in _reqs(cfg):
            eng1.submit(r)
        g1 = [r.generated for r in sorted(eng1.run(), key=lambda r: r.uid)]
        assert g0 == g1, (spare, reps)
        assert mgr.n_migrations == 0


@pytest.mark.slow
def test_engine_refuses_unexpanded_params(model):
    from repro.serving.engine import Engine
    cfg, params = model
    mgr = ReplicaManager(cfg, ReplicationConfig(spare_per_rank=1), 4)
    with pytest.raises(AssertionError, match="expand_moe_params"):
        Engine(cfg, params, ReaLBConfig(), max_slots=3, max_len=32,
               placement=mgr)


@pytest.mark.slow
def test_engine_aborts_staged_plan_on_failed_apply(model, monkeypatch):
    """A failed slab gather must not leave the manager stuck with a
    pending plan: the engine aborts it, the old set stays routable, and a
    later cadence point can replan."""
    from repro.placement import migrate as pmigrate
    from repro.serving.engine import Engine
    cfg, params = model
    params = _bias_router(params)
    mgr = ReplicaManager(cfg, ReplicationConfig(
        replan_every=3, warmup_iters=2, min_gain=0.0), 4)
    eng = Engine(cfg, expand_moe_params(params, mgr.rset),
                 ReaLBConfig(gate_gamma=4), max_slots=3, max_len=32,
                 placement=mgr)
    for r in _reqs(cfg, n=8):
        eng.submit(r)
    orig = pmigrate.apply_to_params

    def boom(params, plan):
        raise RuntimeError("simulated gather failure")

    monkeypatch.setattr(pmigrate, "apply_to_params", boom)
    with pytest.raises(RuntimeError, match="gather failure"):
        eng.run()
    assert mgr._pending is None and mgr.n_migrations == 0
    assert (mgr.rset.n_rep == 1).all()          # old set still routable
    monkeypatch.setattr(pmigrate, "apply_to_params", orig)
    done = eng.run()                             # replans and finishes
    assert len(done) == 8
    assert mgr.n_migrations >= 1


@pytest.mark.slow
def test_engine_live_replication_beats_placement_ib(model):
    """Acceptance: on a hot-expert stream the replica engine performs
    live replica adds and ends with lower prefill IB than the bijective
    placement engine on the same stream."""
    from repro.configs import PlacementConfig
    from repro.placement import PlacementManager
    from repro.serving.engine import Engine
    from repro.serving.telemetry import Telemetry
    cfg, params = model
    params = _bias_router(params)
    rcfg = ReaLBConfig(gate_gamma=4)

    def run(mgr, p):
        tel = Telemetry()
        eng = Engine(cfg, p, rcfg, max_slots=4, max_len=32, placement=mgr,
                     telemetry=tel, virtual_ep=4)
        for r in _reqs(cfg, n=16, seed=3):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 16
        pre = [s.ib_global for s in eng.stats if s.phase == "prefill"]
        return float(np.mean(pre)), eng

    pmgr = PlacementManager(cfg, PlacementConfig(
        planner="least_loaded", replan_every=3, warmup_iters=2,
        min_gain=0.0), 4)
    ib_p, _ = run(pmgr, params)

    rmgr = ReplicaManager(cfg, ReplicationConfig(
        replan_every=3, warmup_iters=2, min_gain=0.0, spare_per_rank=1,
        max_replicas=2), 4)
    ib_r, eng_r = run(rmgr, expand_moe_params(params, rmgr.rset))
    assert rmgr.n_migrations >= 1 and rmgr.migrated_bytes > 0
    assert any(s.split_frac > 0 for s in eng_r.stats)
    assert rmgr.cum_slot_load.sum() > 0
    assert ib_r < ib_p, (ib_r, ib_p)


@pytest.mark.slow
def test_engine_replication_checkpoint_roundtrip(model):
    from repro.serving.engine import Engine
    cfg, params = model
    params = _bias_router(params)
    rcfg = ReaLBConfig(gate_gamma=4)
    mgr = ReplicaManager(cfg, ReplicationConfig(
        replan_every=3, warmup_iters=2, min_gain=0.0), 4)
    eng = Engine(cfg, expand_moe_params(params, mgr.rset), rcfg,
                 max_slots=3, max_len=32, placement=mgr)
    for r in _reqs(cfg, n=10):
        eng.submit(r)
    eng.run()
    assert mgr.n_migrations >= 1

    with tempfile.TemporaryDirectory() as d:
        eng.save_checkpoint(d, 5)
        # same-kind restore resumes the exact replica set + weights
        mgr2 = ReplicaManager(cfg, ReplicationConfig(), 4)
        eng2 = Engine(cfg, expand_moe_params(params, mgr2.rset), rcfg,
                      max_slots=3, max_len=32, placement=mgr2)
        assert eng2.load_checkpoint(d) == 5
        assert np.array_equal(mgr2.rset.rep_pos, mgr.rset.rep_pos)
        assert mgr2.n_migrations == mgr.n_migrations
        w0 = np.asarray(eng.params["blocks"]["layer0"]["moe"]["w_gate"])
        w1 = np.asarray(eng2.params["blocks"]["layer0"]["moe"]["w_gate"])
        assert np.array_equal(w0, w1)
        # a manager-free engine must refuse the replicated checkpoint
        eng3 = Engine(cfg, params, rcfg, max_slots=3, max_len=32)
        with pytest.raises(ValueError, match="replication"):
            eng3.load_checkpoint(d)
        # and so must a bijective-placement engine (replicated↔bijective)
        from repro.configs import PlacementConfig
        from repro.placement import PlacementManager
        pmgr = PlacementManager(cfg, PlacementConfig(), 4)
        eng4 = Engine(cfg, params, rcfg, max_slots=3, max_len=32,
                      placement=pmgr)
        with pytest.raises(ValueError, match="replication"):
            eng4.load_checkpoint(d)

    # the reverse direction: a replica engine restoring a checkpoint
    # written WITHOUT any manager resets cleanly to identity and
    # re-expands the logical weights into its slot layout
    with tempfile.TemporaryDirectory() as d:
        eng_plain = Engine(cfg, params, rcfg, max_slots=3, max_len=32)
        eng_plain.save_checkpoint(d, 1)
        mgr5 = ReplicaManager(cfg, ReplicationConfig(), 4)
        mgr5.rset = mgr.rset                    # pretend it had replicated
        eng5 = Engine(cfg, expand_moe_params(params, mgr5.rset), rcfg,
                      max_slots=3, max_len=32, placement=mgr5)
        assert eng5.load_checkpoint(d) == 1
        assert (mgr5.rset.n_rep == 1).all() and mgr5.n_migrations == 0
        w = np.asarray(eng5.params["blocks"]["layer0"]["moe"]["w_gate"])
        assert w.shape[-3] == mgr5.n_slots      # re-expanded
        # a bijective-placement checkpoint is refused by a replica engine
        from repro.configs import PlacementConfig
        from repro.placement import PlacementManager
        pmgr = PlacementManager(cfg, PlacementConfig(
            planner="least_loaded", replan_every=2, warmup_iters=1,
            min_gain=0.0), 4)
        eng6 = Engine(cfg, params, rcfg, max_slots=3, max_len=32,
                      placement=pmgr)
        for r in _reqs(cfg, n=6):
            eng6.submit(r)
        eng6.run()
        eng6.save_checkpoint(d, 2)
        mgr7 = ReplicaManager(cfg, ReplicationConfig(), 4)
        eng7 = Engine(cfg, expand_moe_params(params, mgr7.rset), rcfg,
                      max_slots=3, max_len=32, placement=mgr7)
        with pytest.raises(ValueError, match="placement"):
            eng7.load_checkpoint(d)
