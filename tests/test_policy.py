"""ReaLB control policy invariants.

Property tests run under ``hypothesis`` when it is installed; a seeded
plain-pytest subset of each property exercises the same check functions so
collection and coverage never depend on the optional package.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ReaLBConfig
from repro.core.policy import lb_gate, realb_policy

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


# -- shared check bodies (hypothesis and plain tests both call these) -------
def check_policy_invariants(load, vis_frac, m):
    vis = load * vis_frac
    cfg = ReaLBConfig()
    dec = realb_policy(jnp.asarray(load), jnp.asarray(vis), jnp.asarray(m),
                       cfg)
    m_new = np.asarray(dec.m_new)
    use = np.asarray(dec.use_fp4)
    hot = np.asarray(dec.hotspots)
    ib = np.asarray(dec.ib_d)

    # M_d stays in [md_min, 1]
    assert np.all(m_new >= cfg.md_min - 1e-9)
    assert np.all(m_new <= 1.0 + 1e-9)
    # compression only on hotspots, and hotspots match the definition
    assert not np.any(use & ~hot)
    np.testing.assert_array_equal(hot, ib > cfg.capacity_c)
    # gate: no compression when total tokens below Γ
    if load.sum() <= cfg.gate_gamma:
        assert not np.any(use)
        np.testing.assert_allclose(m_new, np.asarray(m, np.float32),
                                   atol=1e-7)  # held
    # IB_global is the max of per-rank imbalance
    assert abs(float(dec.ib_global) - ib.max()) < 1e-5


def check_aimd_direction(load):
    """congested ⇒ every M_d halves; calm ⇒ every M_d rises by md_add."""
    load = np.round(load)
    cfg = ReaLBConfig(gate_gamma=0)
    m = jnp.full((8,), 0.8)
    vis = jnp.asarray(load)
    dec = realb_policy(jnp.asarray(load), vis, m, cfg)
    if load.sum() == 0:
        return
    m_new = np.asarray(dec.m_new)
    if float(dec.ib_global) > cfg.tau:
        np.testing.assert_allclose(m_new, 0.4, atol=1e-6)
    else:
        np.testing.assert_allclose(m_new, 0.9, atol=1e-6)


# -- hypothesis property tests (optional) -----------------------------------
if HAVE_HYPOTHESIS:
    loads = hnp.arrays(np.float64, (8,),
                       elements=st.floats(0, 1e6, allow_nan=False))
    ms = hnp.arrays(np.float64, (8,), elements=st.floats(0, 1))

    @hypothesis.given(loads, st.data())
    @hypothesis.settings(deadline=None, max_examples=200)
    def test_policy_invariants(load, data):
        vis_frac = data.draw(hnp.arrays(np.float64, (8,),
                                        elements=st.floats(0, 1)))
        m = data.draw(ms)
        check_policy_invariants(load, vis_frac, m)

    @hypothesis.given(hnp.arrays(np.float64, (8,),
                                 elements=st.floats(1, 1e6)))  # token counts
    @hypothesis.settings(deadline=None, max_examples=100)
    def test_aimd_direction(load):
        check_aimd_direction(load)


# -- plain-pytest subset (always runs) --------------------------------------
@pytest.mark.parametrize("seed", range(25))
def test_policy_invariants_sampled(seed):
    rng = np.random.default_rng(seed)
    # mix uniform-magnitude and heavy-tailed loads, plus degenerate corners
    if seed % 5 == 0:
        load = np.zeros(8)
    elif seed % 5 == 1:
        load = rng.uniform(0, 10, 8)          # below-gate totals
    else:
        load = np.exp(rng.uniform(0, np.log(1e6), 8))
    check_policy_invariants(load, rng.uniform(0, 1, 8), rng.uniform(0, 1, 8))


@pytest.mark.parametrize("seed", range(10))
def test_aimd_direction_sampled(seed):
    rng = np.random.default_rng(100 + seed)
    check_aimd_direction(np.exp(rng.uniform(0, np.log(1e6), 8)))


def test_monotone_in_modality_threshold():
    """Lower M_d ⇒ (weakly) more ranks compressed."""
    load = jnp.asarray([4000.0, 1000, 1000, 1000, 900, 900, 900, 900])
    vis = load * jnp.asarray([0.8, 0.1, 0.2, 0.9, 0.5, 0.5, 0.5, 0.5])
    cfg = ReaLBConfig(gate_gamma=0, adaptive=False)
    prev = -1
    for m_val in (1.0, 0.9, 0.5, 0.1, 0.0):
        dec = realb_policy(load, vis, jnp.full((8,), m_val), cfg)
        n = int(np.asarray(dec.use_fp4).sum())
        assert n >= prev
        prev = n


def test_disabled_never_compresses():
    cfg = ReaLBConfig(enabled=False, gate_gamma=0)
    load = jnp.asarray([1e5, 1.0, 1.0, 1.0])
    dec = realb_policy(load, load, jnp.zeros(4), cfg)
    assert not np.any(np.asarray(dec.use_fp4))


def test_gate_threshold():
    cfg = ReaLBConfig(gate_gamma=2048)
    assert not bool(lb_gate(jnp.asarray(2048.0), cfg))
    assert bool(lb_gate(jnp.asarray(2049.0), cfg))
