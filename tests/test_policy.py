"""ReaLB control policy invariants (hypothesis property tests)."""
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.configs import ReaLBConfig
from repro.core.policy import lb_gate, realb_policy

loads = hnp.arrays(np.float64, (8,),
                   elements=st.floats(0, 1e6, allow_nan=False))
ms = hnp.arrays(np.float64, (8,), elements=st.floats(0, 1))


@hypothesis.given(loads, st.data())
@hypothesis.settings(deadline=None, max_examples=200)
def test_policy_invariants(load, data):
    vis_frac = data.draw(hnp.arrays(np.float64, (8,),
                                    elements=st.floats(0, 1)))
    m = data.draw(ms)
    vis = load * vis_frac
    cfg = ReaLBConfig()
    dec = realb_policy(jnp.asarray(load), jnp.asarray(vis), jnp.asarray(m),
                       cfg)
    m_new = np.asarray(dec.m_new)
    use = np.asarray(dec.use_fp4)
    hot = np.asarray(dec.hotspots)
    ib = np.asarray(dec.ib_d)

    # M_d stays in [md_min, 1]
    assert np.all(m_new >= cfg.md_min - 1e-9)
    assert np.all(m_new <= 1.0 + 1e-9)
    # compression only on hotspots, and hotspots match the definition
    assert not np.any(use & ~hot)
    np.testing.assert_array_equal(hot, ib > cfg.capacity_c)
    # gate: no compression when total tokens below Γ
    if load.sum() <= cfg.gate_gamma:
        assert not np.any(use)
        np.testing.assert_allclose(m_new, np.asarray(m, np.float32),
                                   atol=1e-7)  # held
    # IB_global is the max of per-rank imbalance
    assert abs(float(dec.ib_global) - ib.max()) < 1e-5


@hypothesis.given(hnp.arrays(np.float64, (8,),
                             elements=st.floats(1, 1e6)))  # token counts
@hypothesis.settings(deadline=None, max_examples=100)
def test_aimd_direction(load):
    """congested ⇒ every M_d halves; calm ⇒ every M_d rises by md_add."""
    load = np.round(load)
    cfg = ReaLBConfig(gate_gamma=0)
    m = jnp.full((8,), 0.8)
    vis = jnp.asarray(load)
    dec = realb_policy(jnp.asarray(load), vis, m, cfg)
    if load.sum() == 0:
        return
    m_new = np.asarray(dec.m_new)
    if float(dec.ib_global) > cfg.tau:
        np.testing.assert_allclose(m_new, 0.4, atol=1e-6)
    else:
        np.testing.assert_allclose(m_new, 0.9, atol=1e-6)


def test_monotone_in_modality_threshold():
    """Lower M_d ⇒ (weakly) more ranks compressed."""
    load = jnp.asarray([4000.0, 1000, 1000, 1000, 900, 900, 900, 900])
    vis = load * jnp.asarray([0.8, 0.1, 0.2, 0.9, 0.5, 0.5, 0.5, 0.5])
    cfg = ReaLBConfig(gate_gamma=0, adaptive=False)
    prev = -1
    for m_val in (1.0, 0.9, 0.5, 0.1, 0.0):
        dec = realb_policy(load, vis, jnp.full((8,), m_val), cfg)
        n = int(np.asarray(dec.use_fp4).sum())
        assert n >= prev
        prev = n


def test_disabled_never_compresses():
    cfg = ReaLBConfig(enabled=False, gate_gamma=0)
    load = jnp.asarray([1e5, 1.0, 1.0, 1.0])
    dec = realb_policy(load, load, jnp.zeros(4), cfg)
    assert not np.any(np.asarray(dec.use_fp4))


def test_gate_threshold():
    cfg = ReaLBConfig(gate_gamma=2048)
    assert not bool(lb_gate(jnp.asarray(2048.0), cfg))
    assert bool(lb_gate(jnp.asarray(2049.0), cfg))
