"""Jaxpr auditor: callback / f64 / widening detection, the collective
census with scan multipliers, and the hot-path audit of the real MoE
layer (local path must be collective-free and clean)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jaxpr_audit import (DEFAULT_WIDEN_ALLOWLIST,
                                        audit_jaxpr,
                                        collective_census_jaxpr)
from repro.configs import ReaLBConfig, get_config, reduced
from repro.core import ep_moe


# --------------------------------------------------------------------------
# rule detection on handcrafted traces
# --------------------------------------------------------------------------
def test_clean_fn_passes():
    rep = audit_jaxpr(jax.make_jaxpr(lambda x: jnp.sin(x) * 2)(
        jnp.ones(4)))
    assert rep.ok and rep.n_eqns > 0 and rep.census == {}


def test_callback_flagged():
    def f(x):
        y = jax.pure_callback(lambda v: np.asarray(v) + 1, x, x)
        return y * 2

    rep = audit_jaxpr(jax.make_jaxpr(f)(jnp.ones(4, jnp.float32)))
    assert [v.kind for v in rep.violations] == ["callback"]
    assert "round trip" in rep.violations[0].detail


def test_f64_flagged_and_waivable():
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(lambda x: x * 2.0)(np.ones(4, np.float64))
    rep = audit_jaxpr(closed)
    assert any(v.kind == "f64" for v in rep.violations)
    assert audit_jaxpr(closed, allow_f64=True).ok


def test_widening_violation_on_dispatch_path_only():
    def f(x):
        with jax.named_scope("dispatch"):
            return x.astype(jnp.float32) * 2

    closed = jax.make_jaxpr(f)(jnp.ones(4, jnp.bfloat16))
    rep = audit_jaxpr(closed)
    assert [v.kind for v in rep.violations] == ["widening"]
    assert "dispatch" in rep.violations[0].where
    # same widening is legal when the scope names an allowlisted phase
    assert "route" in DEFAULT_WIDEN_ALLOWLIST

    def g(x):
        with jax.named_scope("dispatch"), jax.named_scope("route"):
            return x.astype(jnp.float32) * 2

    rep2 = audit_jaxpr(jax.make_jaxpr(g)(jnp.ones(4, jnp.bfloat16)))
    assert rep2.ok
    # ...and recorded either way
    assert rep.widenings and rep2.widenings
    assert rep.widenings[0]["src"] == "bfloat16"


def test_widening_off_fp4_path_recorded_not_flagged():
    def f(x):
        with jax.named_scope("misc"):
            return x.astype(jnp.float32) * 2

    rep = audit_jaxpr(jax.make_jaxpr(f)(jnp.ones(4, jnp.bfloat16)))
    assert rep.ok and len(rep.widenings) == 1


def test_subbyte_dequant_widening_always_legal():
    def f(x):
        with jax.named_scope("dispatch"):
            return x.astype(jnp.bfloat16) * 2

    rep = audit_jaxpr(jax.make_jaxpr(f)(
        jnp.ones(4, jnp.float8_e4m3fn)))
    assert rep.ok and rep.widenings          # seen, but it IS the dequant


# --------------------------------------------------------------------------
# collective census
# --------------------------------------------------------------------------
def _shard_mapped_psum():
    from repro.models.common import shard_map
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    P = jax.sharding.PartitionSpec

    def inner(x):
        def step(c, _):
            return c + jax.lax.psum(x, "x"), None
        y, _ = jax.lax.scan(step, jnp.zeros_like(x), None, length=3)
        return y

    return shard_map(inner, mesh=mesh, in_specs=(P("x"),),
                     out_specs=P("x"), check_rep=False)


def test_census_multiplies_scan_trips():
    f = _shard_mapped_psum()
    closed = jax.make_jaxpr(f)(jnp.ones(4, jnp.float32))
    census = collective_census_jaxpr(closed)
    assert census == {"psum": {"count": 3, "bytes": 3 * 4 * 4}}
    # the full audit carries the same census
    assert audit_jaxpr(closed, allow_f64=True).census == census


# --------------------------------------------------------------------------
# the real hot path
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def moe():
    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, F, E = cfg.d_model, e.d_ff, e.num_experts
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.2,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
        "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F),
    }
    x = jax.random.normal(ks[4], (2, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (2, 16))
    return cfg, p, x, mod


def test_local_moe_path_audits_clean(moe):
    """Single-host ep_moe (FP4 policy active): no callbacks, no f64, no
    collectives, every dispatch-path widening allowlisted."""
    cfg, p, x, mod = moe
    rcfg = ReaLBConfig(gate_gamma=1e-6)      # policy ON: fp4 branch live
    m = jnp.full((1, 1), 0.9)
    closed = jax.make_jaxpr(
        lambda p_, x_, m_: ep_moe.ep_moe_forward(
            p_, x_, cfg, rcfg, m_, mod, mode="dispatch"))(p, x, m)
    rep = audit_jaxpr(closed)
    assert rep.ok, [v.format() for v in rep.violations]
    assert rep.census == {}, "local path must not emit collectives"
    assert rep.n_eqns > 50
