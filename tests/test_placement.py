"""Placement subsystem: tables, planners, predictor, migration, the
placement-threaded MoE layer (identity ≡ bitwise, permutation ≡ allclose
with permuted stats) and the serving engine's live-migration loop."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (PlacementConfig, ReaLBConfig, get_config,
                           reduced)
from repro.core import ep_moe
from repro.placement import (EWMAPredictor, PlacementManager,
                             PlacementTable, apply_to_params, diff,
                             plan_least_loaded, plan_modality_aware,
                             plan_placement)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("olmoe-1b-7b"))
    e = cfg.moe
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    D, E, F = cfg.d_model, e.num_experts, e.d_ff
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.2,
         "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
         "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
         "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)}
    x = jax.random.normal(ks[4], (2, 16, D)) * 0.5
    mod = jax.random.bernoulli(ks[5], 0.6, (2, 16))
    return cfg, p, x, mod


def random_table(e: int, ep: int, seed: int = 0) -> PlacementTable:
    rng = np.random.default_rng(seed)
    owner = rng.permutation(e)              # physical row -> logical expert
    pos = np.empty(e, np.int64)
    pos[owner] = np.arange(e)               # logical -> physical
    e_loc = e // ep
    return PlacementTable(pos // e_loc, pos % e_loc, ep)


# --------------------------------------------------------------------------
# table
# --------------------------------------------------------------------------
def test_table_identity_roundtrip():
    t = PlacementTable.identity(8, 4)
    assert np.array_equal(t.pos, np.arange(8))
    assert np.array_equal(t.owner, np.arange(8))
    assert t.e_loc == 2


def test_table_owner_inverts_pos():
    t = random_table(16, 4, seed=3)
    assert np.array_equal(t.pos[t.owner], np.arange(16))
    assert np.array_equal(np.sort(t.pos), np.arange(16))


def test_table_rejects_overfull_rank():
    with pytest.raises(AssertionError):
        PlacementTable(np.zeros(8, np.int32), np.arange(8, dtype=np.int32),
                       4)   # all experts on rank 0


def test_table_rank_loads():
    t = PlacementTable.from_ranks(np.array([0, 0, 1, 1]), 2)
    np.testing.assert_allclose(t.rank_loads(np.array([1., 2, 3, 4])),
                               [3.0, 7.0])


# --------------------------------------------------------------------------
# planners
# --------------------------------------------------------------------------
def test_least_loaded_beats_identity_on_skew():
    load = np.array([10, 8, 1, 1, 1, 1, 1, 1.0])   # identity: rank0 = 18
    ident = PlacementTable.identity(8, 4)
    t = plan_least_loaded(load, 4)
    assert t.rank_loads(load).max() < ident.rank_loads(load).max()
    assert np.bincount(t.e2r, minlength=4).tolist() == [2, 2, 2, 2]


def test_modality_aware_concentrates_vision():
    load = np.ones(8)
    vis = np.array([0.9, 0.8, 0.85, 0.95, 0.0, 0.1, 0.05, 0.0])
    t = plan_modality_aware(load, vis, 4)
    rank_vis = t.rank_loads(vis)
    # the four vision-heavy experts land on two ranks, not four
    assert (rank_vis > 0.5).sum() == 2, rank_vis


def test_modality_aware_rebalances_load():
    load = np.array([8, 1, 1, 1, 4, 1, 1, 1.0])
    vis = load * 0.9                               # uniform vision ratio
    t = plan_modality_aware(load, vis, 4, vis_tol=0.5)
    ident = PlacementTable.identity(8, 4)
    assert t.rank_loads(load).max() <= ident.rank_loads(load).max()


def test_plan_placement_dispatch_and_unknown():
    t = plan_placement("identity", np.ones(8), 4)
    assert np.array_equal(t.e2r, PlacementTable.identity(8, 4).e2r)
    with pytest.raises(ValueError):
        plan_placement("nope", np.ones(8), 4)


# --------------------------------------------------------------------------
# predictor
# --------------------------------------------------------------------------
def test_predictor_ewma_math():
    pred = EWMAPredictor(4, alpha=0.5)
    pred.observe(np.array([[4.0, 0, 0, 0]]))
    pred.observe(np.array([[0, 4.0, 0, 0]]))
    load, _ = pred.predict()
    np.testing.assert_allclose(load, [0.5, 0.5, 0, 0])
    pred.observe(np.zeros((1, 4)))                 # ignored, not decayed
    np.testing.assert_allclose(pred.predict()[0], load)


def test_predictor_state_roundtrip():
    pred = EWMAPredictor(4, alpha=0.3)
    pred.observe(np.array([[1.0, 2, 3, 4]]), np.array([[0.0, 1, 1, 2]]))
    sd = {k: np.asarray(v) for k, v in pred.state_dict().items()}
    p2 = EWMAPredictor(4)
    p2.load_state_dict(sd)
    np.testing.assert_allclose(p2.predict()[0], pred.predict()[0])
    assert p2.n_obs == pred.n_obs and p2.alpha == pred.alpha


# --------------------------------------------------------------------------
# migration
# --------------------------------------------------------------------------
def test_diff_identity_is_noop():
    t = PlacementTable.identity(8, 4)
    plan = diff(t, t, bytes_per_expert=10)
    assert plan.is_noop and plan.moved_bytes == 0
    assert np.array_equal(plan.gather_idx, np.arange(8))


def test_apply_to_params_permutes_stacked_weights():
    t_old = PlacementTable.identity(8, 4)
    t_new = random_table(8, 4, seed=1)
    plan = diff(t_old, t_new, bytes_per_expert=7)
    assert plan.moved_bytes == 7 * plan.n_moved
    w = np.arange(2 * 8 * 3 * 5, dtype=np.float32).reshape(2, 8, 3, 5)
    params = {"blocks": {"layer0": {"moe": {
        "router": np.zeros((3, 8)), "w_gate": w, "w_up": w + 1,
        "w_down": np.swapaxes(w, 2, 3)}}}}
    out = apply_to_params(params, plan)
    got = out["blocks"]["layer0"]["moe"]["w_gate"]
    for p_new in range(8):
        expert = t_new.owner[p_new]
        np.testing.assert_array_equal(got[:, p_new],
                                      w[:, t_old.pos[expert]])
    # router never migrates
    assert out["blocks"]["layer0"]["moe"]["router"] is \
        params["blocks"]["layer0"]["moe"]["router"]


# --------------------------------------------------------------------------
# MoE layer invariance
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["dispatch", "broadcast"])
def test_identity_table_bitwise_equal(setup, mode):
    """The placement-threaded layer with the identity table must be
    bitwise-identical to the default (placement=None) path."""
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 1), 0.9)
    e = cfg.moe.num_experts
    ident = ep_moe.identity_placement(e, 1)
    y0, m0, aux0 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode=mode)
    y1, m1, aux1 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode=mode,
                                         placement=ident)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))
    assert np.array_equal(np.asarray(m0), np.asarray(m1))
    for k in ("load_d", "vis_d", "drop_frac", "lb_loss"):
        assert np.array_equal(np.asarray(aux0[k]), np.asarray(aux1[k])), k


@pytest.mark.parametrize("mode", ["dispatch", "broadcast"])
def test_permuted_table_allclose_with_permuted_stats(setup, mode):
    """Any permutation table (with correspondingly permuted weight slabs)
    yields allclose outputs, and the per-rank load/vision stats move with
    the experts (virtual 4-rank policy topology)."""
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    e = cfg.moe.num_experts
    vep = 4
    m = jnp.full((1, vep), 0.9)
    table = random_table(e, vep, seed=2)
    perm = table.owner                      # physical row -> logical expert
    p_perm = dict(p, w_gate=p["w_gate"][perm], w_up=p["w_up"][perm],
                  w_down=p["w_down"][perm])
    place = (jnp.asarray(table.e2r), jnp.asarray(table.local_slot))
    y0, _, aux0 = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod, mode=mode)
    y1, _, aux1 = ep_moe.ep_moe_forward(p_perm, x, cfg, rcfg, m, mod,
                                        mode=mode, placement=place)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-5,
                               atol=2e-5)
    el = np.asarray(aux0["expert_load"])
    ev = np.asarray(aux0["expert_vis"])
    np.testing.assert_allclose(np.asarray(aux1["load_d"]),
                               table.rank_loads(el))
    np.testing.assert_allclose(np.asarray(aux1["vis_d"]),
                               table.rank_loads(ev))
    # logical-expert stats are placement-invariant
    np.testing.assert_allclose(np.asarray(aux1["expert_load"]), el)


def test_expert_load_aux_totals(setup):
    cfg, p, x, mod = setup
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    m = jnp.full((1, 1), 0.9)
    _, _, aux = ep_moe.ep_moe_forward(p, x, cfg, rcfg, m, mod,
                                      mode="dispatch")
    el = np.asarray(aux["expert_load"])
    assert el.shape == (cfg.moe.num_experts,)
    assert el.sum() == x.shape[0] * x.shape[1] * cfg.moe.top_k
    assert np.asarray(aux["expert_vis"]).sum() == \
        float(np.asarray(mod).sum()) * cfg.moe.top_k


# --------------------------------------------------------------------------
# manager
# --------------------------------------------------------------------------
def test_manager_replans_on_skew_and_respects_cadence():
    cfg = reduced(get_config("olmoe-1b-7b"))
    mgr = PlacementManager(cfg, PlacementConfig(replan_every=2,
                                                warmup_iters=1), 4)
    es = np.zeros((4, 2, 8))
    es[:, 0] = np.array([10, 8, 1, 1, 1, 1, 1, 1.0])
    mgr.observe(es)
    assert mgr.maybe_replan(1) is None            # off-cadence
    plan = mgr.maybe_replan(2)
    assert plan is not None and plan.n_moved > 0
    # staged: routable table and accounting unchanged until commit
    assert mgr.in_flight is plan and mgr.n_migrations == 0
    assert mgr.maybe_replan(4) is None            # one plan in flight
    mgr.commit(plan)
    assert mgr.in_flight is None
    assert mgr.n_migrations == 1
    assert mgr.migrated_bytes == plan.moved_bytes > 0
    mgr.observe(es)
    assert mgr.maybe_replan(6) is None            # plan already optimal


def test_manager_cost_gate_amortized_gain_guard():
    """ROADMAP satellite: replans fire only when the cost model predicts
    layer-time savings over the replan horizon above the migration cost."""
    from benchmarks import costmodel as cm
    cfg = reduced(get_config("olmoe-1b-7b"))
    es = np.zeros((4, 2, 8))
    es[:, 0] = np.array([10, 8, 1, 1, 1, 1, 1, 1.0])

    def mgr_with(gate):
        m = PlacementManager(cfg, PlacementConfig(
            replan_every=2, warmup_iters=1, min_gain=0.0), 4,
            cost_gate=gate)
        m.observe(es)
        return m

    g = cm.KIMI_VL
    # a generous horizon amortizes the move -> the replan fires
    open_gate = cm.ReplanCostGate(g, 4, horizon_iters=10_000)
    assert mgr_with(open_gate).maybe_replan(2) is not None
    # a one-iteration horizon cannot pay for a full-stack migration
    tight_gate = cm.ReplanCostGate(g, 4, horizon_iters=1,
                                   tokens_per_iter=64.0)
    m = mgr_with(tight_gate)
    assert m.maybe_replan(2) is None
    assert m.n_migrations == 0
    # ... and without a gate the same skew migrates immediately
    assert mgr_with(None).maybe_replan(2) is not None


def test_manager_identity_planner_never_migrates():
    cfg = reduced(get_config("olmoe-1b-7b"))
    mgr = PlacementManager(cfg, PlacementConfig(planner="identity",
                                                replan_every=1,
                                                warmup_iters=0), 4)
    es = np.zeros((4, 2, 8))
    es[:, 0] = np.arange(8) + 1.0
    for it in range(4):
        mgr.observe(es)
        assert mgr.maybe_replan(it) is None
    assert mgr.n_migrations == 0


# --------------------------------------------------------------------------
# engine end-to-end (live migration + checkpoint resume)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    import repro.models.transformer as tf
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


@pytest.mark.slow
def test_engine_identity_placement_matches_baseline(model):
    """An identity-planner engine generates exactly what a placement-free
    engine does (same virtual policy topology)."""
    from repro.serving.engine import Engine
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)

    eng0 = Engine(cfg, params, rcfg, max_slots=3, max_len=32, virtual_ep=4)
    for r in _reqs(cfg):
        eng0.submit(r)
    g0 = [r.generated for r in sorted(eng0.run(), key=lambda r: r.uid)]

    mgr = PlacementManager(cfg, PlacementConfig(planner="identity"), 4)
    eng1 = Engine(cfg, params, rcfg, max_slots=3, max_len=32, placement=mgr)
    for r in _reqs(cfg):
        eng1.submit(r)
    g1 = [r.generated for r in sorted(eng1.run(), key=lambda r: r.uid)]
    assert g0 == g1
    assert mgr.n_migrations == 0
    assert eng1.stats and all(s.migration_bytes == 0 for s in eng1.stats)


@pytest.mark.slow
def test_engine_live_migration_and_checkpoint_resume(model):
    from repro.serving.engine import Engine
    from repro.serving.telemetry import Telemetry
    from repro.workloads import IterationCostModel, VirtualClock
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=4)
    mgr = PlacementManager(cfg, PlacementConfig(planner="least_loaded",
                                                replan_every=3,
                                                warmup_iters=2,
                                                min_gain=0.0), 4)
    tel = Telemetry()
    eng = Engine(cfg, params, rcfg, max_slots=3, max_len=32, placement=mgr,
                 telemetry=tel, clock=VirtualClock(),
                 cost_model=IterationCostModel())
    for r in _reqs(cfg, n=10):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 10
    assert all(len(r.generated) == r.max_new_tokens for r in done)
    assert mgr.n_migrations >= 1
    assert tel.migration_bytes_total == mgr.migrated_bytes > 0
    s = tel.summary()
    assert s["migration_bytes_total"] > 0 and s["n_migrations"] >= 1
    assert "drop_frac" in s and "p50" in s["drop_frac"]
    # migration time was charged to the virtual clock via IterStats
    assert sum(st.migration_s for st in eng.stats) > 0

    with tempfile.TemporaryDirectory() as d:
        eng.save_checkpoint(d, 3)
        mgr2 = PlacementManager(cfg, PlacementConfig(
            planner="least_loaded"), 4)
        eng2 = Engine(cfg, params, rcfg, max_slots=3, max_len=32,
                      placement=mgr2)
        assert eng2.load_checkpoint(d) == 3
        # restored engine resumes with the same placement, not identity
        assert np.array_equal(mgr2.table.e2r, mgr.table.e2r)
        assert np.array_equal(mgr2.table.local_slot, mgr.table.local_slot)
        assert mgr2.n_migrations == mgr.n_migrations
        w0 = np.asarray(eng.params["blocks"]["layer0"]["moe"]["w_gate"])
        w1 = np.asarray(eng2.params["blocks"]["layer0"]["moe"]["w_gate"])
        assert np.array_equal(w0, w1)
        # a placement-free engine must refuse the permuted checkpoint
        # instead of silently routing the identity table through it
        eng3 = Engine(cfg, params, rcfg, max_slots=3, max_len=32)
        with pytest.raises(ValueError, match="placement"):
            eng3.load_checkpoint(d)

    # the reverse direction: a placement engine restoring a checkpoint
    # written WITHOUT placement resets to a clean identity state
    with tempfile.TemporaryDirectory() as d:
        eng_plain = Engine(cfg, params, rcfg, max_slots=3, max_len=32)
        eng_plain.save_checkpoint(d, 1)
        mgr4 = PlacementManager(cfg, PlacementConfig(
            planner="least_loaded"), 4)
        mgr4.table = mgr.table                  # pretend it had migrated
        eng4 = Engine(cfg, params, rcfg, max_slots=3, max_len=32,
                      placement=mgr4)
        assert eng4.load_checkpoint(d) == 1
        assert np.array_equal(mgr4.table.e2r,
                              np.arange(8, dtype=np.int32) // 2)
        assert mgr4.n_migrations == 0
