"""The HLO analyzer must multiply loop bodies by trip count and count dot
flops correctly (validated on a known program)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha


def test_scan_flops_trip_multiplied():
    n, steps = 128, 7

    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w, precision="highest"), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((n, n), jnp.float32)
    ws = jnp.ones((steps, n, n), jnp.float32)
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    res = ha.analyze(hlo)
    expect = 2.0 * n * n * n * steps
    assert abs(res["flops"] - expect) / expect < 0.05, (res["flops"], expect)


def test_single_dot_flops():
    m, k, n = 64, 256, 32
    f = jax.jit(lambda a, b: a @ b)
    hlo = f.lower(jnp.ones((m, k)), jnp.ones((k, n))).compile().as_text()
    res = ha.analyze(hlo)
    expect = 2.0 * m * k * n
    assert abs(res["flops"] - expect) / expect < 0.01


def test_traffic_nonzero_and_sane():
    f = jax.jit(lambda a: (a * 2 + 1).sum())
    hlo = f.lower(jnp.ones((1024, 1024))).compile().as_text()
    res = ha.analyze(hlo)
    # at least one read of the input
    assert res["traffic_bytes"] >= 4 * 1024 * 1024
    assert res["collective_bytes"] == 0


def test_parse_module_structure():
    f = jax.jit(lambda a: jax.lax.scan(lambda c, x: (c + x, c), a,
                                       jnp.ones((5, 4)))[0])
    hlo = f.lower(jnp.ones((4,))).compile().as_text()
    comps, entry = ha.parse_module(hlo)
    assert entry in comps
    assert len(comps) >= 2            # entry + loop body/cond
