"""The HLO analyzer must multiply loop bodies by trip count and count dot
flops correctly (validated on a known program)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha


def test_scan_flops_trip_multiplied():
    n, steps = 128, 7

    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w, precision="highest"), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((n, n), jnp.float32)
    ws = jnp.ones((steps, n, n), jnp.float32)
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    res = ha.analyze(hlo)
    expect = 2.0 * n * n * n * steps
    assert abs(res["flops"] - expect) / expect < 0.05, (res["flops"], expect)


def test_single_dot_flops():
    m, k, n = 64, 256, 32
    f = jax.jit(lambda a, b: a @ b)
    hlo = f.lower(jnp.ones((m, k)), jnp.ones((k, n))).compile().as_text()
    res = ha.analyze(hlo)
    expect = 2.0 * m * k * n
    assert abs(res["flops"] - expect) / expect < 0.01


def test_traffic_nonzero_and_sane():
    f = jax.jit(lambda a: (a * 2 + 1).sum())
    hlo = f.lower(jnp.ones((1024, 1024))).compile().as_text()
    res = ha.analyze(hlo)
    # at least one read of the input
    assert res["traffic_bytes"] >= 4 * 1024 * 1024
    assert res["collective_bytes"] == 0


def test_parse_module_structure():
    f = jax.jit(lambda a: jax.lax.scan(lambda c, x: (c + x, c), a,
                                       jnp.ones((5, 4)))[0])
    hlo = f.lower(jnp.ones((4,))).compile().as_text()
    comps, entry = ha.parse_module(hlo)
    assert entry in comps
    assert len(comps) >= 2            # entry + loop body/cond


def test_analyze_byte_counts_are_integral():
    f = jax.jit(lambda a: (a * 2 + 1).sum())
    res = ha.analyze(f.lower(jnp.ones((64, 64))).compile().as_text())
    assert type(res["traffic_bytes"]) is int
    assert type(res["collective_bytes"]) is int
    assert all(type(v) is int for v in res["collective_by_kind"].values())


# --------------------------------------------------------------------------
# collective census on a handcrafted module: a 4-trip layer loop with two
# user collectives (op_name name-stack leaf = jaxpr primitive) and one
# partitioner-inserted all-reduce (no op_name), plus a one-off user psum
# and an async start/done pair outside the loop.
# --------------------------------------------------------------------------
_CENSUS_HLO = """\
HloModule census_fixture

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (bp: (s32[], f32[8])) -> (s32[], f32[8]) {
  %bp = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%bp), index=0
  %x = f32[8] get-tuple-element(%bp), index=1
  %ar = f32[8] all-reduce(%x), to_apply=%add, metadata={op_name="jit(step)/transformer/moe/psum"}
  %a2a = f32[8] all-to-all(%ar), dimensions={0}, metadata={op_name="jit(step)/transformer/moe/all_to_all"}
  %infra = f32[8] all-reduce(%a2a), channel_id=3, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %bt = (s32[], f32[8]) tuple(%ip, %infra)
}

%cond (cp: (s32[], f32[8])) -> pred[] {
  %cp = (s32[], f32[8]) parameter(0)
  %ci = s32[] get-tuple-element(%cp), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%ci, %n), direction=LT
}

ENTRY %main (px: f32[8]) -> f32[8] {
  %px = f32[8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%zero, %px)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  %y = f32[8] get-tuple-element(%w), index=1
  %pre = f32[8] all-reduce(%y), to_apply=%add, metadata={op_name="jit(step)/psum"}
  %ars = f32[8] all-reduce-start(%pre), to_apply=%add, metadata={op_name="jit(step)/aux/psum"}
  ROOT %ard = f32[8] all-reduce-done(%ars)
}
"""


def test_collective_census_handcrafted():
    c = ha.collective_census(_CENSUS_HLO)
    assert c["layers"] == 4
    # total: loop {user psum + a2a + infra ar} x4, entry {pre, start}
    # (the -done half of the async pair is never double counted)
    assert c["total"]["all-reduce"] == {"count": 10, "bytes": 320}
    assert c["total"]["all-to-all"] == {"count": 4, "bytes": 128}
    # user slice excludes the partitioner-inserted %infra (no op_name)
    assert c["user"]["all-reduce"] == {"count": 6, "bytes": 192}
    assert c["user"]["all-to-all"] == {"count": 4, "bytes": 128}
    # steady-state body (one trip's worth) vs one-off collectives
    assert c["per_layer"]["all-reduce"] == {"count": 2, "bytes": 64}
    assert c["per_layer"]["all-to-all"] == {"count": 1, "bytes": 32}
    assert c["outside"]["all-reduce"] == {"count": 2, "bytes": 64}
    assert "all-to-all" not in c["outside"]
    # every cell integral
    for table in ("total", "user", "per_layer", "outside"):
        for ent in c[table].values():
            assert type(ent["count"]) is int and type(ent["bytes"]) is int


def test_collective_census_analyze_agree_on_bytes():
    """analyze()'s per-kind collective bytes equal the census totals."""
    res = ha.analyze(_CENSUS_HLO)
    c = ha.collective_census(_CENSUS_HLO)
    by_kind = {k: v["bytes"] for k, v in c["total"].items()}
    assert res["collective_by_kind"] == by_kind
    assert res["collective_bytes"] == sum(by_kind.values())
