"""Observability layer (repro.obs): span tracer + Chrome-trace export,
typed metrics registry, expert-load heatmap, prediction-accuracy
tracker, replan-decision audit log — and their wiring through
Telemetry, the managers and the engine (trace/accounting
reconciliation, exactly-one-audit-event-per-maybe_replan, bitwise
parity with tracing disabled)."""
import json

import numpy as np
import pytest

from repro.configs import (PlacementConfig, ReaLBConfig, ReplicationConfig,
                           get_config, reduced)
from repro.obs import (NULL_TRACER, Counter, Gauge, HeatmapRecorder,
                       Histogram, MetricsRegistry, PredictionTracker,
                       ReplanAudit, Tracer, validate_chrome_trace)
from repro.obs.trace import load_trace
from repro.placement import PlacementManager
from repro.replication import ReplicaManager
from repro.serving.telemetry import Telemetry, percentile, summarize

SKEW = [10.0, 8, 1, 1, 1, 1, 1, 1]
FLAT = [1.0] * 8


def _skew_stats(skews, e=8):
    es = np.zeros((len(skews), 2, e))
    for l, row in enumerate(skews):
        es[l, 0] = row
        es[l, 1] = np.asarray(row) * 0.5
    return es


# --------------------------------------------------------------------------
# percentile / summarize
# --------------------------------------------------------------------------
def test_percentile_matches_numpy_linear():
    rng = np.random.default_rng(7)
    for n in (1, 2, 3, 17, 100, 513):
        xs = rng.normal(size=n).tolist()
        for q in (0, 10, 50, 90, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q, method="linear")))
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)


def test_summarize_empty_and_keys():
    assert summarize([]) == {}
    s = summarize([1.0, 2.0, 3.0])
    assert set(s) == {"p50", "p90", "p99", "mean"}
    assert s["p50"] == 2.0 and s["mean"] == 2.0
    assert set(summarize([1.0], qs=(50, 90))) == {"p50", "p90", "mean"}


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------
def test_counter_semantics():
    c = Counter("bytes")
    assert c.value() == 0 and c.total() == 0
    c.inc(5)
    c.inc(3)
    assert c.value() == 8 and isinstance(c.value(), int)
    with pytest.raises(ValueError):
        c.inc(-1)
    lab = Counter("decisions", labels=("verdict",))
    lab.inc(verdict="staged")
    lab.inc(2, verdict="noop")
    assert lab.value(verdict="staged") == 1 and lab.total() == 3
    with pytest.raises(ValueError):
        lab.inc(wrong="x")
    assert lab.snapshot() == {"verdict=noop": 2, "verdict=staged": 1}


def test_gauge_and_histogram_semantics():
    g = Gauge("capacity")
    assert g.value() is None and g.value(default=1.0) == 1.0
    g.set(0.5)
    g.set(0.7)
    assert g.value() == 0.7
    h = Histogram("lat")
    assert h.summary() == {} and h.count() == 0
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count() == 4 and h.summary()["p50"] == 2.5
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["max"] == 4.0


def test_histogram_rolling_window_eviction():
    h = Histogram("w", window=3)
    for v in range(10):
        h.observe(float(v))
    assert h.values() == [7.0, 8.0, 9.0] and h.count() == 3


def test_registry_register_or_get_and_snapshot():
    reg = MetricsRegistry()
    c1 = reg.counter("n", "help")
    assert reg.counter("n") is c1                  # same object back
    with pytest.raises(ValueError):
        reg.gauge("n")                             # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("n", labels=("x",))            # label mismatch
    c1.inc(2)
    reg.gauge("g").set(1.5)
    reg.histogram("h").observe(3.0)
    snap = reg.snapshot()
    assert snap["n"] == 2 and snap["g"] == 1.5
    assert snap["h"]["count"] == 1
    json.dumps(snap)                               # JSON-serializable
    assert reg.names() == ["g", "h", "n"]
    assert reg.get("missing") is None


# --------------------------------------------------------------------------
# heatmap recorder
# --------------------------------------------------------------------------
def test_heatmap_accumulates_and_summarizes():
    hr = HeatmapRecorder(every=2, keep=3)
    assert hr.summary() == {}
    hm = np.array([[3.0, 1.0], [1.0, 1.0]])
    for _ in range(4):
        hr.record(hm)
    s = hr.summary()
    assert s["layers"] == 2 and s["ranks"] == 2 and s["n_records"] == 4
    assert s["layer_peak_rank"] == [0, 0]
    assert s["layer_peak_share"][0] == pytest.approx(0.75)
    assert s["layer_peak_share"][1] == pytest.approx(0.5)
    assert s["imbalance_max"] == pytest.approx(1.5)   # 0.75 * 2 ranks
    assert s["n_snapshots"] == 2                       # every=2, 4 records
    np.testing.assert_allclose(np.sum(s["share"], axis=1), 1.0)


def test_heatmap_shape_change_resets():
    hr = HeatmapRecorder()
    hr.record(np.ones((2, 4)))
    hr.record(np.ones((3, 4)))                         # elastic resize
    assert hr.n_records == 1 and hr.summary()["layers"] == 3


# --------------------------------------------------------------------------
# prediction tracker
# --------------------------------------------------------------------------
def test_prediction_tracker_window_math():
    pt = PredictionTracker()
    assert pt.summary() == {}
    # window 1: prediction exactly right
    pt.open(0, np.array([[4.0, 1.0, 1.0]]))
    for _ in range(3):
        pt.record(np.array([[8.0, 2.0, 2.0]]))         # same shares
    # window 2 opens (closes window 1): prediction wrong rank
    pt.open(10, np.array([[1.0, 1.0, 4.0]]))
    pt.record(np.array([[4.0, 1.0, 1.0]]))
    s = pt.summary()                                   # virtually closes w2
    assert s["n_windows"] == 2 and s["n_iters_observed"] == 4
    assert s["rank_match_frac"] == pytest.approx(0.5)
    assert s["peak_share_abs_err"]["p50"] == pytest.approx(0.0)
    assert pt.summary() == s                           # non-destructive
    assert len(pt.windows) == 1                        # w2 still open
    pt.record(np.array([[4.0, 1.0, 1.0]]))             # still accumulating
    assert pt.summary()["n_iters_observed"] == 5


def test_prediction_tracker_shared_table_folds_layers():
    """A shared-table manager predicts one depth-aggregated [1, R] row;
    per-layer realized [L, R] loads fold to the same shape."""
    pt = PredictionTracker()
    pt.open(0, np.array([[4.0, 1.0, 1.0]]))
    pt.record(np.array([[3.0, 0.5, 0.5], [1.0, 0.5, 0.5]]))
    s = pt.summary()
    assert s["n_iters_observed"] == 1
    assert s["rank_match_frac"] == 1.0
    assert s["real_peak_share_mean"] == pytest.approx(4.0 / 6.0)


def test_prediction_tracker_guards():
    pt = PredictionTracker()
    pt.record(np.ones((2, 3)))                         # no open window: noop
    pt.open(0, None)                                   # None: just closes
    pt.record(np.ones((2, 3)))
    assert pt.summary() == {}
    pt.open(1, np.ones((2, 3)))
    pt.record(np.ones((4, 3)))                         # shape mismatch: skip
    assert pt.summary() == {}                          # nothing accumulated


# --------------------------------------------------------------------------
# tracer + Chrome-trace export
# --------------------------------------------------------------------------
def test_tracer_spans_instants_and_export(tmp_path):
    t = [0.0]
    tr = Tracer(clock=lambda: t[0])
    with tr.span("iter", cat="engine") as sp:
        t[0] = 0.5
        with tr.span("forward.chunk") as inner:
            t[0] = 2.0
            inner.set(tokens=128)
        sp.set(it=3).set(n_active=2)                   # set() merges
    tr.instant("table.commit", cat="migration", args={"layers": 1})
    tr.complete("migration.drain", 2.0, 1.5, args={"stall_s": 1.5})
    assert len(tr) == 4
    obj = tr.to_chrome(metadata={"arm": "x"})
    events = validate_chrome_trace(obj)
    assert obj["metadata"] == {"arm": "x"} \
        and obj["displayTimeUnit"] == "ms"
    xs = [e for e in events if e["ph"] == "X"]
    # inner span closed first (append order), times in microseconds
    assert xs[0]["name"] == "forward.chunk"
    assert xs[0]["ts"] == pytest.approx(0.5e6)
    assert xs[0]["dur"] == pytest.approx(1.5e6)
    assert xs[1]["args"] == {"it": 3, "n_active": 2}
    assert xs[2]["dur"] == pytest.approx(1.5e6)
    inst = [e for e in events if e["ph"] == "i"]
    assert inst[0]["s"] == "t" and inst[0]["args"] == {"layers": 1}
    # roundtrip through the file writer + validating loader
    p = tmp_path / "trace.json"
    tr.write(str(p), metadata={"arm": "x"})
    assert load_trace(str(p))["metadata"] == {"arm": "x"}


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    bad_ph = {"traceEvents": [{"ph": "Z", "name": "x", "ts": 0}]}
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace(bad_ph)
    no_name = {"traceEvents": [{"ph": "i", "ts": 0}]}
    with pytest.raises(ValueError, match="name"):
        validate_chrome_trace(no_name)
    neg_dur = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0,
                                "dur": -1}]}
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(neg_dur)


def test_null_tracer_is_inert_singletons():
    assert NULL_TRACER.enabled is False
    sp = NULL_TRACER.span("anything")
    assert sp is NULL_TRACER.span("other")             # shared null span
    with sp as s:
        assert s.set(a=1) is s
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("x", 0.0, 1.0)                # all no-ops


# --------------------------------------------------------------------------
# telemetry on the registry (satellite 1: recovery percentiles +
# disambiguated migration counters)
# --------------------------------------------------------------------------
class _Stat:
    def __init__(self, **kw):
        self.phase = "decode"
        self.ib_global = 1.0
        self.gate_open = 0.0
        self.fp4_ranks = 0.0
        for k, v in kw.items():
            setattr(self, k, v)


def test_telemetry_counter_shims_and_summary():
    tel = Telemetry()
    tel.record_iter(_Stat(migration_bytes=100, migration_s=0.5,
                          migration_hidden_s=0.25))
    tel.record_iter(_Stat(migration_bytes=0, migration_s=0.0,
                          migration_hidden_s=0.0))
    tel.record_iter(_Stat(migration_bytes=50, migration_s=0.0,
                          migration_hidden_s=0.1))
    tel.record_plan_commit()
    assert tel.migration_bytes_total == 150
    assert isinstance(tel.migration_bytes_total, int)
    assert tel.migration_s_total == pytest.approx(0.5)
    assert tel.migration_hidden_s_total == pytest.approx(0.35)
    assert tel.n_migrations == 2                       # iterations, not plans
    assert tel.n_plans_committed == 1
    s = tel.summary()
    assert s["n_migration_iters"] == 2 == s["n_migrations"]
    assert s["n_plans_committed"] == 1
    assert tel.registry.snapshot()["migration_bytes"] == 150


def test_telemetry_recovery_percentiles_and_max_alias():
    tel = Telemetry()
    s = tel.summary()
    assert s["recovery_s"] is None and s["recovery"] == {}
    for r in (1.0, 3.0, 2.0):
        tel.record_recovery(r)
    assert tel.recoveries == [1.0, 3.0, 2.0]
    s = tel.summary()
    assert s["recovery_s"] == 3.0                      # legacy max alias
    assert s["n_recoveries"] == 3
    assert s["recovery"]["p50"] == 2.0
    assert s["recovery"]["mean"] == pytest.approx(2.0)


def test_telemetry_empty_phase_summaries():
    tel = Telemetry()
    s = tel.summary()
    assert s["ttft"] == {} and s["ib_global"] == {}
    assert s["gate_duty_prefill"] == 0.0 and s["fp4_duty"] == 0.0
    assert s["availability"] == 1.0
    assert s["expert_load_heatmap"] == {}
    assert s["prediction_accuracy"] == {}
    tel.record_iter(_Stat(phase="decode"))
    assert tel.summary()["ib_global_prefill"] == {}    # no prefill iters


def test_telemetry_heatmap_and_prediction_feeds():
    tel = Telemetry()
    tel.record_rank_heatmap(None)                      # None-safe
    tel.open_prediction_window(0, np.array([[2.0, 1.0]]))
    for _ in range(3):
        tel.record_rank_heatmap(np.array([[2.0, 1.0]]))
    s = tel.summary()
    assert s["expert_load_heatmap"]["n_records"] == 3
    assert s["prediction_accuracy"]["n_windows"] == 1
    assert s["prediction_accuracy"]["rank_match_frac"] == 1.0
    assert s["prediction_accuracy"]["peak_share_abs_err"]["p50"] \
        == pytest.approx(0.0)


# --------------------------------------------------------------------------
# replan audit: exactly one event per maybe_replan call, priced verdicts
# --------------------------------------------------------------------------
def _audited_mgr(cls, ccls, per_layer=False, **kw):
    cfgkw = dict(replan_every=2, warmup_iters=3, min_gain=0.0,
                 per_layer=per_layer, **kw)
    mgr = cls.from_geometry(8, ccls(**cfgkw), 4, bytes_per_expert=7,
                            n_layers=3 if per_layer else 1)
    mgr.audit = ReplanAudit()
    return mgr


@pytest.mark.parametrize("cls,ccls", [
    (PlacementManager, PlacementConfig),
    (ReplicaManager, ReplicationConfig)])
@pytest.mark.parametrize("per_layer", [False, True])
def test_audit_one_event_per_maybe_replan(cls, ccls, per_layer):
    mgr = _audited_mgr(cls, ccls, per_layer=per_layer)
    n_calls = 0
    for it in range(1, 9):
        mgr.observe(_skew_stats([SKEW, FLAT, SKEW[::-1]] if per_layer
                                else [SKEW]))
        plan = mgr.maybe_replan(it)
        n_calls += 1
        if plan is not None:
            mgr.commit(plan)
    assert len(mgr.audit) == n_calls                   # completeness
    assert [e["seq"] for e in mgr.audit.events] == list(range(n_calls))
    assert all(e["manager"] == mgr._kind for e in mgr.audit.events)
    # n_obs < warmup_iters=3 at iterations 1-2 (one observe per call);
    # past warmup every even iteration hits the replan_every=2 cadence
    assert mgr.audit.query(it=1)[0]["verdict"] == "warmup"
    assert mgr.audit.query(it=2)[0]["verdict"] == "warmup"
    assert mgr.audit.query(it=3)[0]["verdict"] == "no-cadence"
    hits = mgr.audit.cadence_hits()
    assert {e["it"] for e in hits} == {4, 6, 8}
    for e in hits:
        assert e["regime"] == "mixed"
    staged = mgr.audit.query(verdict="staged")
    assert staged, "the skewed load must stage at least one plan"
    for e in staged:
        assert e["migration_bytes"] > 0 and e["migration_s"] >= 0
        assert e["pred_gain"] > 0 and e["n_moved"] > 0
    counts = mgr.audit.counts()
    assert sum(counts.values()) == n_calls


def test_audit_cost_gate_rejection_is_priced():
    class VetoGate:
        def accept(self, old, new, moved):
            return False

        def accept_layers(self, old, new, moved):
            return False

    pcfg = PlacementConfig(replan_every=2, warmup_iters=1, min_gain=0.0)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=7,
                                         cost_gate=VetoGate())
    mgr.audit = ReplanAudit()
    mgr.observe(_skew_stats([SKEW]))
    assert mgr.maybe_replan(2) is None
    (ev,) = mgr.audit.query(verdict="cost-gate")
    assert ev["migration_bytes"] > 0 and "pred_gain" in ev
    assert mgr.audit.counts()["cost-gate"] == 1


def test_audit_jsonl_roundtrip(tmp_path):
    audit = ReplanAudit()
    audit.record(it=1, manager="placement", verdict="warmup")
    audit.record(it=2, manager="placement", verdict="staged",
                 regime="mixed", pred_gain=0.5, migration_bytes=100,
                 dropped=None)                         # None fields dropped
    p = tmp_path / "audit.jsonl"
    audit.to_jsonl(str(p))
    back = ReplanAudit.load_jsonl(str(p))
    assert back == audit.events
    assert "dropped" not in back[1]


def test_audit_disabled_by_default_no_overhead():
    pcfg = PlacementConfig(replan_every=2, warmup_iters=1, min_gain=0.0)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=7)
    assert mgr.audit is None and mgr.tracer is NULL_TRACER
    mgr.observe(_skew_stats([SKEW]))
    assert mgr.maybe_replan(2) is not None             # planning unaffected


# --------------------------------------------------------------------------
# manager rank heatmaps ([L, R] from the scan's expert/slot stats)
# --------------------------------------------------------------------------
def test_placement_rank_heatmap_folds_tables():
    pcfg = PlacementConfig(replan_every=2, warmup_iters=1, min_gain=0.0,
                           per_layer=True)
    mgr = PlacementManager.from_geometry(8, pcfg, 4, bytes_per_expert=7,
                                         n_layers=2)
    es = _skew_stats([SKEW, FLAT])
    hm = mgr.rank_heatmap(es)
    assert hm.shape == (2, 4)
    np.testing.assert_allclose(hm.sum(axis=1), es[:, 0, :].sum(axis=1))
    # identity-ish layout: rank r owns experts 2r, 2r+1
    np.testing.assert_allclose(hm[1], [2.0, 2.0, 2.0, 2.0])


def test_replication_rank_heatmap_prefers_slot_stats():
    rcfg = ReplicationConfig(replan_every=2, warmup_iters=1, min_gain=0.0,
                             spare_per_rank=1)
    mgr = ReplicaManager.from_geometry(8, rcfg, 4, bytes_per_expert=7)
    es = _skew_stats([SKEW])
    hm = mgr.rank_heatmap(es)
    assert hm.shape == (1, 4) and hm.sum() == pytest.approx(es[0, 0].sum())
    # exact post-split loads come from slot stats when provided
    ss = np.zeros((1, 2, mgr.n_slots))
    ss[0, 0, :] = 1.0
    hm2 = mgr.rank_heatmap(es, slot_stats=ss)
    np.testing.assert_allclose(hm2[0], np.full(4, mgr.slots_per_rank))


# --------------------------------------------------------------------------
# engine end-to-end (slow): trace reconciliation + disabled parity
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    import jax

    from repro.models import transformer as tf
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n=6, p_len=12, new=4, seed=0):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        out.append(Request(uid=i, tokens=toks,
                           modality=np.full(p_len, bool(i % 2)),
                           max_new_tokens=new, arrival_time=0.0))
    return out


def _bias_routers_by_depth(params, biases):
    import jax.numpy as jnp
    out = dict(params)
    blocks = dict(out["blocks"])
    lp = dict(blocks["layer0"])
    moe = dict(lp["moe"])
    moe["router"] = moe["router"] + jnp.asarray(biases)[:, None, :]
    lp["moe"] = moe
    blocks["layer0"] = lp
    out["blocks"] = blocks
    return out


def _engine(cfg, params, tracer=None, migrate_async=False, budget=None):
    from repro.serving.engine import Engine
    from repro.workloads import IterationCostModel, VirtualClock
    mgr = PlacementManager(cfg, PlacementConfig(
        planner="least_loaded", replan_every=3, warmup_iters=2,
        min_gain=0.0, per_layer=True), 4)
    mgr.audit = ReplanAudit()
    tel = Telemetry()
    eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                 max_len=32, placement=mgr, telemetry=tel,
                 clock=VirtualClock(), cost_model=IterationCostModel(),
                 migrate_async=migrate_async,
                 migrate_bytes_per_iter=budget, tracer=tracer)
    return eng, mgr, tel


@pytest.mark.slow
@pytest.mark.parametrize("migrate_async", [False, True])
def test_engine_trace_reconciles_migration_accounting(model, migrate_async,
                                                      tmp_path):
    """Acceptance invariant: summed migration.drain span durations equal
    migration_s_total + migration_hidden_s_total (sync and async)."""
    from repro.placement import migrate as pmigrate
    cfg, params = model
    b0 = np.array([3.0, 2.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0])
    params = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))
    budget = pmigrate.expert_bytes(cfg, 1) * cfg.moe.num_experts \
        if migrate_async else None
    eng, mgr, tel = _engine(cfg, params, tracer=Tracer(),
                            migrate_async=migrate_async, budget=budget)
    eng.tracer.clock = eng.clock                       # trace engine time
    for r in _reqs(cfg, n=12, seed=3):
        eng.submit(r)
    eng.run()
    eng.drain_migrations()
    assert mgr.n_migrations >= 1
    p = tmp_path / "trace.json"
    eng.tracer.write(str(p), metadata={
        "migration_s_total": eng.migration_stall_s,
        "migration_hidden_s_total": eng.migration_hidden_s})
    obj = load_trace(str(p))
    drains = [e for e in obj["traceEvents"]
              if e.get("ph") == "X" and e["name"] == "migration.drain"]
    assert drains, "migrations ran but no drain spans recorded"
    span_s = sum(e["dur"] for e in drains) / 1e6
    assert span_s == pytest.approx(
        eng.migration_stall_s + eng.migration_hidden_s, abs=1e-9)
    stall_s = sum(e["args"]["stall_s"] for e in drains)
    hidden_s = sum(e["args"]["hidden_s"] for e in drains)
    assert stall_s == pytest.approx(eng.migration_stall_s, abs=1e-9)
    assert hidden_s == pytest.approx(eng.migration_hidden_s, abs=1e-9)
    if migrate_async:
        assert hidden_s > 0
    else:
        assert hidden_s == 0.0 and stall_s > 0
    # the span vocabulary the ISSUE names is present
    names = {e["name"] for e in obj["traceEvents"] if e.get("ph") == "X"}
    assert {"iter", "admit", "migration.drain"} <= names
    assert names & {"forward.chunk", "forward.decode", "forward.prefill"}
    assert any(e["name"] == "replan.placement"
               for e in obj["traceEvents"])
    assert any(e["name"] == "table.commit"
               for e in obj["traceEvents"] if e.get("ph") == "i")
    assert any(e["name"] == "dispatch.policy"
               for e in obj["traceEvents"] if e.get("ph") == "i")
    # audit completeness rode along: plans committed => staged verdicts
    assert len(mgr.audit.query(verdict="staged")) >= mgr.n_migrations
    # prediction accuracy reached the telemetry summary (acceptance)
    acc = tel.summary()["prediction_accuracy"]
    assert acc and acc["n_windows"] >= 1
    assert tel.summary()["expert_load_heatmap"]["n_records"] > 0


@pytest.mark.slow
def test_engine_disabled_tracer_bitwise_parity(model):
    """An engine without a tracer produces bitwise-identical generations
    and identical accounting to one tracing every span."""
    cfg, params = model
    b0 = np.array([3.0, 2.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0])
    params = _bias_routers_by_depth(params, np.stack([b0, b0[::-1]]))
    outs = []
    for tracer in (None, Tracer()):
        eng, mgr, tel = _engine(cfg, params, tracer=tracer)
        if tracer is not None:
            eng.tracer.clock = eng.clock
        for r in _reqs(cfg, n=8, seed=5):
            eng.submit(r)
        eng.run()
        eng.drain_migrations()
        outs.append((
            {r.uid: list(r.generated) for r in eng.scheduler.finished},
            eng.migration_bytes_moved, mgr.n_migrations,
            [list(t.e2r) for t in mgr.tables],
        ))
    base, traced = outs
    assert base[0] == traced[0]                        # same tokens, bitwise
    # same plans, bytes and final tables (stall *seconds* are measured
    # apply wall time — nondeterministic run-to-run with or without a
    # tracer — so they are excluded from the parity check)
    assert base[1:] == traced[1:]


@pytest.mark.slow
def test_elastic_events_traced_as_instants(model):
    """ElasticCoordinator events surface as elastic.* instants."""
    import tempfile

    from repro.checkpoint import ckpt as ckpt_lib
    from repro.replication import expand_moe_params
    from repro.runtime.fault_tolerance import FaultInjector
    from repro.serving.elastic import ElasticCoordinator
    from repro.serving.engine import Engine
    from repro.workloads import IterationCostModel, VirtualClock
    cfg, params = model
    rcfg = ReplicationConfig(replan_every=3, warmup_iters=2, min_gain=0.0,
                             spare_per_rank=1, per_layer=True)
    mgr = ReplicaManager(cfg, rcfg, ep=4)
    params = expand_moe_params(params, mgr.rsets)
    clock = VirtualClock()
    tel = Telemetry()
    with tempfile.TemporaryDirectory() as d:
        ckpt_lib.save(d, 0, {"serving": {"params": params},
                             mgr.ckpt_group: mgr.state_dict()})
        elastic = ElasticCoordinator(mgr, ckpt_dir=d, clock=clock,
                                     telemetry=tel)
        injector = FaultInjector([(4, "fail", 1), (12, "rejoin", 1)])
        tracer = Tracer(clock=clock)
        eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                     max_len=32, placement=mgr, telemetry=tel, clock=clock,
                     cost_model=IterationCostModel(), elastic=elastic,
                     fault_injector=injector, migrate_async=True,
                     migrate_bytes_per_iter=4096, tracer=tracer)
        for r in _reqs(cfg, n=12, seed=3):
            eng.submit(r)
        eng.run()
        eng.drain_migrations()
    kinds = {e["kind"] for e in elastic.events}
    assert "fail" in kinds and "rejoin" in kinds
    obj = tracer.to_chrome()
    inst = [e["name"] for e in obj["traceEvents"] if e.get("ph") == "i"]
    for k in kinds:
        assert f"elastic.{k}" in inst
    validate_chrome_trace(obj)
