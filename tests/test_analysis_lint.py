"""AST lint: one positive + one scoped/refined negative fixture per
rule, the escape hatch, the CLI contract, and the dogfood pin (the repo
itself lints clean)."""
import json
import pathlib
import subprocess
import sys
import textwrap

from repro.analysis.lint import (RULES, Finding, lint_paths, lint_source,
                                 summarize)

SRC = pathlib.Path(__file__).parents[1] / "src" / "repro"


def _lint(code, path="repro/core/fake.py", rules=None):
    return lint_source(textwrap.dedent(code), path, rules=rules)


def _rules(findings, suppressed=False):
    return [f.rule for f in findings if f.suppressed == suppressed]


# --------------------------------------------------------------------------
# RPL001: host coercion of traced values
# --------------------------------------------------------------------------
def test_rpl001_flags_float_of_jnp():
    f = _lint("""
        def f(x):
            return float(jnp.sum(x))
        """)
    assert _rules(f) == ["RPL001"]


def test_rpl001_flags_np_asarray_of_traced():
    f = _lint("""
        def f(x):
            return np.asarray(jnp.ones(3))
        """)
    assert _rules(f) == ["RPL001"]


def test_rpl001_module_level_and_host_values_exempt():
    # module-level jnp runs eagerly at import; float(python) is fine
    f = _lint("""
        INV = float(jnp.float32(1.0) / jnp.float32(6.0))
        def f(n):
            return float(n) + int(len([1]))
        """)
    assert f == []


def test_rpl001_scoped_to_hot_dirs():
    code = """
        def f(x):
            return float(jnp.sum(x))
        """
    assert _rules(_lint(code, path="repro/serving/engine.py")) == []
    assert _rules(_lint(code, path="repro/models/attention.py")) \
        == ["RPL001"]


# --------------------------------------------------------------------------
# RPL002: Python control flow on traced values
# --------------------------------------------------------------------------
def test_rpl002_flags_if_on_jnp():
    f = _lint("""
        def f(x):
            if jnp.any(x > 0):
                return x
            return -x
        """)
    assert _rules(f) == ["RPL002"]


def test_rpl002_host_jax_api_exempt():
    f = _lint("""
        def f(x):
            impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
            return impl
        """)
    assert f == []


def test_rpl002_while_and_ternary():
    f = _lint("""
        def f(x):
            y = 1 if jnp.all(x) else 2
            while jnp.any(x):
                x = x - 1
            return y
        """)
    assert _rules(f) == ["RPL002", "RPL002"]


# --------------------------------------------------------------------------
# RPL003: hardware-magnitude literals
# --------------------------------------------------------------------------
def test_rpl003_band():
    f = _lint("""
        ICI_BW = 45e9
        MASK = -1e30          # numeric sentinel: above the band
        N = 100_000_000       # below the band
        """, path="repro/launch/roofline.py")
    assert _rules(f) == ["RPL003"]
    assert "45000000000" in f[0].message or "4.5e+10" in f[0].message


def test_rpl003_configs_exempt():
    f = _lint("MIGRATION_BW_DEFAULT = 50e9\n",
              path="repro/configs/base.py")
    assert f == []


# --------------------------------------------------------------------------
# RPL004: unguarded tracer/profiler annotation calls
# --------------------------------------------------------------------------
def test_rpl004_flags_unguarded_instant():
    f = _lint("""
        def step(self):
            self.tracer.instant("replan", args={"it": 3})
        """, path="repro/serving/engine.py")
    assert _rules(f) == ["RPL004"]


def test_rpl004_enabled_guard_and_non_profiler_receiver_ok():
    f = _lint("""
        def step(self):
            if self.tracer.enabled:
                self.tracer.instant("replan", args={"it": 3})
            if self.profiler.enabled:
                self.profiler.observe_iter(moe_stats=s, tokens=4)
            gate.observe_iter(s)       # cost gate, not a profiler
        """, path="repro/serving/engine.py")
    assert f == []


# --------------------------------------------------------------------------
# RPL005: table mutation outside the staged-commit API
# --------------------------------------------------------------------------
def test_rpl005_flags_direct_table_assign():
    f = _lint("""
        def hack(mgr, t):
            mgr.tables = t
        """, path="repro/serving/engine.py")
    assert _rules(f) == ["RPL005"]


def test_rpl005_managers_exempt():
    f = _lint("""
        def commit(self, t):
            self.tables = t
        """, path="repro/replication/manager.py")
    assert f == []


# --------------------------------------------------------------------------
# RPL006: non-integral byte accounting
# --------------------------------------------------------------------------
def test_rpl006_flags_float_bytes():
    f = _lint("""
        def plan(n):
            budget_bytes = n / 2
            slab_bytes = float(n)
            nbytes = 1.5
        """, path="repro/placement/migrate.py")
    assert _rules(f) == ["RPL006", "RPL006", "RPL006"]


def test_rpl006_floor_div_and_ledger_exempt():
    assert _lint("""
        def plan(n):
            budget_bytes = n // 2
        """, path="repro/placement/migrate.py") == []
    assert _lint("""
        def f(n):
            hbm_bytes = n * 0.53125
        """, path="repro/obs/ledger.py") == []


# --------------------------------------------------------------------------
# RPL007: wall clock
# --------------------------------------------------------------------------
def test_rpl007_flags_time_time():
    f = _lint("""
        def f():
            t0 = time.time()
            t1 = time.perf_counter()
        """, path="repro/launch/serve.py")
    assert _rules(f) == ["RPL007"]


def test_rpl007_clock_seam_exempt():
    f = _lint("""
        def now():
            return time.time()
        """, path="repro/obs/trace.py")
    assert f == []


# --------------------------------------------------------------------------
# escape hatch + machinery
# --------------------------------------------------------------------------
def test_suppression_collected_separately():
    f = _lint("""
        def f(x):
            # calibration constant, computed once at trace time
            return float(jnp.sum(x))  # repro-lint: disable=RPL001
        """)
    assert _rules(f) == [] and _rules(f, suppressed=True) == ["RPL001"]
    s = summarize(f)
    assert s["files_ok"] and s["n_suppressed"] == 1


def test_suppression_is_rule_specific():
    f = _lint("""
        def f(x):
            return float(jnp.sum(x))  # repro-lint: disable=RPL002
        """)
    assert _rules(f) == ["RPL001"]


def test_syntax_error_reported_as_rpl000():
    f = _lint("def f(:\n")
    assert [x.rule for x in f] == ["RPL000"]


def test_rules_catalog_complete():
    assert sorted(RULES) == [f"RPL00{i}" for i in range(1, 8)]


def test_cli_json_and_exit_codes(tmp_path):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(x):\n    return float(jnp.sum(x))\n")
    env_path = str(SRC.parent)
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad), "--json"],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert r.returncode == 1
    out = json.loads(r.stdout)
    assert out["n_findings"] == 1 and out["by_rule"] == {"RPL001": 1}

    bad.write_text("def f(x):\n"
                   "    return float(jnp.sum(x))"
                   "  # repro-lint: disable=RPL001\n")
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad)],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert r2.returncode == 0
    assert "1 suppressed" in r2.stdout


# --------------------------------------------------------------------------
# dogfood pin: the repo itself is lint-clean
# --------------------------------------------------------------------------
def test_repo_lints_clean():
    findings = lint_paths([str(SRC)])
    unsup = [f for f in findings if not f.suppressed]
    assert unsup == [], "\n".join(f.format() for f in unsup)
    # suppressions exist and are the documented, justified ones
    sup = {(pathlib.Path(f.path).name, f.rule)
           for f in findings if f.suppressed}
    assert sup <= {("lint.py", "RPL003"), ("profiler.py", "RPL006")}
