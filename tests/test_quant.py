"""NVFP4 quantization recipe properties (paper Appendix E).

Property tests run under ``hypothesis`` when it is installed; seeded
plain-pytest subsets call the same check bodies so collection and coverage
never depend on the optional package.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

FP4_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
GRID_ALL = np.sort(np.concatenate([-FP4_GRID, FP4_GRID]))


def test_fp4_round_onto_grid():
    x = np.linspace(-8, 8, 4001).astype(np.float32)
    y = np.asarray(quant.fp4_round(jnp.asarray(x)))
    assert set(np.unique(np.abs(y))) <= set(FP4_GRID)


def test_fp4_round_nearest():
    x = np.array([0.24, 0.26, 0.74, 0.76, 2.4, 2.6, 4.9, 5.1, 100.0, -1.3])
    y = np.asarray(quant.fp4_round(jnp.asarray(x)))
    expected = np.array([0.0, 0.5, 0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 6.0, -1.5])
    np.testing.assert_array_equal(y, expected)


def test_fp4_code_decode_roundtrip():
    x = np.linspace(-7, 7, 997).astype(np.float32)
    codes = quant.fp4_code(jnp.asarray(x))
    dec = np.asarray(quant.fp4_decode(codes))
    np.testing.assert_array_equal(dec, np.asarray(quant.fp4_round(x)))


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, (4, 32)).astype(np.uint8)
    packed = quant.pack_u4(jnp.asarray(codes))
    assert packed.shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(quant.unpack_u4(packed)), codes)


# -- shared check bodies ----------------------------------------------------
def check_e4m3_idempotent_and_bounded(x):
    y = np.asarray(quant.e4m3_round(jnp.asarray(x)))
    y2 = np.asarray(quant.e4m3_round(jnp.asarray(y)))
    np.testing.assert_array_equal(y, y2)          # representable fixed point
    assert np.all(np.abs(y) <= 448.0)
    # relative error of a normal e4m3 value is <= 2^-4 (+ denormal floor)
    err = np.abs(y - x)
    bound = np.maximum(np.abs(x) * (2 ** -3), 2.0 ** -10 + 1e-12)
    assert np.all(err <= bound + 1e-6)


def check_quantize_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    w = (rng.normal(0, scale, (4, 64))).astype(np.float32)
    q = quant.quantize_fp4(jnp.asarray(w))
    dq = np.asarray(quant.dequantize_fp4(q))
    wg = w.reshape(4, 4, 16)
    amax = np.abs(wg).max(-1, keepdims=True)
    err = np.abs(dq.reshape(4, 4, 16) - wg)
    # grid step <= amax/3 around the top; scale rounding <= 6.25% extra
    assert np.all(err <= 0.25 * amax + 1e-7)


# -- hypothesis property tests (optional) -----------------------------------
if HAVE_HYPOTHESIS:
    @hypothesis.given(hnp.arrays(np.float32, (8,),
                                 elements=st.floats(-448, 448, width=32)))
    @hypothesis.settings(deadline=None, max_examples=100)
    def test_e4m3_idempotent_and_bounded(x):
        check_e4m3_idempotent_and_bounded(x)

    @hypothesis.given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 10.0))
    @hypothesis.settings(deadline=None, max_examples=40)
    def test_quantize_roundtrip_error_bound(seed, scale):
        check_quantize_roundtrip_error_bound(seed, scale)


# -- plain-pytest subset (always runs) --------------------------------------
@pytest.mark.parametrize("seed", range(10))
def test_e4m3_idempotent_and_bounded_sampled(seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-448, 448, 8).astype(np.float32)
    if seed == 0:
        x = np.array([0.0, -0.0, 448.0, -448.0, 1e-6, -1e-6, 2.0, 3.1],
                     np.float32)
    check_e4m3_idempotent_and_bounded(x)


@pytest.mark.parametrize("seed,scale", [(0, 1e-3), (1, 0.05), (2, 1.0),
                                        (3, 10.0), (4, 0.3)])
def test_quantize_roundtrip_error_bound_sampled(seed, scale):
    check_quantize_roundtrip_error_bound(seed, scale)


def test_e4m3_clamps():
    y = np.asarray(quant.e4m3_round(jnp.asarray([1e6, -1e6, 500.0])))
    np.testing.assert_array_equal(y, [448.0, -448.0, 448.0])


def test_fp4_sim_gradient_straight_through():
    import jax
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 32)),
                    jnp.float32)
    g = jax.grad(lambda v: quant.fp4_sim(v).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_matmul_w4a4_matches_manual():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (16, 32)), jnp.float32)
    q = quant.quantize_fp4(w)
    y = quant.matmul_w4a4(x, q)
    xq = quant.fp4_sim(x)
    wq = quant.dequantize_fp4(q)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(xq) @ np.asarray(wq).T, rtol=2e-5,
                               atol=2e-5)


def test_quant_error_reasonable():
    w = jnp.asarray(np.random.default_rng(0).normal(0, 0.02, (256, 256)),
                    jnp.float32)
    err = float(quant.quant_error(w))
    assert 0.01 < err < 0.2       # fp4 w/ group scales ~ 5-12% on gaussian
