"""Serving engine + scheduler behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ReaLBConfig, get_config, reduced
from repro.models import transformer as tf
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, Scheduler

pytestmark = pytest.mark.slow    # full engine loops (prefill+decode jits)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    params = tf.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _req(rng, cfg, uid, p_len=10, new=4):
    toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
    return Request(uid=uid, tokens=toks,
                   modality=rng.random(p_len) < 0.5, max_new_tokens=new)


def test_scheduler_slots():
    s = Scheduler(2)
    reqs = [Request(uid=i, tokens=np.zeros(4, np.int32),
                    modality=np.zeros(4, bool)) for i in range(5)]
    for r in reqs:
        s.submit(r)
    admitted = s.admit()
    assert len(admitted) == 2 and len(s.queue) == 3
    admitted[0].generated = list(range(99))
    s.retire()
    assert len(s.active) == 1
    assert len(s.admit()) == 1


def test_engine_serves_all(model, rng):
    cfg, params = model
    eng = Engine(cfg, params, ReaLBConfig(gate_gamma=4), max_slots=3,
                 max_len=32)
    for i in range(7):
        eng.submit(_req(rng, cfg, i))
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.generated) == r.max_new_tokens for r in done)
    assert len(eng.stats) > 0


def test_engine_matches_manual_greedy(model):
    """Engine generation for a single request == hand-rolled greedy loop."""
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    rng = np.random.default_rng(7)
    toks = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    eng = Engine(cfg, params, rcfg, max_slots=2, max_len=24)
    eng.submit(Request(uid=0, tokens=toks, modality=np.zeros(9, bool),
                       max_new_tokens=4))
    out = eng.run()[0].generated

    # manual loop
    m = jnp.full((1, 1), rcfg.md_init)
    batch = {"tokens": jnp.asarray(toks)[None],
             "modality": jnp.zeros((1, 9), bool)}
    res = tf.prefill_forward(params, cfg, rcfg, batch, m, cache_len=24)
    cache, m = res.cache, res.m_state
    cur = int(jnp.argmax(res.logits, -1)[0])
    manual = [cur]
    pos = 9
    for _ in range(3):
        d = tf.decode_forward(params, cfg, rcfg,
                              {"tokens": jnp.asarray([[cur]], jnp.int32),
                               "pos": jnp.asarray([pos], jnp.int32)},
                              cache, m)
        cache, m = d.cache, d.m_state
        cur = int(jnp.argmax(d.logits, -1)[0])
        manual.append(cur)
        pos += 1
    assert out == manual, (out, manual)


def test_engine_slot_isolation(model):
    """A request's output must not depend on which other requests share the
    batch (cache slots are isolated)."""
    cfg, params = model
    rcfg = ReaLBConfig(gate_gamma=10 ** 9)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def serve_with(n_others):
        eng = Engine(cfg, params, rcfg, max_slots=4, max_len=24)
        eng.submit(Request(uid=0, tokens=toks.copy(),
                           modality=np.zeros(8, bool), max_new_tokens=4))
        r2 = np.random.default_rng(100)
        for j in range(n_others):
            eng.submit(_req(r2, cfg, 10 + j, p_len=6, new=4))
        done = eng.run()
        return next(r for r in done if r.uid == 0).generated

    assert serve_with(0) == serve_with(3)
