"""EPLB-style replication planner: predicted expert loads → a ReplicaSet.

Two greedy phases, both deterministic (stable sorts, first-index
tie-breaks) so repeated planning from identical predictions yields
identical sets and the diff is a no-op:

1. *Replica counting* — spend the spare slots one at a time on the
   expert with the largest current per-replica hotness
   ``(load + vis_weight * vis) / count`` (the marginal-gain greedy of
   fractional EPLB), capped at ``max_replicas`` and at ``n_ranks``
   (replicas must live on distinct ranks).  Vision-heavy experts are
   preferentially replicated: under a multimodal burst they are both the
   hottest and the ones ReaLB would otherwise have to compress.

2. *Instance packing* — longest-processing-time bin packing of all
   replica instances (each carrying ``load / count``) onto ranks with
   ``slots_per_rank`` capacity, never putting two replicas of one expert
   on the same rank.  When every remaining feasible rank already hosts
   the expert, the instance is dropped (count reduced) rather than
   violating the distinct-rank invariant.

The planner consumes ONE ``[E]`` load row; per-layer replication
(``ReplicationConfig.per_layer``) maps it over the predictor's
``[L, E]`` rows — one independent replica set per scanned MoE block,
staged and committed as a layer-diff.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import ReplicationConfig
from repro.replication.replica_set import ReplicaSet


def plan_replication(load: np.ndarray, n_ranks: int, slots_per_rank: int,
                     max_replicas: int = 2,
                     vis: Optional[np.ndarray] = None,
                     vis_weight: float = 1.0,
                     rank_alive: Optional[np.ndarray] = None) -> ReplicaSet:
    load = np.asarray(load, np.float64)
    e = load.shape[0]
    vis = np.zeros(e) if vis is None else np.asarray(vis, np.float64)
    assert e % n_ranks == 0, (e, n_ranks)
    assert slots_per_rank >= e // n_ranks, (slots_per_rank, e, n_ranks)
    assert 1 <= max_replicas, max_replicas
    s = n_ranks * slots_per_rank
    # dead-rank-aware planning (elastic serving): dead ranks contribute no
    # slots, replica counts are capped at the live-rank count, and spare
    # spending is capped at the *live* slot surplus so every expert's
    # primary still fits (phase 2 would otherwise drop cold primaries)
    alive = (np.ones(n_ranks, bool) if rank_alive is None
             else np.asarray(rank_alive, bool))
    assert alive.shape == (n_ranks,), (alive.shape, n_ranks)
    n_live = int(alive.sum())
    assert n_live * slots_per_rank >= e, \
        f"{e} experts cannot fit on {n_live} live ranks x {slots_per_rank}"
    spare = n_live * slots_per_rank - e
    cap = min(max_replicas, n_live)
    score = load + vis_weight * vis

    # phase 1: replica counts by marginal per-replica hotness
    counts = np.ones(e, np.int64)
    for _ in range(spare):
        per = np.where(counts < cap, score / counts, -np.inf)
        best = int(np.argmax(per))
        if not np.isfinite(per[best]) or per[best] <= 0.0:
            break
        counts[best] += 1

    # phase 2: LPT packing of replica instances with distinct-rank rule
    share = load / counts
    inst_e = np.repeat(np.arange(e), counts)
    inst_share = np.repeat(share, counts)
    order = np.argsort(-inst_share, kind="stable")
    rank_load = np.zeros(n_ranks)
    rank_free = np.where(alive, slots_per_rank, 0).astype(np.int64)
    hosts = np.zeros((e, n_ranks), bool)
    placed_ranks = [[] for _ in range(e)]
    for i in order:
        ex = int(inst_e[i])
        ok = (rank_free > 0) & ~hosts[ex]
        if not ok.any():
            continue                    # drop instance: count shrinks
        cand = np.flatnonzero(ok)
        r = int(cand[np.argmin(rank_load[cand])])
        placed_ranks[ex].append(r)
        hosts[ex, r] = True
        rank_load[r] += inst_share[i]
        rank_free[r] -= 1
    # materialize slots: per rank, residents in ascending (expert, j) order
    rep_pos = np.zeros((e, max_replicas), np.int64)
    n_rep = np.zeros(e, np.int64)
    next_slot = np.arange(n_ranks) * slots_per_rank
    for ex in range(e):
        assert placed_ranks[ex], f"expert {ex} lost every replica slot"
        for r in sorted(placed_ranks[ex]):
            rep_pos[ex, n_rep[ex]] = next_slot[r]
            next_slot[r] += 1
            n_rep[ex] += 1
        rep_pos[ex, n_rep[ex]:] = rep_pos[ex, 0]
    return ReplicaSet(rep_pos.astype(np.int32), n_rep.astype(np.int32),
                      n_ranks, slots_per_rank)


def plan_from_config(load: np.ndarray, n_ranks: int,
                     rpcfg: ReplicationConfig,
                     vis: Optional[np.ndarray] = None,
                     slots_per_rank: int = 0) -> ReplicaSet:
    e = np.asarray(load).shape[0]
    s_loc = slots_per_rank or (e // n_ranks + rpcfg.spare_per_rank)
    return plan_replication(load, n_ranks, s_loc,
                            max_replicas=rpcfg.max_replicas, vis=vis,
                            vis_weight=rpcfg.vis_weight)
