"""Replica ownership matrices (host side).

A :class:`ReplicaSet` generalizes the bijective
:class:`~repro.placement.table.PlacementTable` to *redundant experts*:
each of the ``E`` logical experts owns between 1 and ``max_replicas``
physical weight slots, out of ``n_ranks * slots_per_rank`` statically
shaped slots (``slots_per_rank >= E // n_ranks``; the excess is the spare
capacity replicas live in).  ``rep_pos[e, j]`` is the physical slot
(``rank * slots_per_rank + slot``) of replica ``j`` of expert ``e``;
entries at ``j >= n_rep[e]`` repeat the primary so traced gathers never
read garbage.  ``slot_owner`` is the inverse view: the logical expert
resident in each physical slot, ``-1`` for an empty spare.

With ``slots_per_rank == E // n_ranks`` and ``max_replicas == 1`` a
ReplicaSet *is* a PlacementTable (the identity configuration the bitwise
regression tests pin), so the whole placement machinery — weight-slab
gathers, checkpointing, the traced MoE table — degrades gracefully to
PR 2's bijective behavior.

Replicas of one expert always live on distinct ranks: splitting a
logical expert's tokens between two slots of the *same* rank changes
nothing about that rank's load, so such sets are rejected as planner
bugs rather than silently accepted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.placement.table import PlacementTable


@dataclasses.dataclass(frozen=True)
class ReplicaSet:
    rep_pos: np.ndarray        # [E, R] int32: physical slot per replica
    n_rep: np.ndarray          # [E] int32: valid replicas per expert (>= 1)
    n_ranks: int
    slots_per_rank: int

    def __post_init__(self):
        rp = np.asarray(self.rep_pos, np.int32)
        nr = np.asarray(self.n_rep, np.int32)
        if rp.ndim != 2:
            raise ValueError(f"rep_pos must be [E, R], got {rp.shape}")
        object.__setattr__(self, "rep_pos", rp)
        object.__setattr__(self, "n_rep", nr)
        e, r = rp.shape
        if nr.shape != (e,):
            raise ValueError((rp.shape, nr.shape))
        if not ((1 <= nr) & (nr <= r)).all():
            raise ValueError(f"n_rep out of [1, {r}]: {nr}")
        s = self.n_slots
        if e > s:
            raise ValueError(f"{e} experts need at least {e} slots, got {s}")
        if not ((0 <= rp) & (rp < s)).all():
            raise ValueError("rep_pos out of range")
        valid = self._valid_mask()
        # padding entries must repeat the primary (traced round-robin
        # gathers index the full row; mod n_rep keeps them unselected, but
        # a well-formed pad makes the table self-describing)
        if not (np.where(valid, rp, rp[:, :1]) == rp).all():
            raise ValueError("pad entries must repeat rep_pos[:, 0]")
        flat = rp[valid]
        if len(np.unique(flat)) != flat.shape[0]:
            raise ValueError("replica slots are not distinct")
        ranks = rp // self.slots_per_rank
        for ex in range(e):
            rr = ranks[ex, : nr[ex]]
            if len(np.unique(rr)) != rr.shape[0]:
                raise ValueError(
                    f"expert {ex} has two replicas on one rank: {rr}")

    # -- derived views ----------------------------------------------------
    def _valid_mask(self) -> np.ndarray:
        """[E, R] bool: which rep_pos entries are live replicas (the rest
        are primary-repeating padding)."""
        cols = np.arange(self.rep_pos.shape[1])[None, :]
        return cols < self.n_rep[:, None]

    def _per_replica(self, row_values: np.ndarray) -> np.ndarray:
        """Broadcast a per-expert row vector over the [E, R] replica
        matrix (padding entries included; mask with _valid_mask)."""
        return np.broadcast_to(row_values[:, None], self.rep_pos.shape)

    @property
    def num_experts(self) -> int:
        return int(self.rep_pos.shape[0])

    @property
    def max_replicas(self) -> int:
        return int(self.rep_pos.shape[1])

    @property
    def n_slots(self) -> int:
        return self.n_ranks * self.slots_per_rank

    @property
    def n_spare(self) -> int:
        """Physical slots not holding any replica."""
        return self.n_slots - int(self.n_rep.sum())

    @property
    def is_bijective(self) -> bool:
        return (self.n_slots == self.num_experts
                and int(self.n_rep.max()) == 1)

    @property
    def slot_owner(self) -> np.ndarray:
        """[S] physical slot -> resident logical expert (-1 = empty)."""
        own = np.full(self.n_slots, -1, np.int32)
        valid = self._valid_mask()
        e_ids = self._per_replica(np.arange(self.num_experts,
                                            dtype=np.int32))
        own[self.rep_pos[valid]] = e_ids[valid]
        return own

    @property
    def rep_rank(self) -> np.ndarray:
        """[E, R] owning rank per replica (pad entries repeat the primary)."""
        return self.rep_pos // self.slots_per_rank

    def rank_loads(self, expert_load: np.ndarray,
                   weights: np.ndarray = None) -> np.ndarray:
        """Post-split per-rank loads [n_ranks]: each expert's load split
        over its replicas — equally (the round-robin dispatch rule) or by
        ``weights`` [E, R] (the weighted-split dispatch rule; rows are
        normalized over the valid replicas)."""
        load = np.asarray(expert_load, np.float64)
        valid = self._valid_mask()
        if weights is None:
            share = self._per_replica(load / np.maximum(self.n_rep, 1))
        else:
            w = np.where(valid, np.asarray(weights, np.float64), 0.0)
            tot = np.maximum(w.sum(axis=1, keepdims=True), 1e-12)
            share = load[:, None] * (w / tot)
        out = np.zeros(self.n_ranks, np.float64)
        np.add.at(out, self.rep_rank[valid], share[valid])
        return out

    def capacity_factor(self, expert_load: np.ndarray,
                        margin: float = 1.25,
                        floor: float = 1.0,
                        rank_alive: np.ndarray = None) -> float:
        """Dispatch ``capacity_factor`` sized from the *post-split*
        worst-case rank load instead of the bijective worst case.

        The per-rank dispatch buffer holds ``t*k/ep × capacity_factor``
        entries, so the factor must cover the peak rank's share of the
        routed load.  Under replication the hot experts are split across
        replicas, so the post-split peak (``rank_loads(load).max()`` over
        the equal-split model) is flatter than the bijective peak — the
        buffer (and its HBM) can shrink by the same ratio.  ``margin``
        is the safety headroom over the predicted peak; ``floor`` the
        minimum factor (1.0 = perfectly balanced provisioning).

        ``rank_alive`` [n_ranks] restricts the peak/ideal computation to
        live ranks (degraded mode: dead ranks serve no tokens, so the
        surviving ranks' buffers must absorb the redistributed load).
        """
        rl = self.rank_loads(expert_load)
        if rank_alive is not None:
            rl = rl[np.asarray(rank_alive, bool)]
        n = max(rl.shape[0], 1)
        tot = rl.sum()
        if tot <= 0:
            return float(floor)
        ib = rl.max() / (tot / n)              # post-split peak / ideal
        return float(max(floor, margin * ib))

    def slot_loads(self, expert_load: np.ndarray) -> np.ndarray:
        """Post-split per-physical-slot loads [S] (empty slots 0)."""
        load = np.asarray(expert_load, np.float64)
        share = self._per_replica(load / np.maximum(self.n_rep, 1))
        valid = self._valid_mask()
        out = np.zeros(self.n_slots, np.float64)
        np.add.at(out, self.rep_pos[valid], share[valid])
        return out

    def as_arrays(self):
        """(rep_pos [E,R], n_rep [E], slot_owner [S]) for the traced MoE
        layer (:class:`repro.core.ep_moe.Replication`)."""
        return self.rep_pos, self.n_rep, self.slot_owner

    # -- elastic views ----------------------------------------------------
    def masked(self, rank_alive: np.ndarray):
        """Mask dead ranks out of the set: ``(masked_set, lost_experts)``.

        Per expert, replicas on dead ranks are dropped and the row is
        re-padded from the first surviving replica — a table flip with no
        data motion, because surviving slabs are already resident (the
        distinct-rank planner invariant is what guarantees a candidate).
        An expert with *no* surviving replica keeps its original row (it
        still points at the dead slot, whose slab is gone) and is reported
        in ``lost_experts``; its tokens are unroutable until the expert is
        re-materialized from checkpoint.
        """
        alive = np.asarray(rank_alive, bool)
        if alive.shape != (self.n_ranks,):
            raise ValueError((alive.shape, self.n_ranks))
        rp = self.rep_pos.copy()
        nr = self.n_rep.copy()
        lost = []
        for ex in range(self.num_experts):
            n = int(self.n_rep[ex])
            live = [j for j in range(n) if alive[self.rep_rank[ex, j]]]
            if not live:
                lost.append(ex)
                continue
            row = [self.rep_pos[ex, j] for j in live]
            rp[ex] = row + [row[0]] * (self.max_replicas - len(row))
            nr[ex] = len(live)
        return (ReplicaSet(rp, nr, self.n_ranks, self.slots_per_rank),
                np.asarray(lost, np.int64))

    def hosts_rank(self, rank: int) -> bool:
        """Does any live replica reside on ``rank``?"""
        return bool((self.rep_rank[self._valid_mask()] == rank).any())

    # -- weighted token splitting -----------------------------------------
    SPLIT_QUANTUM = 12             # schedule length Q (lcm of 1..4, 6)

    def split_schedule(self, weights: np.ndarray = None) -> np.ndarray:
        """[E, Q] int32 replica-index schedule for weighted token
        splitting
        (:class:`repro.core.ep_moe.WeightedReplication.split_sched`).

        The traced dispatch sends the ``occ``-th routed token of expert
        ``e`` to replica ``sched[e, occ % Q]``.  With ``weights`` the
        schedule is built by deficit round-robin — per phase slot each
        replica accrues credit proportional to its normalized weight and
        the highest-credit replica (lowest index on ties) is picked — so
        token shares match the weights to quantization ±1/Q *interleaved*,
        not block-wise: shard-local occurrence counters stay within ±1 of
        the global split, the same property the equal round-robin has.
        With equal weights the schedule is exactly ``m % n_rep`` — when
        ``n_rep`` divides Q this is bitwise-identical to the unscheduled
        ``occ % n_rep`` path.
        """
        e, r = self.rep_pos.shape
        q = self.SPLIT_QUANTUM
        base = (np.arange(q)[None, :]
                % np.maximum(self.n_rep, 1)[:, None]).astype(np.int32)
        if weights is None:
            return base
        w = np.where(self._valid_mask(),
                     np.asarray(weights, np.float64), 0.0)
        sched = base.copy()
        for ex in range(e):
            n = int(self.n_rep[ex])
            ww = w[ex, :n]
            if n <= 1 or ww.sum() <= 0:
                continue
            ww = ww / ww.sum()
            credit = np.zeros(n)
            for m in range(q):
                credit += ww
                j = int(np.argmax(credit))   # argmax ties -> lowest index
                sched[ex, m] = j
                credit[j] -= 1.0
        return sched

    def residual_split_weights(self, expert_load: np.ndarray,
                               rank_alive: np.ndarray = None,
                               floor: float = 1e-3) -> np.ndarray:
        """[E, R] split weights proportional to host-rank *residual*
        capacity: a replica whose rank is already loaded (by the other
        experts it hosts) takes a smaller share of its expert's tokens.

        Residual of replica ``j`` = ``max(target - other_load_j, floor)``
        where ``target`` is the mean live-rank load and ``other_load_j``
        is the host rank's equal-split load minus this expert's own share
        (so an expert doesn't see its own traffic as congestion).
        Replicas on dead ranks get weight 0 (degraded mode).
        """
        load = np.asarray(expert_load, np.float64)
        rl = self.rank_loads(load)
        alive = (np.ones(self.n_ranks, bool) if rank_alive is None
                 else np.asarray(rank_alive, bool))
        n_live = max(int(alive.sum()), 1)
        target = rl[alive].sum() / n_live
        eps = floor * max(target, 1.0)
        w = np.zeros(self.rep_pos.shape)
        w[:, 0] = 1.0
        share = load / np.maximum(self.n_rep, 1)
        for ex in np.flatnonzero(self.n_rep > 1):
            n = int(self.n_rep[ex])
            ranks = self.rep_rank[ex, :n]
            other = rl[ranks] - share[ex]
            resid = np.maximum(target - other, eps)
            resid[~alive[ranks]] = 0.0
            if resid.sum() <= 0:           # every replica on a dead rank
                resid[:] = 1.0
            w[ex, :n] = resid
            w[ex, n:] = 0.0
        return w

    # -- constructors -----------------------------------------------------
    @classmethod
    def identity(cls, num_experts: int, n_ranks: int,
                 slots_per_rank: int = 0,
                 max_replicas: int = 1) -> "ReplicaSet":
        """Contiguous single-replica layout: expert ``e`` in slot
        ``(e // e_loc) * slots_per_rank + e % e_loc`` — with
        ``slots_per_rank == e_loc`` this is PR 2's identity placement."""
        return cls.from_placement(
            PlacementTable.identity(num_experts, n_ranks),
            slots_per_rank=slots_per_rank, max_replicas=max_replicas)

    @classmethod
    def from_placement(cls, table: PlacementTable,
                       slots_per_rank: int = 0,
                       max_replicas: int = 1) -> "ReplicaSet":
        """Lift a bijective table into a (possibly spare-padded) set."""
        e_loc = table.e_loc
        s_loc = slots_per_rank or e_loc
        assert s_loc >= e_loc, (s_loc, e_loc)
        pos = (table.e2r.astype(np.int64) * s_loc
               + table.local_slot.astype(np.int64))
        rep_pos = np.broadcast_to(
            pos[:, None], (table.num_experts, max_replicas)).astype(np.int32)
        return cls(rep_pos.copy(), np.ones(table.num_experts, np.int32),
                   table.n_ranks, s_loc)

    def ownership_matrix(self) -> np.ndarray:
        """[E, n_ranks] fractional ownership (rows sum to 1) — the cost
        model's replication view (``benchmarks/traces.rank_loads``)."""
        mat = np.zeros((self.num_experts, self.n_ranks))
        valid = self._valid_mask()
        frac = self._per_replica(1.0 / np.maximum(self.n_rep, 1))
        e_ids = self._per_replica(np.arange(self.num_experts))
        np.add.at(mat, (e_ids[valid], self.rep_rank[valid]), frac[valid])
        return mat
