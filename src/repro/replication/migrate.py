"""Live replica add/drop: ReplicaSet diff → physical-slot weight gather.

Same mechanism as bijective placement migration
(:mod:`repro.placement.migrate`): the expert weight arrays are stored in
physical-slot order ``[S, ...]``, and a new set is applied by one gather
along the slot axis — ``w_new[..., p, :] = w_old[..., gather_idx[p], :]``.
The produced :class:`ReplicaMigrationPlan` is interface-compatible with
:class:`~repro.placement.migrate.MigrationPlan` (``gather_idx`` /
``is_noop`` / ``n_moved``), so ``placement.migrate.apply_to_params``
applies it unchanged.

Source selection per changed slot: prefer an old replica of the incoming
expert that already lives on the *destination* slot's rank (an HBM-local
copy, zero cross-rank bytes), else the old primary (a cross-rank slab
transfer, charged ``bytes_per_expert``).  Retiring a replica is free —
the slot merely stops being routable (its stale weights are unreachable:
no ``rep_pos`` entry points at it).

Consistency rule: a replica is routable only after its slab lands.  The
plan carries the *pending* set; :class:`~repro.replication.manager.
ReplicaManager` keeps serving the old set until ``commit(plan)`` — which
the engine calls only after ``apply_to_params`` has produced the permuted
weights — flips the routable table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np

from repro.placement.migrate import MOE_WEIGHT_KEYS, jnp_take, moe_param_paths
from repro.replication.replica_set import ReplicaSet


@dataclasses.dataclass(frozen=True)
class ReplicaMigrationPlan:
    gather_idx: np.ndarray     # [S] new physical slot -> old physical slot
    changed_slots: np.ndarray  # slots whose resident expert changed
    crossrank_slots: np.ndarray  # changed slots sourced from another rank
    moved_bytes: int           # cross-rank weight bytes of the transition
    new_set: "ReplicaSet"      # the pending (not yet routable) set

    @property
    def n_moved(self) -> int:
        return int(self.changed_slots.shape[0])

    @property
    def is_noop(self) -> bool:
        return self.n_moved == 0


def diff(old: ReplicaSet, new: ReplicaSet,
         bytes_per_expert: int = 0) -> ReplicaMigrationPlan:
    """The slot gather (and cost) taking placed weights from old to new."""
    assert old.num_experts == new.num_experts, (old, new)
    assert old.n_ranks == new.n_ranks, (old.n_ranks, new.n_ranks)
    assert old.slots_per_rank == new.slots_per_rank, \
        (old.slots_per_rank, new.slots_per_rank)
    s = old.n_slots
    own_old, own_new = old.slot_owner, new.slot_owner
    gather = np.arange(s, dtype=np.int64)
    changed, cross = [], []
    for p in range(s):
        ex = own_new[p]
        if ex == own_old[p]:
            continue
        if ex < 0:
            # retired slot: content is unreachable, keep it in place
            continue
        changed.append(p)
        srcs = old.rep_pos[ex, : old.n_rep[ex]]
        same_rank = srcs[srcs // old.slots_per_rank
                         == p // new.slots_per_rank]
        if same_rank.shape[0]:
            gather[p] = int(same_rank[0])          # HBM-local copy
        else:
            gather[p] = int(srcs[0])               # cross-rank transfer
            cross.append(p)
    changed = np.asarray(changed, np.int64)
    cross = np.asarray(cross, np.int64)
    return ReplicaMigrationPlan(
        gather_idx=gather, changed_slots=changed, crossrank_slots=cross,
        moved_bytes=int(cross.shape[0]) * bytes_per_expert, new_set=new)


def expand_moe_params(params: Dict[str, Any], rset: ReplicaSet
                      ) -> Dict[str, Any]:
    """Lay logically-ordered ``[.., E, ..]`` expert weights out into the
    set's physical ``[.., S, ..]`` slot order (empty spares zeroed).

    The inverse of the identity assumption: a freshly initialised /
    restored model stores one row per logical expert; a replica engine
    stores one row per physical slot.  Routers stay logical and are not
    touched.  Works on stacked ``[n_blocks, E, ...]`` scan weights and on
    unstacked ``[E, ...]`` ones.
    """
    owner = rset.slot_owner
    idx = np.where(owner >= 0, owner, 0).astype(np.int64)
    empty = owner < 0
    out = dict(params)
    for group, lname in moe_param_paths(params):
        grp = dict(out[group])
        lp = dict(grp[lname])
        moe = dict(lp["moe"])
        for key in MOE_WEIGHT_KEYS:
            w = moe[key]
            axis = w.ndim - 3          # [.., E, a, b]: expert axis
            assert w.shape[axis] == rset.num_experts, \
                (key, w.shape, rset.num_experts)
            w2 = jnp_take(w, idx, axis)
            if empty.any():
                mask_shape = [1] * w2.ndim
                mask_shape[axis] = rset.n_slots
                if isinstance(w2, np.ndarray):
                    w2 = w2 * (~empty).reshape(mask_shape)
                else:
                    import jax.numpy as jnp
                    w2 = w2 * jnp.asarray(
                        (~empty).reshape(mask_shape), w2.dtype)
            moe[key] = w2
        lp["moe"] = moe
        grp[lname] = lp
        out[group] = grp
    return out
