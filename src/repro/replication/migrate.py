"""Live replica add/drop: ReplicaSet diff → physical-slot weight gather.

Same mechanism as bijective placement migration
(:mod:`repro.placement.migrate`): the expert weight arrays are stored in
physical-slot order ``[S, ...]``, and a new set is applied by one gather
along the slot axis — ``w_new[..., p, :] = w_old[..., gather_idx[p], :]``.
The produced :class:`ReplicaMigrationPlan` is interface-compatible with
:class:`~repro.placement.migrate.MigrationPlan` (``gather_idx`` /
``is_noop`` / ``n_moved``), so ``placement.migrate.apply_to_params``
applies it unchanged.

Source selection per changed slot: prefer an old replica of the incoming
expert that already lives on the *destination* slot's rank (an HBM-local
copy, zero cross-rank bytes), else the old primary (a cross-rank slab
transfer, charged ``bytes_per_expert``).  Retiring a replica is free —
the slot merely stops being routable (its stale weights are unreachable:
no ``rep_pos`` entry points at it).

Consistency rule: a replica is routable only after its slab lands.  The
plan carries the *pending* set; :class:`~repro.replication.manager.
ReplicaManager` keeps serving the old set until ``commit(plan)`` — which
the engine calls only after ``apply_to_params`` has produced the permuted
weights — flips the routable table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np

from repro.placement.migrate import (MOE_WEIGHT_KEYS, jnp_take,
                                     jnp_take_layers, moe_param_paths)
from repro.placement.migrate import apply_layers_to_params as \
    _apply_layers_to_params
from repro.replication.replica_set import ReplicaSet


@dataclasses.dataclass(frozen=True)
class ReplicaMigrationPlan:
    gather_idx: np.ndarray     # [S] new physical slot -> old physical slot
    changed_slots: np.ndarray  # slots whose resident expert changed
    crossrank_slots: np.ndarray  # changed slots sourced from another rank
    moved_bytes: int           # cross-rank weight bytes of the transition
    new_set: "ReplicaSet"      # the pending (not yet routable) set

    @property
    def n_moved(self) -> int:
        return int(self.changed_slots.shape[0])

    @property
    def is_noop(self) -> bool:
        return self.n_moved == 0


@dataclasses.dataclass(frozen=True)
class LayerReplicaMigrationPlan:
    """Layer-diff replica transition across per-layer replica sets.

    Same staged-commit semantics as :class:`ReplicaMigrationPlan` (the
    pending ``new_sets`` become routable only on ``commit``), but each
    scanned block's slot slab is gathered by its own ``gather_idx`` row;
    unchanged layers carry the identity row and cost nothing."""
    gather_idx: np.ndarray        # [L, S] per-layer new slot -> old slot
    changed_per_layer: np.ndarray  # [L] slots whose resident changed
    crossrank_per_layer: np.ndarray  # [L] changed slots crossing ranks
    moved_bytes: int              # cross-rank bytes, changed layers only
    new_sets: tuple               # the pending per-layer ReplicaSets

    @property
    def n_layers(self) -> int:
        return int(self.gather_idx.shape[0])

    @property
    def changed_layers(self) -> np.ndarray:
        return np.flatnonzero(self.changed_per_layer)

    @property
    def n_moved(self) -> int:
        """Total (slot, layer) pairs whose resident expert changed."""
        return int(self.changed_per_layer.sum())

    @property
    def n_crossrank(self) -> int:
        return int(self.crossrank_per_layer.sum())

    @property
    def is_noop(self) -> bool:
        return self.n_moved == 0


def diff(old: ReplicaSet, new: ReplicaSet,
         bytes_per_expert: int = 0) -> ReplicaMigrationPlan:
    """The slot gather (and cost) taking placed weights from old to new."""
    assert old.num_experts == new.num_experts, (old, new)
    assert old.n_ranks == new.n_ranks, (old.n_ranks, new.n_ranks)
    assert old.slots_per_rank == new.slots_per_rank, \
        (old.slots_per_rank, new.slots_per_rank)
    s = old.n_slots
    own_old, own_new = old.slot_owner, new.slot_owner
    gather = np.arange(s, dtype=np.int64)
    changed, cross = [], []
    for p in range(s):
        ex = own_new[p]
        if ex == own_old[p]:
            continue
        if ex < 0:
            # retired slot: content is unreachable, keep it in place
            continue
        changed.append(p)
        srcs = old.rep_pos[ex, : old.n_rep[ex]]
        same_rank = srcs[srcs // old.slots_per_rank
                         == p // new.slots_per_rank]
        if same_rank.shape[0]:
            gather[p] = int(same_rank[0])          # HBM-local copy
        else:
            gather[p] = int(srcs[0])               # cross-rank transfer
            cross.append(p)
    changed = np.asarray(changed, np.int64)
    cross = np.asarray(cross, np.int64)
    return ReplicaMigrationPlan(
        gather_idx=gather, changed_slots=changed, crossrank_slots=cross,
        moved_bytes=int(cross.shape[0]) * bytes_per_expert, new_set=new)


def diff_layers(old_sets, new_sets,
                bytes_per_expert: int = 0) -> LayerReplicaMigrationPlan:
    """Layer-diff between two per-layer replica-set stacks.

    ``bytes_per_expert`` is the slab bytes of one expert in ONE scanned
    block; only cross-rank (slot, layer) sources are charged."""
    assert len(old_sets) == len(new_sets), (len(old_sets), len(new_sets))
    gather, changed, cross = [], [], []
    for old, new in zip(old_sets, new_sets):
        p = diff(old, new)
        gather.append(p.gather_idx)
        changed.append(p.n_moved)
        cross.append(int(p.crossrank_slots.shape[0]))
    cross = np.asarray(cross, np.int64)
    return LayerReplicaMigrationPlan(
        gather_idx=np.stack(gather).astype(np.int64),
        changed_per_layer=np.asarray(changed, np.int64),
        crossrank_per_layer=cross,
        moved_bytes=int(cross.sum()) * bytes_per_expert,
        new_sets=tuple(new_sets))


def apply_layers_to_params(params: Dict[str, Any], plan,
                           layers) -> Dict[str, Any]:
    """Chunked subset apply of a replica plan: gather only ``layers``'
    slot slabs (identity rows elsewhere).  Replica ``gather_idx``
    semantics are identical to placement's (new slot <- old slot), so
    this delegates to :func:`repro.placement.migrate.
    apply_layers_to_params`; a shared :class:`ReplicaMigrationPlan` is
    one chunk (layer 0 = the whole plan)."""
    return _apply_layers_to_params(params, plan, layers)


def expand_moe_params(params: Dict[str, Any], rset) -> Dict[str, Any]:
    """Lay logically-ordered ``[.., E, ..]`` expert weights out into the
    set's physical ``[.., S, ..]`` slot order (empty spares zeroed).

    The inverse of the identity assumption: a freshly initialised /
    restored model stores one row per logical expert; a replica engine
    stores one row per physical slot.  Routers stay logical and are not
    touched.  Works on stacked ``[n_blocks, E, ...]`` scan weights and on
    unstacked ``[E, ...]`` ones.

    ``rset`` is a single :class:`ReplicaSet` (shared across layers) or a
    sequence of per-layer sets — the latter requires stacked
    ``[n_blocks, E, ...]`` weights and expands each block by its own
    layer's slot layout.
    """
    rsets = list(rset) if isinstance(rset, (list, tuple)) else None
    if rsets is not None and len(rsets) == 1:
        rset, rsets = rsets[0], None
    if rsets is not None:
        return _expand_layers(params, rsets)
    owner = rset.slot_owner
    idx = np.where(owner >= 0, owner, 0).astype(np.int64)
    empty = owner < 0
    out = dict(params)
    for group, lname in moe_param_paths(params):
        grp = dict(out[group])
        lp = dict(grp[lname])
        moe = dict(lp["moe"])
        for key in MOE_WEIGHT_KEYS:
            w = moe[key]
            axis = w.ndim - 3          # [.., E, a, b]: expert axis
            assert w.shape[axis] == rset.num_experts, \
                (key, w.shape, rset.num_experts)
            w2 = jnp_take(w, idx, axis)
            if empty.any():
                mask_shape = [1] * w2.ndim
                mask_shape[axis] = rset.n_slots
                if isinstance(w2, np.ndarray):
                    w2 = w2 * (~empty).reshape(mask_shape)
                else:
                    import jax.numpy as jnp
                    w2 = w2 * jnp.asarray(
                        (~empty).reshape(mask_shape), w2.dtype)
            moe[key] = w2
        lp["moe"] = moe
        grp[lname] = lp
        out[group] = grp
    return out


def _expand_layers(params: Dict[str, Any], rsets) -> Dict[str, Any]:
    """Per-layer expansion: block ``l``'s ``[E, ...]`` slab laid out by
    ``rsets[l]``'s slot order."""
    owner = np.stack([rs.slot_owner for rs in rsets])        # [L, S]
    idx = np.where(owner >= 0, owner, 0).astype(np.int64)
    empty = owner < 0
    n_e = rsets[0].num_experts
    out = dict(params)
    for group, lname in moe_param_paths(params):
        grp = dict(out[group])
        lp = dict(grp[lname])
        moe = dict(lp["moe"])
        for key in MOE_WEIGHT_KEYS:
            w = moe[key]
            assert w.ndim == 4 and w.shape[0] == len(rsets) \
                and w.shape[1] == n_e, (key, w.shape, len(rsets), n_e)
            w2 = jnp_take_layers(w, idx)
            if empty.any():
                mask = (~empty).reshape(empty.shape + (1, 1))
                if isinstance(w2, np.ndarray):
                    w2 = w2 * mask
                else:
                    import jax.numpy as jnp
                    w2 = w2 * jnp.asarray(mask, w2.dtype)
            moe[key] = w2
        lp["moe"] = moe
        grp[lname] = lp
        out[group] = grp
    return out
