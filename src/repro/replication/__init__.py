"""Redundant experts: replication with deterministic token splitting.

The third arm of the load-balancing comparison (vs. ReaLB's precision
switching and ``repro.placement``'s bijective remapping): an EPLB-style
planner duplicates the predictor's hottest (vision-weighted) experts
into spare weight slots, the MoE layer splits each hot expert's routed
tokens round-robin across its replicas (see
``repro.core.ep_moe.Replication``), and live replica add/drop rides the
placement weight-slab gather with a two-phase consistency rule — a
replica becomes routable only after its slab lands.
"""
from repro.replication.manager import ReplicaManager
from repro.replication.migrate import (LayerReplicaMigrationPlan,
                                       ReplicaMigrationPlan, diff,
                                       diff_layers, expand_moe_params)
from repro.replication.planner import plan_from_config, plan_replication
from repro.replication.replica_set import ReplicaSet

__all__ = [
    "ReplicaManager", "ReplicaMigrationPlan", "LayerReplicaMigrationPlan",
    "diff", "diff_layers", "expand_moe_params",
    "plan_from_config", "plan_replication", "ReplicaSet",
]
