"""ReplicaManager: the serving-side control loop of expert replication.

The replication twin of :class:`~repro.placement.manager.PlacementManager`
— same EWMA predictor, same cadence/churn discipline — but the planner
produces a :class:`ReplicaSet` and the migration path adds/retires
replica slabs instead of permuting a bijection.

Two-phase consistency (a replica is routable only after its slab lands):
``maybe_replan`` *stages* a plan and keeps serving the old set; the
engine gathers the weight slabs (``placement.migrate.apply_to_params``)
and only then calls ``commit(plan)``, which flips the routable table and
books the accounting.  Under async overlapped migration the commit is
per layer: ``commit_layers(plan, layers)`` flips exactly the layers
whose slab chunks have landed (``repro.serving.async_migrate``), so the
consistency rule holds layer-wise while the rest of the plan drains.  A
crashed / abandoned apply (``abort``) leaves the old set fully
consistent with the untouched weights.  The staging/commit machinery is
shared with :class:`~repro.placement.manager.PlacementManager` via
``ReplanDiscipline``.

Per-layer replica sets (``ReplicationConfig.per_layer``): one set per
scanned MoE block, each planned from its own predictor row; the staged
plan is a layer-diff (:class:`~repro.replication.migrate.
LayerReplicaMigrationPlan`) whose slab traffic covers changed layers
only, and ``device_tables`` returns stacked ``[L, ...]`` arrays for the
transformer's layer scan.  ``n_tables == 1`` degrades bitwise to the
shared-set behavior.

Decode-regime replanning mirrors placement: a separate decode EWMA
window (``decode_halflife``) plus a decode-iteration cadence
(``decode_replan_every``).

Optionally gated by a cost model (``cost_gate``): a replan fires only
when the predicted layer-time savings over the plan's amortization
horizon exceed the migration cost — see
:class:`benchmarks.costmodel.ReplanCostGate`.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

import numpy as np

from repro.configs.base import ModelConfig, ReplicationConfig
from repro.placement import migrate as pmigrate
from repro.placement.manager import ReplanDiscipline
from repro.placement.predictor import EWMAPredictor
from repro.replication import migrate
from repro.replication.planner import plan_replication
from repro.replication.replica_set import ReplicaSet

Plan = Union[migrate.ReplicaMigrationPlan, migrate.LayerReplicaMigrationPlan]


class ReplicaManager(ReplanDiscipline):
    ckpt_group = "replication"     # engine checkpoint group name
    _kind = "replication"          # audit / span label

    def __init__(self, cfg: ModelConfig, rpcfg: ReplicationConfig, ep: int,
                 cost_gate=None):
        assert cfg.moe is not None, "replication requires an MoE model"
        n_blocks, n_moe_per_block = cfg.moe_block_structure()
        n_moe = n_blocks * n_moe_per_block
        if rpcfg.per_layer:
            n_tables = n_blocks
            bpe = pmigrate.expert_bytes(cfg, max(n_moe_per_block, 1))
        else:
            n_tables = 1
            bpe = pmigrate.expert_bytes(cfg, max(n_moe, 1))
        self._setup(cfg.moe.num_experts, rpcfg, ep, bpe, cost_gate,
                    n_tables=n_tables)
        self.cfg = cfg

    @classmethod
    def from_geometry(cls, num_experts: int, rpcfg: ReplicationConfig,
                      ep: int, bytes_per_expert: int = 0,
                      cost_gate=None, n_layers: int = 1) -> "ReplicaManager":
        """Model-config-free construction (cost-model simulators).

        ``bytes_per_expert`` is per-table granularity: the whole stack for
        a shared manager, one scanned block for a per-layer one."""
        self = cls.__new__(cls)
        self._setup(num_experts, rpcfg, ep, bytes_per_expert, cost_gate,
                    n_tables=n_layers if rpcfg.per_layer else 1)
        self.cfg = None
        return self

    def _setup(self, num_experts: int, rpcfg: ReplicationConfig, ep: int,
               bytes_per_expert: int, cost_gate=None, n_tables: int = 1):
        assert num_experts % ep == 0, (num_experts, ep)
        assert n_tables >= 1, n_tables
        self.rpcfg, self.ep = rpcfg, ep
        self.n_tables = n_tables
        self.slots_per_rank = num_experts // ep + rpcfg.spare_per_rank
        self.rsets: List[ReplicaSet] = [
            ReplicaSet.identity(num_experts, ep,
                                slots_per_rank=self.slots_per_rank,
                                max_replicas=rpcfg.max_replicas)
            for _ in range(n_tables)]
        self.predictor = EWMAPredictor(num_experts, alpha=rpcfg.ewma_alpha,
                                       decode_halflife=rpcfg.decode_halflife)
        self.bytes_per_expert = bytes_per_expert
        self.cost_gate = cost_gate
        # measured-bandwidth EWMA pricing this manager's slab copies;
        # shared with the cost gate so both price the same bytes/s
        self.bandwidth = pmigrate.MigrationBandwidth(rpcfg.migration_bw)
        if cost_gate is not None \
                and getattr(cost_gate, "bandwidth", False) is None:
            cost_gate.bandwidth = self.bandwidth
        self._pending: Optional[Plan] = None
        self._pending_remaining = None
        # elastic serving: which EP ranks are live.  Dead ranks are masked
        # out of the capacity model, the planner and the split weights;
        # the ElasticCoordinator owns the transitions.
        self.rank_alive = np.ones(ep, bool)
        self.must_layers = set()
        self._event_replan = False
        # cumulative accounting
        self.n_migrations = 0
        self.migrated_bytes = 0
        self.migrated_slots = 0
        self.migrated_bytes_per_layer = np.zeros(n_tables, np.int64)
        self.last_replan_iter = -1
        self._decode_since_replan = 0
        self.cum_slot_load = np.zeros(self.n_slots, np.float64)

    # -- geometry ----------------------------------------------------------
    @property
    def per_layer(self) -> bool:
        return self.n_tables > 1

    @property
    def rset(self) -> ReplicaSet:
        """The shared set (first set of a per-layer manager)."""
        return self.rsets[0]

    @rset.setter
    def rset(self, rs: ReplicaSet) -> None:
        self.rsets[0] = rs

    @property
    def num_experts(self) -> int:
        return self.rsets[0].num_experts

    @property
    def n_slots(self) -> int:
        return self.ep * self.slots_per_rank

    def reset(self) -> None:
        """Back to a fresh identity state (e.g. restoring a checkpoint
        written by a replication-free engine: weights are logical-order
        and there is no replica state to resume)."""
        self._setup(self.num_experts, self.rpcfg, self.ep,
                    self.bytes_per_expert, self.cost_gate,
                    n_tables=self.n_tables)

    def device_tables(self):
        """(rep_pos, n_rep, slot_owner[, split_sched]) of the *routable*
        set(s) — staged plans are invisible here until committed.
        Stacked ``[L, ...]`` arrays for a per-layer manager (scanned
        alongside the block params), plain arrays for a shared one.
        Under ``weighted_split`` a 4th entry carries the per-expert
        replica schedule built from the predictor's residual-capacity
        weights (equal-share until the first observation)."""
        if not self.per_layer:
            base = self.rsets[0].as_arrays()
            if not self.rpcfg.weighted_split:
                return base
            return base + (self._split_schedules()[0],)
        base = (np.stack([rs.rep_pos for rs in self.rsets]),
                np.stack([rs.n_rep for rs in self.rsets]),
                np.stack([rs.slot_owner for rs in self.rsets]))
        if not self.rpcfg.weighted_split:
            return base
        return base + (np.stack(self._split_schedules()),)

    def _split_schedules(self) -> List[np.ndarray]:
        """Per-set ``[E, Q]`` weighted-split schedules from the predicted
        loads (residual host-rank capacity; dead ranks weight 0)."""
        pred = self.predictor.predict_layers("mixed")
        loads = None
        if pred is not None and pred[0].sum() > 0:
            loads = pred[0]
        out = []
        alive = self._rank_alive_arg()
        for l, rs in enumerate(self.rsets):
            if loads is None:
                out.append(rs.split_schedule())
                continue
            load_l = loads[l] if (self.per_layer
                                  and loads.shape[0] == self.n_tables) \
                else loads.sum(0)
            w = rs.residual_split_weights(load_l, rank_alive=alive)
            out.append(rs.split_schedule(w))
        return out

    def wants_table_refresh(self, it: int) -> bool:
        """Should the engine rebuild its cached device tables at ``it``
        even though no plan committed?  Weighted-split schedules track
        the predictor, so they are refreshed on the replan cadence."""
        return (self.rpcfg.weighted_split and self.rpcfg.replan_every > 0
                and it % self.rpcfg.replan_every == 0)

    # -- elastic serving ---------------------------------------------------
    def _rank_alive_arg(self) -> Optional[np.ndarray]:
        """``rank_alive`` for planner/capacity calls — None while every
        rank is live (the planners' zero-drift default path)."""
        return None if self.rank_alive.all() else self.rank_alive.copy()

    def mask_dead_ranks(self) -> Dict[int, np.ndarray]:
        """Re-pad every routable set onto the live ranks (an immediate
        table flip: surviving replicas' slabs are already resident).
        Returns ``{layer: lost_experts}`` for experts with no surviving
        replica — unroutable until re-materialized from checkpoint."""
        lost: Dict[int, np.ndarray] = {}
        for l, rs in enumerate(self.rsets):
            masked, lost_l = rs.masked(self.rank_alive)
            self.rsets[l] = masked
            if lost_l.size:
                lost[l] = lost_l
        return lost

    def hosts_rank(self, rank: int) -> bool:
        """Does any routable set keep a live replica on ``rank``?"""
        return any(rs.hosts_rank(rank) for rs in self.rsets)

    # -- engine feeds ------------------------------------------------------
    def observe(self, expert_stats: np.ndarray,
                decode: bool = False) -> None:
        """expert_stats [n_blocks, 2, E]: per-MoE-layer (load, vis) counts
        per *logical* expert of one engine iteration.  ``decode`` routes
        the observation into the decode window when one is configured."""
        es = np.asarray(expert_stats, np.float64)
        self.predictor.observe(es[:, 0, :], es[:, 1, :], decode=decode)
        if decode:
            self._decode_since_replan += 1

    def observe_slots(self, slot_stats: np.ndarray) -> None:
        """slot_stats [n_blocks, 2, S]: post-split physical-slot loads —
        cumulative replica-utilization accounting (diagnostics only)."""
        ss = np.asarray(slot_stats, np.float64)
        if ss.shape[-1] == self.n_slots:
            self.cum_slot_load += ss[:, 0, :].sum(0)

    # -- replica-aware dispatch capacity -----------------------------------
    def capacity_factor(self, margin: float = 1.25,
                        floor: float = 1.0) -> float:
        """Effective dispatch ``capacity_factor`` from the post-split
        predicted loads — the replica-aware shrink of the per-rank
        dispatch buffer.  Conservative on both axes: the worst layer
        (per-layer manager) and the worst prediction *window* price the
        buffer, so a decode-regime drift the main window cannot see
        still re-grows it.  Before any observation there is nothing to
        justify a shrink: returns +inf (the engine clamps to its static
        provision), never the floor."""
        out = 0.0
        seen = False
        for regime in ("mixed", "decode"):
            pred = self.predictor.predict_layers(regime)
            if pred is None:
                continue
            loads, _ = pred
            if loads.sum() <= 0:
                continue
            seen = True
            alive = self._rank_alive_arg()
            if self.per_layer and loads.shape[0] == self.n_tables:
                f = max(rs.capacity_factor(loads[l], margin, floor,
                                           rank_alive=alive)
                        for l, rs in enumerate(self.rsets))
            else:
                f = self.rset.capacity_factor(loads.sum(0), margin, floor,
                                              rank_alive=alive)
            out = max(out, f)
        return out if seen else float("inf")

    # -- replanning --------------------------------------------------------
    def _discipline_cfg(self) -> ReplicationConfig:
        return self.rpcfg

    def _replan_shared(self, it: int, regime: str) -> Optional[Plan]:
        """The shared-set planning attempt (cadence already hit — the
        discipline's ``maybe_replan`` dispatched here).  The staged plan
        is pending: the routable set(s) (and therefore
        ``device_tables``) are unchanged until :meth:`commit`."""
        p = self.rpcfg
        forced = self._event_now
        load, vis = self.predictor.predict(regime)
        if load.sum() <= 0:
            return self._decide("zero-load")
        new = plan_replication(load, self.ep, self.slots_per_rank,
                               max_replicas=p.max_replicas, vis=vis,
                               vis_weight=p.vis_weight,
                               rank_alive=self._rank_alive_arg())
        # churn guard: require a predicted post-split max-rank-load gain
        # (event-triggered replans — rank loss/rejoin — bypass the guard
        # and the cost gate: availability beats churn discipline)
        old_max = self.rset.rank_loads(load).max()
        new_max = new.rank_loads(load).max()
        gain = (old_max - new_max) / old_max if old_max > 0 else 0.0
        if not forced and (old_max <= 0 or gain < p.min_gain):
            return self._decide("min-gain", pred_gain=float(gain))
        plan = migrate.diff(self.rset, new, self.bytes_per_expert)
        if plan.is_noop:
            return self._decide("noop", pred_gain=float(gain),
                                changed_layers=0)
        price = dict(
            pred_gain=float(gain),
            migration_bytes=int(plan.moved_bytes),
            migration_s=float(self.migration_seconds(plan.moved_bytes)),
            n_moved=len(plan.crossrank_slots))
        if not forced and not self._gate_accept(
                self.rset.rank_loads(load), new.rank_loads(load),
                len(plan.crossrank_slots)):
            return self._decide("cost-gate", **price)
        self.last_replan_iter = it
        self._decide("staged", **price)
        return self._stage(plan)

    def rank_heatmap(self, expert_stats, slot_stats=None) -> np.ndarray:
        """Realized per-layer per-rank loads ``[n_blocks, ep]`` of one
        iteration.  Prefers the post-split physical ``slot_stats`` (the
        exact loads replica token-splitting produced); falls back to the
        logical expert stats under the routable sets' equal-split
        model."""
        if slot_stats is not None:
            ss = np.asarray(slot_stats, np.float64)
            if ss.shape[-1] == self.n_slots:
                return ss[:, 0, :].reshape(
                    ss.shape[0], self.ep, self.slots_per_rank).sum(-1)
        loads = np.asarray(expert_stats, np.float64)[:, 0, :]
        if self.per_layer and loads.shape[0] == self.n_tables:
            return np.stack([self.rsets[l].rank_loads(loads[l])
                             for l in range(loads.shape[0])])
        return np.stack([self.rset.rank_loads(loads[l])
                         for l in range(loads.shape[0])])

    # per-layer replan hooks (loop lives in ReplanDiscipline); the staged
    # layer-diff copies slabs for changed layers only, priced cross-rank
    def _layer_states(self) -> list:
        return self.rsets

    def _plan_one_layer(self, load: np.ndarray,
                        vis: np.ndarray) -> ReplicaSet:
        p = self.rpcfg
        return plan_replication(load, self.ep, self.slots_per_rank,
                                max_replicas=p.max_replicas, vis=vis,
                                vis_weight=p.vis_weight,
                                rank_alive=self._rank_alive_arg())

    def _diff_layer_states(self, old_states: list, new_states: list
                           ) -> migrate.LayerReplicaMigrationPlan:
        return migrate.diff_layers(old_states, new_states,
                                   self.bytes_per_expert)

    def _layer_gate_moved(self,
                          plan: migrate.LayerReplicaMigrationPlan) -> int:
        return plan.n_crossrank

    def _accept_layer_plan(self, plan: migrate.LayerReplicaMigrationPlan,
                           new_states: list
                           ) -> migrate.LayerReplicaMigrationPlan:
        return self._stage(plan)           # staged, routable only on commit

    def layer_bytes(self, plan: Plan, layer: int) -> int:
        if isinstance(plan, migrate.LayerReplicaMigrationPlan):
            return int(plan.crossrank_per_layer[layer]) \
                * self.bytes_per_expert
        return int(plan.moved_bytes)

    def _commit_one_layer(self, plan: Plan, layer: int) -> None:
        b = self.layer_bytes(plan, layer)
        if isinstance(plan, migrate.LayerReplicaMigrationPlan):
            self.rsets[layer] = plan.new_sets[layer]
            self.migrated_slots += int(plan.changed_per_layer[layer])
        else:
            self.rsets[0] = plan.new_set
            self.migrated_slots += plan.n_moved
        self.migrated_bytes += b
        self.migrated_bytes_per_layer[layer] += b

    def migration_seconds(self, moved_bytes: int) -> float:
        """Virtual-time cost of copying ``moved_bytes`` over the fabric
        — priced at the measured-bandwidth EWMA (the configured
        ``migration_bw`` until the first timed apply calibrates it)."""
        return self.bandwidth.seconds(moved_bytes)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"rep_pos": np.stack([rs.rep_pos for rs in self.rsets]),
               "n_rep": np.stack([rs.n_rep for rs in self.rsets]),
               "n_ranks": np.int64(self.ep),
               "n_tables": np.int64(self.n_tables),
               "slots_per_rank": np.int64(self.slots_per_rank),
               "n_migrations": np.int64(self.n_migrations),
               "migrated_bytes": np.int64(self.migrated_bytes),
               "migrated_slots": np.int64(self.migrated_slots),
               "migrated_bytes_per_layer": self.migrated_bytes_per_layer,
               "cum_slot_load": self.cum_slot_load}
        for k, v in self.predictor.state_dict().items():
            out[f"pred_{k}"] = v
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        assert int(state["n_ranks"]) == self.ep, \
            (int(state["n_ranks"]), self.ep)
        assert int(state["slots_per_rank"]) == self.slots_per_rank, \
            (int(state["slots_per_rank"]), self.slots_per_rank)
        nt = int(state.get("n_tables", 1))
        if nt != self.n_tables:
            raise ValueError(
                f"checkpoint holds {nt} replica set(s) but this manager "
                f"plans {self.n_tables} — per-layer and shared "
                "checkpoints are not interchangeable (the saved weights "
                "are slot-ordered per the writer's sets)")
        rep_pos = np.asarray(state["rep_pos"], np.int32)
        n_rep = np.asarray(state["n_rep"], np.int32)
        if rep_pos.ndim == 2:          # legacy single-set layout
            rep_pos, n_rep = rep_pos[None], n_rep[None]
        assert rep_pos.shape[-1] == self.rsets[0].max_replicas, \
            (rep_pos.shape, self.rsets[0].max_replicas)
        self.rsets = [ReplicaSet(rep_pos[l], n_rep[l], self.ep,
                                 self.slots_per_rank)
                      for l in range(self.n_tables)]
        self.n_migrations = int(state["n_migrations"])
        self.migrated_bytes = int(state["migrated_bytes"])
        self.migrated_slots = int(state["migrated_slots"])
        self.migrated_bytes_per_layer = np.asarray(
            state.get("migrated_bytes_per_layer",
                      np.zeros(self.n_tables)), np.int64).reshape(
            self.n_tables)
        self.cum_slot_load = np.asarray(state["cum_slot_load"], np.float64)
        self._pending = None
        self._pending_remaining = None
        self._decode_since_replan = 0
        # elastic state is runtime-only (a restore implies a restart onto
        # a healthy mesh); checkpoints are refused mid-recovery anyway
        self.rank_alive = np.ones(self.ep, bool)
        self.must_layers = set()
        self._event_replan = False
        self.predictor.load_state_dict(
            {k[len("pred_"):]: v for k, v in state.items()
             if k.startswith("pred_")})
