"""ReplicaManager: the serving-side control loop of expert replication.

The replication twin of :class:`~repro.placement.manager.PlacementManager`
— same EWMA predictor, same cadence/churn discipline — but the planner
produces a :class:`ReplicaSet` and the migration path adds/retires
replica slabs instead of permuting a bijection.

Two-phase consistency (a replica is routable only after its slab lands):
``maybe_replan`` *stages* a plan and keeps serving the old set; the
engine gathers the weight slabs (``placement.migrate.apply_to_params``)
and only then calls ``commit(plan)``, which flips the routable table and
books the accounting.  A crashed / abandoned apply (``abort``) leaves the
old set fully consistent with the untouched weights.

Optionally gated by a cost model (``cost_gate``): a replan fires only
when the predicted layer-time savings over the plan's amortization
horizon exceed the migration cost — see
:class:`benchmarks.costmodel.ReplanCostGate`.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ReplicationConfig
from repro.placement import migrate as pmigrate
from repro.placement.predictor import EWMAPredictor
from repro.replication import migrate
from repro.replication.planner import plan_replication
from repro.replication.replica_set import ReplicaSet


class ReplicaManager:
    ckpt_group = "replication"     # engine checkpoint group name

    def __init__(self, cfg: ModelConfig, rpcfg: ReplicationConfig, ep: int,
                 cost_gate=None):
        assert cfg.moe is not None, "replication requires an MoE model"
        n_moe = sum(1 for f in cfg.ffn_kinds() if f == "moe")
        self._setup(cfg.moe.num_experts, rpcfg, ep,
                    pmigrate.expert_bytes(cfg, max(n_moe, 1)), cost_gate)
        self.cfg = cfg

    @classmethod
    def from_geometry(cls, num_experts: int, rpcfg: ReplicationConfig,
                      ep: int, bytes_per_expert: int = 0,
                      cost_gate=None) -> "ReplicaManager":
        """Model-config-free construction (cost-model simulators)."""
        self = cls.__new__(cls)
        self._setup(num_experts, rpcfg, ep, bytes_per_expert, cost_gate)
        self.cfg = None
        return self

    def _setup(self, num_experts: int, rpcfg: ReplicationConfig, ep: int,
               bytes_per_expert: int, cost_gate=None):
        assert num_experts % ep == 0, (num_experts, ep)
        self.rpcfg, self.ep = rpcfg, ep
        self.slots_per_rank = num_experts // ep + rpcfg.spare_per_rank
        self.rset = ReplicaSet.identity(num_experts, ep,
                                        slots_per_rank=self.slots_per_rank,
                                        max_replicas=rpcfg.max_replicas)
        self.predictor = EWMAPredictor(num_experts, alpha=rpcfg.ewma_alpha)
        self.bytes_per_expert = bytes_per_expert
        self.cost_gate = cost_gate
        self._pending: Optional[migrate.ReplicaMigrationPlan] = None
        # cumulative accounting
        self.n_migrations = 0
        self.migrated_bytes = 0
        self.migrated_slots = 0
        self.last_replan_iter = -1
        self.cum_slot_load = np.zeros(self.n_slots, np.float64)

    # -- geometry ----------------------------------------------------------
    @property
    def num_experts(self) -> int:
        return self.rset.num_experts

    @property
    def n_slots(self) -> int:
        return self.ep * self.slots_per_rank

    def reset(self) -> None:
        """Back to a fresh identity state (e.g. restoring a checkpoint
        written by a replication-free engine: weights are logical-order
        and there is no replica state to resume)."""
        self._setup(self.num_experts, self.rpcfg, self.ep,
                    self.bytes_per_expert, self.cost_gate)

    def device_tables(self):
        """(rep_pos, n_rep, slot_owner) of the *routable* set — staged
        plans are invisible here until committed."""
        return self.rset.as_arrays()

    # -- engine feeds ------------------------------------------------------
    def observe(self, expert_stats: np.ndarray) -> None:
        """expert_stats [n_blocks, 2, E]: per-MoE-layer (load, vis) counts
        per *logical* expert of one engine iteration."""
        es = np.asarray(expert_stats, np.float64)
        self.predictor.observe(es[:, 0, :], es[:, 1, :])

    def observe_slots(self, slot_stats: np.ndarray) -> None:
        """slot_stats [n_blocks, 2, S]: post-split physical-slot loads —
        cumulative replica-utilization accounting (diagnostics only)."""
        ss = np.asarray(slot_stats, np.float64)
        if ss.shape[-1] == self.n_slots:
            self.cum_slot_load += ss[:, 0, :].sum(0)

    # -- replanning --------------------------------------------------------
    def maybe_replan(self, it: int
                     ) -> Optional[migrate.ReplicaMigrationPlan]:
        """Stage the slab gather to apply at iteration ``it``, or None.

        The returned plan is *pending*: the routable set (and therefore
        ``device_tables``) is unchanged until :meth:`commit`."""
        p = self.rpcfg
        if (self._pending is not None or not p.enabled
                or self.predictor.n_obs < p.warmup_iters
                or p.replan_every <= 0 or it % p.replan_every != 0
                or it == self.last_replan_iter):
            return None
        load, vis = self.predictor.predict()
        if load.sum() <= 0:
            return None
        new = plan_replication(load, self.ep, self.slots_per_rank,
                               max_replicas=p.max_replicas, vis=vis,
                               vis_weight=p.vis_weight)
        # churn guard: require a predicted post-split max-rank-load gain
        old_max = self.rset.rank_loads(load).max()
        new_max = new.rank_loads(load).max()
        if old_max <= 0 or (old_max - new_max) / old_max < p.min_gain:
            return None
        plan = migrate.diff(self.rset, new, self.bytes_per_expert)
        if plan.is_noop:
            return None
        if self.cost_gate is not None and not self.cost_gate.accept(
                self.rset.rank_loads(load), new.rank_loads(load),
                len(plan.crossrank_slots)):
            return None
        self._pending = plan
        self.last_replan_iter = it
        return plan

    def commit(self, plan: migrate.ReplicaMigrationPlan) -> None:
        """Make the staged set routable — call only after the weight
        slabs have been gathered into the new layout."""
        assert self._pending is plan, "commit of a plan that is not staged"
        self.rset = plan.new_set
        self.n_migrations += 1
        self.migrated_bytes += plan.moved_bytes
        self.migrated_slots += plan.n_moved
        self._pending = None

    def abort(self) -> None:
        """Drop a staged plan (weights untouched, old set stays routable)."""
        self._pending = None

    def migration_seconds(self, moved_bytes: int) -> float:
        """Virtual-time cost of copying ``moved_bytes`` over the fabric."""
        return moved_bytes / max(self.rpcfg.migration_bw, 1.0)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"rep_pos": self.rset.rep_pos, "n_rep": self.rset.n_rep,
               "n_ranks": np.int64(self.ep),
               "slots_per_rank": np.int64(self.slots_per_rank),
               "n_migrations": np.int64(self.n_migrations),
               "migrated_bytes": np.int64(self.migrated_bytes),
               "migrated_slots": np.int64(self.migrated_slots),
               "cum_slot_load": self.cum_slot_load}
        for k, v in self.predictor.state_dict().items():
            out[f"pred_{k}"] = v
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        assert int(state["n_ranks"]) == self.ep, \
            (int(state["n_ranks"]), self.ep)
        assert int(state["slots_per_rank"]) == self.slots_per_rank, \
            (int(state["slots_per_rank"]), self.slots_per_rank)
        assert state["rep_pos"].shape[1] == self.rset.max_replicas, \
            (state["rep_pos"].shape, self.rset.max_replicas)
        self.rset = ReplicaSet(np.asarray(state["rep_pos"], np.int32),
                               np.asarray(state["n_rep"], np.int32),
                               self.ep, self.slots_per_rank)
        self.n_migrations = int(state["n_migrations"])
        self.migrated_bytes = int(state["migrated_bytes"])
        self.migrated_slots = int(state["migrated_slots"])
        self.cum_slot_load = np.asarray(state["cum_slot_load"], np.float64)
        self._pending = None
        self.predictor.load_state_dict(
            {k[len("pred_"):]: v for k, v in state.items()
             if k.startswith("pred_")})
