"""Sharded checkpointing: atomic, async, restart- and reshard-safe.

Format: one ``.npz`` per top-level state group (params / opt_state /
extras) holding flattened ``path -> array`` entries, plus a ``meta.json``
with step and tree structure.  Writes go to a temp dir + atomic rename so
a crash mid-save never corrupts the latest checkpoint; ``keep`` old steps
are retained for rollback (the fault-tolerance loop restores the newest
intact one).

``save_async`` snapshots to host memory synchronously (cheap) and writes
in a background thread — the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

Tree = Any
_SEP = "|"
_DT_SUFFIX = "::dt"
# dtypes numpy's savez cannot represent natively -> stored as raw uint views
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        arr = np.asarray(jax.device_get(leaf))
        name = getattr(arr.dtype, "name", str(arr.dtype))
        if name in _EXT_DTYPES:
            _, raw = _EXT_DTYPES[name]
            flat[key] = arr.view(raw)
            flat[key + _DT_SUFFIX] = np.array(name)
        else:
            flat[key] = arr
    return flat


def _decode_flat(flat: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    out = {}
    for key, arr in flat.items():
        if key.endswith(_DT_SUFFIX):
            continue
        meta = flat.get(key + _DT_SUFFIX)
        if meta is not None:
            ext, _ = _EXT_DTYPES[str(meta)]
            arr = arr.view(ext)
        out[key] = arr
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _unflatten_into(template: Tree, flat: Dict[str, np.ndarray]) -> Tree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(_key_str(k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef.treedef if hasattr(
        treedef, "treedef") else treedef, leaves)


def save(ckpt_dir: str, step: int, state: Dict[str, Tree],
         keep: int = 3) -> str:
    """Synchronous atomic save. state: {"params": tree, "opt": tree, ...}."""
    root = pathlib.Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    meta = {"step": step, "groups": sorted(state)}
    for group, tree in state.items():
        flat = _flatten(tree)
        np.savez(tmp / f"{group}.npz", **flat)
    (tmp / "meta.json").write_text(json.dumps(meta))
    final = root / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(root, keep)
    return str(final)


def _gc(root: pathlib.Path, keep: int):
    steps = sorted(p for p in root.iterdir() if p.name.startswith("step_"))
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return None
    steps = sorted(p for p in root.iterdir()
                   if p.name.startswith("step_") and (p / "meta.json").exists())
    if not steps:
        return None
    return int(json.loads((steps[-1] / "meta.json").read_text())["step"])


def has_group(ckpt_dir: str, group: str,
              step: Optional[int] = None) -> bool:
    """Whether a saved step carries the named state group — the cheap
    probe the serving engine uses to detect which manager kind (and, via
    the group's own ``n_tables`` entry, which per-layer/shared layout)
    wrote a checkpoint before committing to restore it."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return False
    return (pathlib.Path(ckpt_dir) / f"step_{step:08d}"
            / f"{group}.npz").exists()


def restore_group(ckpt_dir: str, group: str,
                  step: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Template-free restore of one flat group (``path -> array``).

    For state whose structure is owned by the writer rather than declared
    up front — e.g. the serving engine's expert-placement plan
    (``group="placement"``) or replica set (``group="replication"``) plus
    predictor EWMA, which must survive restarts so a restored engine
    resumes with the same expert→slot layout its saved (physically
    permuted / replica-expanded) weights are in.  Placement groups are
    layout-versioned by their ``n_tables`` entry (1 = shared table,
    ``n_blocks`` = per-layer): the manager's ``load_state_dict`` refuses
    a per-layer↔shared mismatch rather than desynchronizing table and
    weights.  The engine also probes these groups (:func:`has_group`) to
    *refuse* a checkpoint written for a different manager kind.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}" / f"{group}.npz"
    if not path.exists():
        raise FileNotFoundError(f"checkpoint group missing: {path}")
    with np.load(path) as z:
        return _decode_flat({k: z[k] for k in z.files})


def restore(ckpt_dir: str, templates: Dict[str, Tree],
            step: Optional[int] = None, shardings: Optional[Dict] = None
            ) -> Tuple[int, Dict[str, Tree]]:
    """Restore onto `templates` structure; `shardings` (same structure)
    re-distributes onto a (possibly different) mesh — elastic restart."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    out = {}
    for group, tmpl in templates.items():
        with np.load(d / f"{group}.npz") as z:
            flat = _decode_flat({k: z[k] for k in z.files})
        tree = _unflatten_into(tmpl, flat)
        if shardings and group in shardings:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings[group])
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        out[group] = tree
    return step, out


class AsyncCheckpointer:
    """Snapshot-now, write-later. One in-flight save at a time."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir, self.keep = ckpt_dir, keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, state: Dict[str, Tree]):
        self.wait()
        snapshot = {g: _flatten(t) for g, t in state.items()}  # host copy

        def _write():
            try:
                root = pathlib.Path(self.ckpt_dir)
                root.mkdir(parents=True, exist_ok=True)
                tmp = root / f".tmp_step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir()
                for group, flat in snapshot.items():
                    np.savez(tmp / f"{group}.npz", **flat)
                (tmp / "meta.json").write_text(
                    json.dumps({"step": step, "groups": sorted(snapshot)}))
                final = root / f"step_{step:08d}"
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                _gc(root, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
