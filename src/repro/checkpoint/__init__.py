"""checkpoint subpackage."""
