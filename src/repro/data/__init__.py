"""data subpackage."""
