"""Synthetic multimodal data pipeline.

Deterministic, host-sharded, restart-safe: batch content is a pure
function of ``(seed, step, host)`` so a restarted job resumes byte-exact
(no data-offset files needed) and hosts never synchronize — at 1000+ nodes
there is no global-shuffle barrier.

Two generators:

* ``lm_batch`` — learnable LM stream: tokens from a per-position Markov
  chain over a Zipf vocabulary; labels are next-token.  A model that
  learns bigram statistics drives the loss visibly down within ~100 steps,
  which the e2e training test asserts.
* ``multimodal_batch`` — vision/text mixed sequences with the paper's skew
  characteristics: a random-length vision prefix (token ids from a
  disjoint "vision vocab" range, flagged in the modality mask) followed by
  text.  Vision fraction varies strongly per sequence (Fig 2's
  device-level modality skew emerges after sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    vision_frac_mean: float = 0.6      # mean vision-token fraction (paper:
    vision_frac_std: float = 0.3       # vision dominates prefill batches)
    n_hosts: int = 1


def _rng(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def _zipf_tokens(rng, shape, vocab: int) -> np.ndarray:
    # bounded zipf over the vocab (realistic token frequency profile)
    ranks = rng.zipf(1.3, size=shape)
    return ((ranks - 1) % vocab).astype(np.int32)


def lm_batch(cfg: DataConfig, step: int, host: int = 0) -> Dict[str, np.ndarray]:
    """Markov LM batch: tokens [b,S], labels [b,S] (next-token)."""
    rng = _rng(cfg, step, host)
    b = cfg.global_batch // cfg.n_hosts
    v = cfg.vocab_size
    # fixed per-seed bigram transition "model": next = (a*cur + b) % v + noise
    a = 31
    c = 7
    first = _zipf_tokens(rng, (b, 1), v)
    toks = [first[:, 0]]
    noise = rng.random((b, cfg.seq_len)) < 0.15
    rand = _zipf_tokens(rng, (b, cfg.seq_len), v)
    for t in range(1, cfg.seq_len):
        nxt = (a * toks[-1] + c) % v
        toks.append(np.where(noise[:, t], rand[:, t], nxt).astype(np.int32))
    tokens = np.stack(toks, axis=1)
    labels = np.concatenate([tokens[:, 1:], np.full((b, 1), -1, np.int32)],
                            axis=1)
    return {"tokens": tokens, "labels": labels,
            "modality": np.zeros((b, cfg.seq_len), bool)}


def multimodal_batch(cfg: DataConfig, step: int, host: int = 0,
                     d_model: int = 0) -> Dict[str, np.ndarray]:
    """Mixed vision/text batch with strong per-sequence modality skew."""
    base = lm_batch(cfg, step, host)
    rng = _rng(cfg, step + 1_000_003, host)
    b = cfg.global_batch // cfg.n_hosts
    frac = np.clip(rng.normal(cfg.vision_frac_mean, cfg.vision_frac_std,
                              size=(b,)), 0.0, 0.95)
    n_vis = (frac * cfg.seq_len).astype(np.int32)
    pos = np.arange(cfg.seq_len)[None, :]
    modality = pos < n_vis[:, None]
    # vision tokens live in the top half of the vocab (routing separates
    # modalities the way real MMoE gating does)
    vis_tok = (cfg.vocab_size // 2
               + (base["tokens"] % (cfg.vocab_size // 2))).astype(np.int32)
    tokens = np.where(modality, vis_tok, base["tokens"])
    labels = np.where(modality[:, :], -1, base["labels"]).astype(np.int32)
    out = {"tokens": tokens, "labels": labels, "modality": modality}
    if d_model:
        emb_rng = _rng(cfg, step + 2_000_003, host)
        nv = int(n_vis.max()) if b else 0
        out["vision_embeds"] = emb_rng.normal(
            0, 0.02, size=(b, nv, d_model)).astype(np.float32)
    return out


class DataLoader:
    """Stateless iterator facade; `state` is just the step counter."""

    def __init__(self, cfg: DataConfig, host: int = 0,
                 multimodal: bool = False, d_model: int = 0,
                 start_step: int = 0):
        self.cfg, self.host = cfg, host
        self.multimodal, self.d_model = multimodal, d_model
        self.step = start_step

    def __next__(self) -> Dict[str, np.ndarray]:
        fn = multimodal_batch if self.multimodal else lm_batch
        kw = {"d_model": self.d_model} if self.multimodal else {}
        batch = fn(self.cfg, self.step, self.host, **kw)
        self.step += 1
        return batch

    def __iter__(self):
        return self
