"""Per-layer EWMA expert-load predictor.

Fed from the engine's per-iteration routing statistics
(``aux["expert_stats"]``: per-MoE-layer routed-assignment counts per
logical expert, plus the vision sub-counts), it keeps one exponentially
weighted moving average per (layer, expert).  This is the
prediction-driven half of placement systems (MoE-GPS-style): the planner
consumes the *predicted* next-window loads, not the instantaneous ones,
so a one-iteration burst does not trigger a migration — that burst is
ReaLB's job.

Loads are normalized per observation (each layer's counts divided by the
iteration's total) before averaging, so prefill iterations with 10³
tokens and decode iterations with 10¹ tokens contribute comparable
routing *distributions* rather than letting prefill dominate by volume.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


class EWMAPredictor:
    def __init__(self, num_experts: int, alpha: float = 0.25):
        assert 0.0 < alpha <= 1.0, alpha
        self.num_experts = int(num_experts)
        self.alpha = float(alpha)
        self.load: Optional[np.ndarray] = None   # [L, E] EWMA load share
        self.vis: Optional[np.ndarray] = None    # [L, E] EWMA vision share
        self.n_obs = 0

    def observe(self, layer_load: np.ndarray,
                layer_vis: Optional[np.ndarray] = None) -> None:
        """layer_load/[layer_vis]: [L, E] routed counts for one iteration.

        Iterations that routed nothing (pure-padding forwards) are
        ignored instead of decaying the average toward zero.
        """
        load = np.atleast_2d(np.asarray(layer_load, np.float64))
        assert load.shape[-1] == self.num_experts, load.shape
        total = load.sum()
        if total <= 0:
            return
        vis = np.zeros_like(load) if layer_vis is None \
            else np.atleast_2d(np.asarray(layer_vis, np.float64))
        norm = load / total
        vnorm = vis / total
        if self.load is None or self.load.shape != load.shape:
            self.load, self.vis = norm, vnorm
        else:
            a = self.alpha
            self.load = a * norm + (1.0 - a) * self.load
            self.vis = a * vnorm + (1.0 - a) * self.vis
        self.n_obs += 1

    def predict(self) -> Tuple[np.ndarray, np.ndarray]:
        """Aggregated (load, vis) share per logical expert, [E] each.

        Layers are summed: the placement table is shared by every MoE
        layer, so the planner balances the stack-total per-expert load.
        """
        if self.load is None:
            z = np.zeros(self.num_experts)
            return z, z.copy()
        return self.load.sum(0), self.vis.sum(0)

    def predict_per_layer(self) -> Optional[np.ndarray]:
        """[L, E] per-layer EWMA load shares (diagnostics)."""
        return None if self.load is None else self.load.copy()

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"alpha": np.float64(self.alpha),
               "n_obs": np.int64(self.n_obs),
               "num_experts": np.int64(self.num_experts)}
        if self.load is not None:
            out["load"] = self.load
            out["vis"] = self.vis
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        assert int(state["num_experts"]) == self.num_experts, \
            (int(state["num_experts"]), self.num_experts)
        self.alpha = float(state["alpha"])
        self.n_obs = int(state["n_obs"])
        self.load = np.asarray(state["load"], np.float64) \
            if "load" in state else None
        self.vis = np.asarray(state["vis"], np.float64) \
            if "vis" in state else None
