"""Per-layer EWMA expert-load predictor with a separate decode window.

Fed from the engine's per-iteration routing statistics
(``aux["expert_stats"]``: per-MoE-layer routed-assignment counts per
logical expert, plus the vision sub-counts), it keeps one exponentially
weighted moving average per (layer, expert).  This is the
prediction-driven half of placement systems (MoE-GPS-style): the planner
consumes the *predicted* next-window loads, not the instantaneous ones,
so a one-iteration burst does not trigger a migration — that burst is
ReaLB's job.

Loads are normalized per observation (each layer's counts divided by the
iteration's total) before averaging, so prefill iterations with 10³
tokens and decode iterations with 10¹ tokens contribute comparable
routing *distributions* rather than letting prefill dominate by volume.

Decode window
-------------
Normalization equalizes *per-observation* weight, but a serving stream
is still prefill-dominated by count, so decode-regime routing drifts are
drowned in the shared EWMA.  With ``decode_halflife > 0`` decode
observations feed a *separate* EWMA whose smoothing is derived from the
half-life (``alpha = 1 - 0.5**(1/halflife)`` in decode iterations);
``predict(regime="decode")`` then exposes the decode-only distribution
for decode-cadence replanning (ROADMAP "Decode-regime placement").

Per-layer prediction
--------------------
The state is already per-(layer, expert); ``predict()`` sums the layer
axis for a shared table, while ``predict_layers()`` keeps it — the
observation stream of per-layer placement/replication planning
(MoE-GPS: prediction granularity decides duplication gains).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


class EWMAPredictor:
    def __init__(self, num_experts: int, alpha: float = 0.25,
                 decode_halflife: float = 0.0):
        assert 0.0 < alpha <= 1.0, alpha
        self.num_experts = int(num_experts)
        self.alpha = float(alpha)
        self.decode_halflife = float(decode_halflife)
        self.load: Optional[np.ndarray] = None   # [L, E] EWMA load share
        self.vis: Optional[np.ndarray] = None    # [L, E] EWMA vision share
        self.load_dec: Optional[np.ndarray] = None  # [L, E] decode window
        self.vis_dec: Optional[np.ndarray] = None
        self.n_obs = 0
        self.n_obs_decode = 0

    @property
    def decode_alpha(self) -> float:
        """EWMA smoothing of the decode window, from its half-life."""
        if self.decode_halflife <= 0:
            return 0.0
        return 1.0 - 0.5 ** (1.0 / self.decode_halflife)

    def observe(self, layer_load: np.ndarray,
                layer_vis: Optional[np.ndarray] = None,
                decode: bool = False) -> None:
        """layer_load/[layer_vis]: [L, E] routed counts for one iteration.

        ``decode`` marks a decode-regime iteration: with a decode window
        configured it updates that window instead of the main one.
        Iterations that routed nothing (pure-padding forwards) are
        ignored instead of decaying the average toward zero.
        """
        load = np.atleast_2d(np.asarray(layer_load, np.float64))
        assert load.shape[-1] == self.num_experts, load.shape
        total = load.sum()
        if total <= 0:
            return
        vis = np.zeros_like(load) if layer_vis is None \
            else np.atleast_2d(np.asarray(layer_vis, np.float64))
        norm = load / total
        vnorm = vis / total
        if decode and self.decode_alpha > 0.0:
            a = self.decode_alpha
            if self.load_dec is None or self.load_dec.shape != load.shape:
                self.load_dec, self.vis_dec = norm, vnorm
            else:
                self.load_dec = a * norm + (1.0 - a) * self.load_dec
                self.vis_dec = a * vnorm + (1.0 - a) * self.vis_dec
        else:
            if self.load is None or self.load.shape != load.shape:
                self.load, self.vis = norm, vnorm
            else:
                a = self.alpha
                self.load = a * norm + (1.0 - a) * self.load
                self.vis = a * vnorm + (1.0 - a) * self.vis
        if decode:
            # counted even without a decode window, so a decode replan
            # cadence still fires (planning from the shared window via
            # predict's fallback) instead of silently never triggering
            self.n_obs_decode += 1
        self.n_obs += 1

    def _window(self, regime: str
                ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray]]:
        if regime == "decode" and self.load_dec is not None:
            return self.load_dec, self.vis_dec
        return self.load, self.vis

    def predict(self, regime: str = "mixed"
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Aggregated (load, vis) share per logical expert, [E] each.

        Layers are summed: a shared placement table serves every MoE
        layer, so its planner balances the stack-total per-expert load.
        ``regime="decode"`` reads the decode window when one exists
        (falling back to the main window otherwise).
        """
        load, vis = self._window(regime)
        if load is None:
            z = np.zeros(self.num_experts)
            return z, z.copy()
        return load.sum(0), vis.sum(0)

    def predict_layers(self, regime: str = "mixed"
                       ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """[L, E] per-layer (load, vis) EWMA shares — the per-layer
        planners' observation stream.  None before the first observation.
        """
        load, vis = self._window(regime)
        if load is None:
            return None
        return load.copy(), vis.copy()

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"alpha": np.float64(self.alpha),
               "n_obs": np.int64(self.n_obs),
               "n_obs_decode": np.int64(self.n_obs_decode),
               "num_experts": np.int64(self.num_experts)}
        if self.load is not None:
            out["load"] = self.load
            out["vis"] = self.vis
        if self.load_dec is not None:
            out["load_dec"] = self.load_dec
            out["vis_dec"] = self.vis_dec
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        assert int(state["num_experts"]) == self.num_experts, \
            (int(state["num_experts"]), self.num_experts)
        self.alpha = float(state["alpha"])
        self.n_obs = int(state["n_obs"])
        self.n_obs_decode = int(state.get("n_obs_decode", 0))
        self.load = np.asarray(state["load"], np.float64) \
            if "load" in state else None
        self.vis = np.asarray(state["vis"], np.float64) \
            if "vis" in state else None
        self.load_dec = np.asarray(state["load_dec"], np.float64) \
            if "load_dec" in state else None
        self.vis_dec = np.asarray(state["vis_dec"], np.float64) \
            if "vis_dec" in state else None
        # decode_halflife is CONFIGURATION, not state — a restore must
        # neither disable a configured decode window nor resurrect one
        # the live run did not ask for.  With the window off, restored
        # decode-window arrays would go stale forever (nothing updates
        # them, regime="decode" would keep reading them): drop them so
        # decode traffic falls back into the main planning window.
        if self.decode_alpha <= 0.0:
            self.load_dec = self.vis_dec = None
            self.n_obs_decode = 0
