"""PlacementManager: the serving-side control loop of the subsystem.

Owns the current :class:`PlacementTable`, the EWMA predictor and the
replan cadence.  The engine feeds it per-iteration expert stats
(`observe`), asks it every iteration whether a replan is due
(`maybe_replan` → a :class:`MigrationPlan` or None) and applies the
returned weight permutation itself (the manager never touches device
arrays).  Cumulative migration accounting lives here so telemetry and
benchmarks can report the placement-vs-ReaLB overhead trade-off
directly.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, PlacementConfig
from repro.placement import migrate
from repro.placement.planner import plan_placement
from repro.placement.predictor import EWMAPredictor
from repro.placement.table import PlacementTable


class PlacementManager:
    ckpt_group = "placement"       # engine checkpoint group name

    def __init__(self, cfg: ModelConfig, pcfg: PlacementConfig, ep: int,
                 cost_gate=None):
        assert cfg.moe is not None, "placement requires an MoE model"
        n_moe = sum(1 for f in cfg.ffn_kinds() if f == "moe")
        self._setup(cfg.moe.num_experts, pcfg, ep,
                    migrate.expert_bytes(cfg, max(n_moe, 1)), cost_gate)
        self.cfg = cfg

    @classmethod
    def from_geometry(cls, num_experts: int, pcfg: PlacementConfig,
                      ep: int, bytes_per_expert: int = 0,
                      cost_gate=None) -> "PlacementManager":
        """Model-config-free construction (cost-model simulators)."""
        self = cls.__new__(cls)
        self._setup(num_experts, pcfg, ep, bytes_per_expert, cost_gate)
        self.cfg = None
        return self

    def _setup(self, num_experts: int, pcfg: PlacementConfig, ep: int,
               bytes_per_expert: int, cost_gate=None):
        assert num_experts % ep == 0, (num_experts, ep)
        self.pcfg, self.ep = pcfg, ep
        self.table = PlacementTable.identity(num_experts, ep)
        self.predictor = EWMAPredictor(num_experts, alpha=pcfg.ewma_alpha)
        self.bytes_per_expert = bytes_per_expert
        # optional amortized-gain guard: an object with
        # accept(old_rank_loads, new_rank_loads, n_moved) -> bool, built
        # from the analytic latency model (benchmarks.costmodel.
        # ReplanCostGate) — a replan then fires only when the predicted
        # layer-time savings over its horizon exceed the migration cost
        self.cost_gate = cost_gate
        # cumulative accounting
        self.n_migrations = 0
        self.migrated_bytes = 0
        self.migrated_experts = 0
        self.last_replan_iter = -1

    def reset(self) -> None:
        """Back to a fresh identity state (e.g. restoring a checkpoint
        written by a placement-free engine: weights are identity-ordered
        and there is no plan/predictor state to resume)."""
        self._setup(self.table.num_experts, self.pcfg, self.ep,
                    self.bytes_per_expert, self.cost_gate)

    def device_tables(self):
        """(e2r, local_slot) for the traced MoE layer."""
        return self.table.as_tuple()

    # -- engine feeds ------------------------------------------------------
    def observe(self, expert_stats: np.ndarray) -> None:
        """expert_stats [n_blocks, 2, E]: per-MoE-layer (load, vis) counts
        of one engine iteration (the transformer's ``aux["expert_stats"]``).
        """
        es = np.asarray(expert_stats, np.float64)
        self.predictor.observe(es[:, 0, :], es[:, 1, :])

    def maybe_replan(self, it: int) -> Optional[migrate.MigrationPlan]:
        """Return the weight permutation to apply at iteration ``it``, or
        None.  Updates the current table and the migration accounting when
        a plan is returned."""
        p = self.pcfg
        if (not p.enabled or p.planner == "identity"
                or self.predictor.n_obs < p.warmup_iters
                or p.replan_every <= 0 or it % p.replan_every != 0
                or it == self.last_replan_iter):
            return None
        load, vis = self.predictor.predict()
        if load.sum() <= 0:
            return None
        new = plan_placement(p.planner, load, self.ep, vis=vis, cfg=p)
        # skip churn: require a predicted max-rank-load improvement
        old_max = self.table.rank_loads(load).max()
        new_max = new.rank_loads(load).max()
        if old_max <= 0 or (old_max - new_max) / old_max < p.min_gain:
            return None
        plan = migrate.diff(self.table, new, self.bytes_per_expert)
        if plan.is_noop:
            return None
        if self.cost_gate is not None and not self.cost_gate.accept(
                self.table.rank_loads(load), new.rank_loads(load),
                plan.n_moved):
            return None
        self.table = new
        self.n_migrations += 1
        self.migrated_bytes += plan.moved_bytes
        self.migrated_experts += plan.n_moved
        self.last_replan_iter = it
        return plan

    def migration_seconds(self, moved_bytes: int) -> float:
        """Virtual-time cost of moving ``moved_bytes`` over the EP fabric."""
        return moved_bytes / max(self.pcfg.migration_bw, 1.0)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"e2r": self.table.e2r, "local_slot": self.table.local_slot,
               "n_ranks": np.int64(self.table.n_ranks),
               "n_migrations": np.int64(self.n_migrations),
               "migrated_bytes": np.int64(self.migrated_bytes),
               "migrated_experts": np.int64(self.migrated_experts)}
        for k, v in self.predictor.state_dict().items():
            out[f"pred_{k}"] = v
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        assert int(state["n_ranks"]) == self.ep, \
            (int(state["n_ranks"]), self.ep)
        self.table = PlacementTable(np.asarray(state["e2r"], np.int32),
                                    np.asarray(state["local_slot"],
                                               np.int32), self.ep)
        self.n_migrations = int(state["n_migrations"])
        self.migrated_bytes = int(state["migrated_bytes"])
        self.migrated_experts = int(state["migrated_experts"])
        self.predictor.load_state_dict(
            {k[len("pred_"):]: v for k, v in state.items()
             if k.startswith("pred_")})
