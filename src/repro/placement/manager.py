"""PlacementManager: the serving-side control loop of the subsystem.

Owns the current placement tables, the EWMA predictor and the replan
cadence.  The engine feeds it per-iteration expert stats (`observe`),
asks it every iteration whether a replan is due (`maybe_replan` → a
*staged* migration plan or None) and applies the returned weight
permutation itself (the manager never touches device arrays) before
committing — the whole plan at once (`commit`), or layer by layer as
each slab lands under async overlapped migration (`commit_layers`, see
``repro.serving.async_migrate``).  Until commit the old tables stay
routable, and no further replan can fire.  Cumulative migration
accounting lives here so telemetry and benchmarks can report the
placement-vs-ReaLB overhead trade-off directly; a measured-bandwidth
EWMA (``bandwidth``) prices the transfers once the engine has timed
real applies.

Per-layer tables (``PlacementConfig.per_layer``): one table per scanned
MoE block instead of one shared table.  The predictor's per-layer state
stops being summed away — each layer is planned independently from its
own EWMA row (MoE-GPS: prediction granularity decides the gains) — and
migration becomes a *layer-diff*: only layers whose plan changed move
weight slabs (HarMoEny-style layer-wise rebalancing), so migration
traffic scales with the number of changed layers rather than
``n_layers×``.  ``device_tables`` then returns stacked ``[L, E]`` arrays
that the transformer threads through its layer scan.  With ``n_tables ==
1`` everything degenerates to the shared-table behavior bitwise.

Decode-regime replanning: with ``decode_halflife`` the predictor keeps a
separate decode window, and ``decode_replan_every`` arms an additional
cadence counted in *decode* iterations that plans from that window — so
decode-regime drift is not drowned by prefill-dominated statistics.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union

import numpy as np

from repro.configs.base import ModelConfig, PlacementConfig
from repro.obs.trace import NULL_TRACER
from repro.placement import migrate
from repro.placement.planner import plan_placement
from repro.placement.predictor import EWMAPredictor
from repro.placement.table import PlacementTable

Plan = Union[migrate.MigrationPlan, migrate.LayerMigrationPlan]


class ReplanDiscipline:
    """Replan cadence + decode-window + cost-gate + staged-commit
    discipline shared by :class:`PlacementManager` and
    :class:`~repro.replication.manager.ReplicaManager` — their configs
    carry the same ``enabled`` / ``replan_every`` / ``warmup_iters`` /
    ``decode_replan_every`` fields.  Hosts the manager-agnostic half of
    ``maybe_replan`` so the two control loops cannot drift apart.

    Staged commit: every plan returned by ``maybe_replan`` is *pending*
    — the routable tables (``device_tables``) are unchanged until the
    engine has landed the weight slabs and calls :meth:`commit` (whole
    plan, the synchronous path) or :meth:`commit_layers` (one chunk of
    layers at a time, the async path — each layer's table flips
    independently as its slab lands).  While a plan is in flight
    ``maybe_replan`` is a guarded no-op: a second replan overwriting the
    staged plan would desynchronize the commit protocol (the engine
    would gather slabs for one plan and flip tables for another).
    :meth:`abort` drops the pending plan — the old tables stay routable
    and consistent with the untouched weights — which is also the
    supersede path: abort, then let the next cadence point re-plan from
    fresher statistics."""

    # filled in by the concrete manager's _setup
    predictor: EWMAPredictor
    cost_gate = None
    last_replan_iter = -1
    _decode_since_replan = 0
    _pending = None                 # staged plan awaiting its slabs
    _pending_remaining = None       # chunk (layer) indices not yet landed
    _event_replan = False           # a requested event-triggered replan
    _event_now = False              # the current attempt IS event-triggered
    must_layers = frozenset()       # layers that must replan regardless of
    #                                 gain (elastic recovery: lost experts)
    # observability (opt-in, both default to inert singletons/None):
    # every maybe_replan call ends in exactly one audit verdict; planning
    # attempts past the cadence gate get a tracer span
    audit = None                    # repro.obs.audit.ReplanAudit
    tracer = NULL_TRACER            # repro.obs.trace.Tracer
    _kind = "manager"               # audit/span label: placement|replication
    _skip = None                    # why the last _cadence said no
    _verdict = "no-cadence"         # the last maybe_replan verdict
    _verdict_fields: dict = {}

    def _discipline_cfg(self):
        """The PlacementConfig / ReplicationConfig of the manager."""
        raise NotImplementedError

    def _replan_blocked(self) -> bool:
        """Manager-specific extra guard (e.g. the identity planner)."""
        return False

    def request_replan(self) -> None:
        """Arm an event-triggered replan (elastic rank loss/rejoin): the
        next ``maybe_replan`` fires immediately — bypassing the cadence,
        the ``min_gain`` churn guard and the cost gate — as soon as no
        plan is in flight and the predictor has any observation.  The
        request is sticky until consumed."""
        self._event_replan = True

    def _cadence(self, it: int) -> Optional[str]:
        """The prediction regime a replan at ``it`` should plan from, or
        None when no cadence is due (``_skip`` then names the reason for
        the audit log)."""
        p = self._discipline_cfg()
        self._event_now = False
        self._skip = None
        if not p.enabled:
            self._skip = "disabled"
            return None
        if self._pending is not None:
            self._skip = "in-flight"
            return None
        if self._replan_blocked():
            self._skip = "blocked"
            return None
        if self._event_replan and self.predictor.n_obs > 0:
            self._event_replan = False
            self._event_now = True
            return "mixed"
        if self.predictor.n_obs < p.warmup_iters:
            self._skip = "warmup"
            return None
        if it == self.last_replan_iter:
            self._skip = "already-replanned"
            return None
        if p.replan_every > 0 and it % p.replan_every == 0:
            return "mixed"
        if (p.decode_replan_every > 0
                and self._decode_since_replan >= p.decode_replan_every
                and self.predictor.n_obs_decode > 0):
            # the decode cadence point fires exactly once: reset the
            # counter even when the attempt is later rejected (min_gain /
            # noop / cost gate), so a rejected plan does not re-run the
            # full planner on every subsequent iteration
            self._decode_since_replan = 0
            return "decode"
        self._skip = "no-cadence"
        return None

    def _gate_accept(self, old_loads: np.ndarray, new_loads: np.ndarray,
                     n_moved: int) -> bool:
        """old/new_loads: [ep] for shared, [L, ep] stacks for per-layer."""
        if self.cost_gate is None:
            return True
        if old_loads.ndim == 2:
            if hasattr(self.cost_gate, "accept_layers"):
                return self.cost_gate.accept_layers(old_loads, new_loads,
                                                    n_moved)
            old_loads, new_loads = old_loads.sum(0), new_loads.sum(0)
        return self.cost_gate.accept(old_loads, new_loads, n_moved)

    # -- decision audit / tracing -----------------------------------------
    def _decide(self, verdict: str, **fields):
        """Record the verdict of the current planning attempt; returns
        None so rejection paths read ``return self._decide(...)``."""
        self._verdict = verdict
        self._verdict_fields = fields
        return None

    def plan_bytes(self, plan) -> int:
        """Total transfer bytes of a staged plan (sum of its chunks)."""
        return sum(self.layer_bytes(plan, l) for l in self.plan_layers(plan))

    def maybe_replan(self, it: int):
        """Stage the migration plan to apply at iteration ``it``, or None.

        The returned plan is *pending*: the routable table(s) and the
        migration accounting are unchanged until :meth:`commit` /
        :meth:`commit_layers` — which the engine calls only after the
        slab gather landed the new weights.  Every call ends in exactly
        one audit verdict (cadence rejections included) when an
        :class:`~repro.obs.audit.ReplanAudit` is attached, and planning
        attempts past the cadence gate get a ``replan.<kind>`` span."""
        regime = self._cadence(it)
        if regime is None:
            if self.audit is not None:
                self.audit.record(it=it, manager=self._kind,
                                  verdict=self._skip or "no-cadence")
            return None
        forced = self._event_now
        self._verdict, self._verdict_fields = "noop", {}
        trc = self.tracer
        if trc.enabled:
            with trc.span(f"replan.{self._kind}", cat="replan") as sp:
                plan = (self._replan_layers(it, regime) if self.per_layer
                        else self._replan_shared(it, regime))
                sp.set(it=it, regime=regime, verdict=self._verdict)
        else:
            plan = (self._replan_layers(it, regime) if self.per_layer
                    else self._replan_shared(it, regime))
        if self.audit is not None:
            self.audit.record(it=it, manager=self._kind,
                              verdict=self._verdict, regime=regime,
                              must=True if forced else None,
                              **self._verdict_fields)
        return plan

    def _replan_shared(self, it: int, regime: str):
        """The shared-table (``n_tables == 1``) planning attempt."""
        raise NotImplementedError

    def predicted_rank_loads(self, regime: str = "mixed"):
        """``[n_tables, ep]`` predicted per-rank loads under the current
        routable tables — the quantity the prediction-accuracy metric
        compares against realized loads per replan window.  None before
        any observation."""
        states = self._layer_states()
        pred = self.predictor.predict_layers(regime)
        if pred is not None and pred[0].shape[0] == len(states) \
                and pred[0].sum() > 0:
            loads = pred[0]
            return np.stack([s.rank_loads(loads[l])
                             for l, s in enumerate(states)])
        load, _ = self.predictor.predict(regime)
        if load.sum() <= 0:
            return None
        # shared manager under a multi-block model: one summed row
        return np.stack([s.rank_loads(load) for s in states])

    # -- staged commit (chunk = one layer of a layer-diff plan) -----------
    @property
    def in_flight(self):
        """The staged plan whose slabs have not all landed, or None."""
        return self._pending

    def plan_layers(self, plan) -> List[int]:
        """The chunk indices of a plan: changed layers of a layer-diff,
        ``[0]`` (one whole-plan chunk) for a shared plan."""
        changed = getattr(plan, "changed_layers", None)
        return [0] if changed is None else [int(l) for l in changed]

    def layer_bytes(self, plan, layer: int) -> int:
        """Transfer bytes of one chunk (manager-specific pricing)."""
        raise NotImplementedError

    def _stage(self, plan):
        assert self._pending is None, \
            "staging a plan over an in-flight one (commit or abort first)"
        self._pending = plan
        self._pending_remaining = set(self.plan_layers(plan))
        return plan

    def _commit_one_layer(self, plan, layer: int) -> None:
        """Flip one landed layer's routable table + book its bytes."""
        raise NotImplementedError

    def commit_layers(self, plan, layers) -> bool:
        """Make ``layers``' staged tables routable — call only after
        exactly those layers' weight slabs have been gathered into the
        new layout (``migrate.apply_layers_to_params``).  Returns True
        once the whole plan has landed (the migration is then counted
        and a new replan may fire)."""
        assert self._pending is plan, "commit of a plan that is not staged"
        for layer in layers:
            layer = int(layer)
            assert layer in self._pending_remaining, \
                (layer, sorted(self._pending_remaining))
            self._pending_remaining.discard(layer)
            self._commit_one_layer(plan, layer)
        if self._pending_remaining:
            return False
        self.n_migrations += 1
        self._decode_since_replan = 0
        self._pending = None
        self._pending_remaining = None
        return True

    def commit(self, plan) -> None:
        """Make the whole staged plan routable (the synchronous path —
        every slab was gathered in one ``apply_to_params``)."""
        assert self._pending is plan, "commit of a plan that is not staged"
        self.commit_layers(plan, sorted(self._pending_remaining))

    def abort(self) -> None:
        """Drop the staged plan (weights untouched for its not-yet-landed
        layers; already-committed layers stay routable — their slabs did
        land).  The old tables remain consistent with the weights."""
        self._pending = None
        self._pending_remaining = None

    # -- per-layer replan loop (hooks below are manager-specific) ---------
    def _layer_states(self) -> list:
        """Current per-layer tables / replica sets."""
        raise NotImplementedError

    def _plan_one_layer(self, load: np.ndarray, vis: np.ndarray):
        """One layer's planner call on its own [E] load row."""
        raise NotImplementedError

    def _diff_layer_states(self, old_states: list, new_states: list):
        """The layer-diff plan between two per-layer state stacks."""
        raise NotImplementedError

    def _layer_gate_moved(self, plan) -> int:
        """The move count the cost gate prices (cross-rank for replicas)."""
        return plan.n_moved

    def _accept_layer_plan(self, plan, new_states: list):
        """Adopt (placement) or stage (replication) the accepted plan."""
        raise NotImplementedError

    def _replan_layers(self, it: int, regime: str):
        """Plan each layer independently from its own EWMA row; layers
        below the churn guard keep their current state, so the diff (and
        the migration traffic) covers changed layers only.

        Churn budget (``max_changed_layers``): when set, at most that
        many layers change per replan, filled in predicted-gain order —
        an event-triggered recovery replan then cannot queue an unbounded
        migration backlog.  ``must_layers`` (elastic recovery: layers
        with unroutable experts) are exempt from both the budget and the
        ``min_gain`` guard; an event-triggered replan (``request_replan``)
        also bypasses ``min_gain`` and the cost gate for every layer."""
        pred = self.predictor.predict_layers(regime)
        if pred is None:
            return self._decide("zero-load")
        loads, viss = pred
        states = self._layer_states()
        if loads.sum() <= 0 or loads.shape[0] != len(states):
            return self._decide("zero-load")
        p = self._discipline_cfg()
        forced = self._event_now
        must = {int(l) for l in self.must_layers}
        candidates = []                        # (gain, layer, new_state)
        for l, state in enumerate(states):
            load_l, vis_l = loads[l], viss[l]
            if load_l.sum() <= 0:
                if l not in must:
                    continue
                # a recovery layer must replan even without load signal
                load_l = np.ones_like(load_l)
            new = self._plan_one_layer(load_l, vis_l)
            old_max = state.rank_loads(load_l).max()
            new_max = new.rank_loads(load_l).max()
            gain = (old_max - new_max) / old_max if old_max > 0 else 0.0
            if l in must:
                candidates.append((np.inf, l, new))
                continue
            # per-layer churn guard: strictly positive gain required
            # (a zero-gain re-permutation of one layer is pure migration
            # churn the layer-diff would otherwise ship)
            if not forced and (old_max <= 0 or gain <= p.min_gain):
                continue
            if forced and old_max <= 0:
                continue
            candidates.append((gain, l, new))
        budget = int(getattr(p, "max_changed_layers", 0))
        if budget > 0 and len(candidates) > budget:
            mandatory = [c for c in candidates if not np.isfinite(c[0])]
            optional = sorted((c for c in candidates if np.isfinite(c[0])),
                              key=lambda c: -c[0])
            candidates = mandatory \
                + optional[:max(budget - len(mandatory), 0)]
        new_states = list(states)
        for _, l, new in candidates:
            new_states[l] = new
        plan = self._diff_layer_states(states, new_states)
        if plan.is_noop:
            return self._decide("noop", changed_layers=0)
        old_rl = np.stack([s.rank_loads(loads[l])
                           for l, s in enumerate(states)])
        new_rl = np.stack([s.rank_loads(loads[l])
                           for l, s in enumerate(new_states)])
        # audit pricing: aggregate peak-load gain over the layer stack,
        # the bytes the diff would ship and their bandwidth-EWMA seconds
        old_peak = float(old_rl.max(axis=1).sum())
        new_peak = float(new_rl.max(axis=1).sum())
        nbytes = self.plan_bytes(plan)
        price = dict(
            pred_gain=(old_peak - new_peak) / old_peak
            if old_peak > 0 else 0.0,
            migration_bytes=int(nbytes),
            migration_s=float(self.migration_seconds(nbytes)),
            n_moved=int(self._layer_gate_moved(plan)),
            changed_layers=len(self.plan_layers(plan)),
            n_must_layers=len(must) if must else None)
        if not forced and not self._gate_accept(
                old_rl, new_rl, self._layer_gate_moved(plan)):
            return self._decide("cost-gate", **price)
        self.last_replan_iter = it
        self._decide("staged", **price)
        return self._accept_layer_plan(plan, new_states)


class PlacementManager(ReplanDiscipline):
    ckpt_group = "placement"       # engine checkpoint group name
    _kind = "placement"            # audit / span label

    def __init__(self, cfg: ModelConfig, pcfg: PlacementConfig, ep: int,
                 cost_gate=None):
        assert cfg.moe is not None, "placement requires an MoE model"
        n_blocks, n_moe_per_block = cfg.moe_block_structure()
        n_moe = n_blocks * n_moe_per_block
        if pcfg.per_layer:
            # one table per scanned block; a moved expert drags only that
            # block's slice of its weights
            n_tables = n_blocks
            bpe = migrate.expert_bytes(cfg, max(n_moe_per_block, 1))
        else:
            n_tables = 1
            bpe = migrate.expert_bytes(cfg, max(n_moe, 1))
        self._setup(cfg.moe.num_experts, pcfg, ep, bpe, cost_gate,
                    n_tables=n_tables)
        self.cfg = cfg

    @classmethod
    def from_geometry(cls, num_experts: int, pcfg: PlacementConfig,
                      ep: int, bytes_per_expert: int = 0,
                      cost_gate=None, n_layers: int = 1
                      ) -> "PlacementManager":
        """Model-config-free construction (cost-model simulators).

        ``bytes_per_expert`` is per-table granularity: the whole stack for
        a shared manager, one scanned block for a per-layer one."""
        self = cls.__new__(cls)
        self._setup(num_experts, pcfg, ep, bytes_per_expert, cost_gate,
                    n_tables=n_layers if pcfg.per_layer else 1)
        self.cfg = None
        return self

    def _setup(self, num_experts: int, pcfg: PlacementConfig, ep: int,
               bytes_per_expert: int, cost_gate=None, n_tables: int = 1):
        assert num_experts % ep == 0, (num_experts, ep)
        assert n_tables >= 1, n_tables
        self.pcfg, self.ep = pcfg, ep
        self.n_tables = n_tables
        self.tables: List[PlacementTable] = [
            PlacementTable.identity(num_experts, ep)
            for _ in range(n_tables)]
        self.predictor = EWMAPredictor(num_experts, alpha=pcfg.ewma_alpha,
                                       decode_halflife=pcfg.decode_halflife)
        self.bytes_per_expert = bytes_per_expert
        # optional amortized-gain guard: an object with
        # accept(old_rank_loads, new_rank_loads, n_moved) -> bool (and
        # accept_layers([L, ep] stacks) for per-layer managers), built
        # from the analytic latency model (benchmarks.costmodel.
        # ReplanCostGate) — a replan then fires only when the predicted
        # layer-time savings over its horizon exceed the migration cost
        self.cost_gate = cost_gate
        # measured-bandwidth EWMA pricing this manager's slab transfers;
        # the engine feeds it timed applies, migration_seconds and the
        # cost gate read it (single-sourced with the analytic model)
        self.bandwidth = migrate.MigrationBandwidth(pcfg.migration_bw)
        if cost_gate is not None \
                and getattr(cost_gate, "bandwidth", False) is None:
            cost_gate.bandwidth = self.bandwidth
        # cumulative accounting
        self.n_migrations = 0
        self.migrated_bytes = 0
        self.migrated_experts = 0
        self.migrated_bytes_per_layer = np.zeros(n_tables, np.int64)
        self.last_replan_iter = -1
        self._decode_since_replan = 0
        self._pending = None
        self._pending_remaining = None

    @property
    def per_layer(self) -> bool:
        return self.n_tables > 1

    @property
    def table(self) -> PlacementTable:
        """The shared table (first table of a per-layer manager)."""
        return self.tables[0]

    @table.setter
    def table(self, t: PlacementTable) -> None:
        self.tables[0] = t

    @property
    def num_experts(self) -> int:
        return self.tables[0].num_experts

    def reset(self) -> None:
        """Back to a fresh identity state (e.g. restoring a checkpoint
        written by a placement-free engine: weights are identity-ordered
        and there is no plan/predictor state to resume)."""
        self._setup(self.num_experts, self.pcfg, self.ep,
                    self.bytes_per_expert, self.cost_gate,
                    n_tables=self.n_tables)

    def device_tables(self):
        """(e2r, local_slot) for the traced MoE layer — ``[E]`` arrays for
        a shared table, stacked ``[L, E]`` for per-layer tables (threaded
        through the transformer's layer scan)."""
        if not self.per_layer:
            return self.tables[0].as_tuple()
        return (np.stack([t.e2r for t in self.tables]),
                np.stack([t.local_slot for t in self.tables]))

    # -- engine feeds ------------------------------------------------------
    def observe(self, expert_stats: np.ndarray,
                decode: bool = False) -> None:
        """expert_stats [n_blocks, 2, E]: per-MoE-layer (load, vis) counts
        of one engine iteration (the transformer's ``aux["expert_stats"]``).
        ``decode`` routes the observation into the decode window when one
        is configured."""
        es = np.asarray(expert_stats, np.float64)
        self.predictor.observe(es[:, 0, :], es[:, 1, :], decode=decode)
        if decode:
            self._decode_since_replan += 1

    # -- replanning --------------------------------------------------------
    def _discipline_cfg(self) -> PlacementConfig:
        return self.pcfg

    def _replan_blocked(self) -> bool:
        return self.pcfg.planner == "identity"

    def layer_bytes(self, plan: Plan, layer: int) -> int:
        if isinstance(plan, migrate.LayerMigrationPlan):
            return int(plan.moved_per_layer[layer]) * self.bytes_per_expert
        return int(plan.moved_bytes)

    def _commit_one_layer(self, plan: Plan, layer: int) -> None:
        b = self.layer_bytes(plan, layer)
        if isinstance(plan, migrate.LayerMigrationPlan):
            self.tables[layer] = plan.new_tables[layer]
            self.migrated_experts += int(plan.moved_per_layer[layer])
        else:
            self.tables[0] = plan.new_table
            self.migrated_experts += plan.n_moved
        self.migrated_bytes += b
        self.migrated_bytes_per_layer[layer] += b

    def _replan_shared(self, it: int, regime: str) -> Optional[Plan]:
        """The shared-table planning attempt (cadence already hit —
        the discipline's ``maybe_replan`` dispatched here)."""
        load, vis = self.predictor.predict(regime)
        if load.sum() <= 0:
            return self._decide("zero-load")
        p = self.pcfg
        forced = self._event_now
        new = plan_placement(p.planner, load, self.ep, vis=vis, cfg=p)
        # skip churn: require a predicted max-rank-load improvement
        # (event-triggered replans bypass the guard and the cost gate)
        old_max = self.table.rank_loads(load).max()
        new_max = new.rank_loads(load).max()
        gain = (old_max - new_max) / old_max if old_max > 0 else 0.0
        if not forced and (old_max <= 0 or gain < p.min_gain):
            return self._decide("min-gain", pred_gain=float(gain))
        plan = migrate.diff(self.table, new, self.bytes_per_expert)
        if plan.is_noop:
            return self._decide("noop", pred_gain=float(gain),
                                changed_layers=0)
        price = dict(
            pred_gain=float(gain),
            migration_bytes=int(plan.moved_bytes),
            migration_s=float(self.migration_seconds(plan.moved_bytes)),
            n_moved=int(plan.n_moved))
        if not forced and not self._gate_accept(
                self.table.rank_loads(load), new.rank_loads(load),
                plan.n_moved):
            return self._decide("cost-gate", **price)
        self.last_replan_iter = it
        self._decide("staged", **price)
        return self._stage(plan)

    def rank_heatmap(self, expert_stats, slot_stats=None) -> np.ndarray:
        """Realized per-layer per-rank loads ``[n_blocks, ep]`` of one
        iteration's ``aux["expert_stats"]`` under the routable tables."""
        loads = np.asarray(expert_stats, np.float64)[:, 0, :]
        if self.per_layer and loads.shape[0] == self.n_tables:
            return np.stack([self.tables[l].rank_loads(loads[l])
                             for l in range(loads.shape[0])])
        return np.stack([self.table.rank_loads(loads[l])
                         for l in range(loads.shape[0])])

    # per-layer replan hooks (loop lives in ReplanDiscipline)
    def _layer_states(self) -> list:
        return self.tables

    def _plan_one_layer(self, load: np.ndarray,
                        vis: np.ndarray) -> PlacementTable:
        return plan_placement(self.pcfg.planner, load, self.ep, vis=vis,
                              cfg=self.pcfg)

    def _diff_layer_states(self, old_states: list, new_states: list
                           ) -> migrate.LayerMigrationPlan:
        return migrate.diff_layers(old_states, new_states,
                                   self.bytes_per_expert)

    def _accept_layer_plan(self, plan: migrate.LayerMigrationPlan,
                           new_states: list) -> migrate.LayerMigrationPlan:
        return self._stage(plan)

    def migration_seconds(self, moved_bytes: int) -> float:
        """Virtual-time cost of moving ``moved_bytes`` over the EP fabric
        — priced at the measured-bandwidth EWMA (the configured
        ``migration_bw`` until the first timed apply calibrates it)."""
        return self.bandwidth.seconds(moved_bytes)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"e2r": np.stack([t.e2r for t in self.tables]),
               "local_slot": np.stack([t.local_slot for t in self.tables]),
               "n_ranks": np.int64(self.ep),
               "n_tables": np.int64(self.n_tables),
               "n_migrations": np.int64(self.n_migrations),
               "migrated_bytes": np.int64(self.migrated_bytes),
               "migrated_experts": np.int64(self.migrated_experts),
               "migrated_bytes_per_layer": self.migrated_bytes_per_layer}
        for k, v in self.predictor.state_dict().items():
            out[f"pred_{k}"] = v
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        assert int(state["n_ranks"]) == self.ep, \
            (int(state["n_ranks"]), self.ep)
        nt = int(state.get("n_tables", 1))
        if nt != self.n_tables:
            raise ValueError(
                f"checkpoint holds {nt} placement table(s) but this "
                f"manager plans {self.n_tables} — per-layer and "
                "shared-table checkpoints are not interchangeable (the "
                "saved weights are permuted per the writer's tables)")
        e2r = np.atleast_2d(np.asarray(state["e2r"], np.int32))
        ls = np.atleast_2d(np.asarray(state["local_slot"], np.int32))
        self.tables = [PlacementTable(e2r[l], ls[l], self.ep)
                       for l in range(self.n_tables)]
        self.n_migrations = int(state["n_migrations"])
        self.migrated_bytes = int(state["migrated_bytes"])
        self.migrated_experts = int(state["migrated_experts"])
        self.migrated_bytes_per_layer = np.asarray(
            state.get("migrated_bytes_per_layer",
                      np.zeros(self.n_tables)), np.int64).reshape(
            self.n_tables)
        self._decode_since_replan = 0
        self._pending = None
        self._pending_remaining = None
        self.predictor.load_state_dict(
            {k[len("pred_"):]: v for k, v in state.items()
             if k.startswith("pred_")})
