"""Placement planners: predicted per-expert loads → an expert→rank table.

All planners are capacity-constrained (every rank ends with exactly
``E // n_ranks`` experts — slabs are statically shaped) and pure host
numpy, so they are unit-testable and reusable by the analytic cost-model
simulators in ``benchmarks/costmodel.py``.

* ``identity``        — the contiguous mapping; never migrates.
* ``least_loaded``    — greedy LPT bin packing of predicted loads: place
  experts heaviest-first, each onto the rank with the least accumulated
  predicted load that still has a free slot.  The classic
  HarMoEny/EPLB-style rebalancing objective (minimize the max rank load).
* ``modality_aware``  — co-locate vision-heavy experts so FP4 ranks are
  *concentrated* rather than spread: under ReaLB, a rank compresses when
  it is hot **and** vision-dominated, so packing the vision-heavy experts
  onto few ranks lets the hybrid compress a small slice of the model
  instead of quantizing everywhere.  Experts are packed onto ranks in
  descending vision-load order (rank 0 gets the most vision-heavy slab),
  then a bounded swap pass rebalances total load between ranks, swapping
  only expert pairs with similar vision ratio (``vis_tol``) so the
  concentration survives the rebalance.

Every bijective planner is bounded below by the hottest single expert —
a load no permutation can split.  When that bound binds, use the
redundant-expert planner (:mod:`repro.replication.planner`) instead,
which divides hot experts across ranks.

All planners consume ONE ``[E]`` load row, so per-layer planning
(``PlacementConfig.per_layer``) is simply the manager mapping them over
the predictor's ``[L, E]`` rows — one independent plan per scanned MoE
block, diffed into a layer-diff migration.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import PlacementConfig
from repro.placement.table import PlacementTable

PLANNERS = ("identity", "least_loaded", "modality_aware")


def plan_identity(num_experts: int, n_ranks: int) -> PlacementTable:
    return PlacementTable.identity(num_experts, n_ranks)


def plan_least_loaded(load: np.ndarray, n_ranks: int) -> PlacementTable:
    """Greedy LPT with per-rank slot capacity."""
    load = np.asarray(load, np.float64)
    e = load.shape[0]
    e_loc = e // n_ranks
    rank_load = np.zeros(n_ranks)
    rank_free = np.full(n_ranks, e_loc)
    e2r = np.empty(e, np.int32)
    for ex in np.argsort(-load, kind="stable"):
        open_ranks = np.flatnonzero(rank_free > 0)
        r = open_ranks[np.argmin(rank_load[open_ranks])]
        e2r[ex] = r
        rank_load[r] += load[ex]
        rank_free[r] -= 1
    return PlacementTable.from_ranks(e2r, n_ranks)


def plan_modality_aware(load: np.ndarray, vis: np.ndarray, n_ranks: int,
                        vis_tol: float = 0.25,
                        max_swaps: int = 64) -> PlacementTable:
    load = np.asarray(load, np.float64)
    vis = np.asarray(vis, np.float64)
    e = load.shape[0]
    e_loc = e // n_ranks
    # phase 1: concentrate — fill ranks in descending vision-load order
    order = np.argsort(-vis, kind="stable")
    e2r = np.empty(e, np.int32)
    e2r[order] = np.arange(e) // e_loc
    # phase 2: bounded rebalance of total load via vis-similar swaps
    r_v = vis / np.maximum(load, 1e-12)
    for _ in range(max_swaps):
        rl = np.zeros(n_ranks)
        np.add.at(rl, e2r, load)
        hi, lo = int(np.argmax(rl)), int(np.argmin(rl))
        spread = rl[hi] - rl[lo]
        if hi == lo or spread <= 1e-12:
            break
        cand_hi = np.flatnonzero(e2r == hi)
        cand_lo = np.flatnonzero(e2r == lo)
        # best swap: move ~spread/2 of load from hi to lo, keeping the
        # swapped experts' vision ratios within vis_tol of each other
        best, best_err = None, spread / 2.0
        for a in cand_hi:
            for b in cand_lo:
                if abs(r_v[a] - r_v[b]) > vis_tol:
                    continue
                delta = load[a] - load[b]
                err = abs(delta - spread / 2.0)
                if 0.0 < delta < spread and err < best_err:
                    best, best_err = (a, b), err
        if best is None:
            break
        a, b = best
        e2r[a], e2r[b] = lo, hi
    return PlacementTable.from_ranks(e2r, n_ranks)


def plan_placement(name: str, load: np.ndarray, n_ranks: int,
                   vis: Optional[np.ndarray] = None,
                   cfg: Optional[PlacementConfig] = None) -> PlacementTable:
    """Dispatch by planner name (`PlacementConfig.planner`)."""
    cfg = cfg or PlacementConfig()
    e = np.asarray(load).shape[0]
    if name == "identity":
        return plan_identity(e, n_ranks)
    if name == "least_loaded":
        return plan_least_loaded(load, n_ranks)
    if name == "modality_aware":
        v = np.zeros(e) if vis is None else vis
        return plan_modality_aware(load, v, n_ranks,
                                   vis_tol=cfg.vis_tol,
                                   max_swaps=cfg.max_swaps)
    raise ValueError(f"unknown planner {name!r}; known: {PLANNERS}")
