"""Live migration: plan-diff → expert-slab permutation of the weights.

The expert weight arrays are stored in *placed* (physical) order.  A new
plan is applied by one gather along the expert axis:

    w_new[..., p, :] = w_old[..., gather_idx[p], :]
    gather_idx = old.pos[new.owner]

i.e. physical row ``p`` must now hold logical expert ``new.owner[p]``,
whose weights currently sit at row ``old.pos[expert]``.  On a real EP
mesh the gather is a cross-device all-to-all of the moved slabs (XLA
lowers the resharding gather); on one device it is a copy.  Only the
routed expert tensors move — router weights are indexed by *logical*
expert id and never migrate, and attention / shared-expert / M-state
tensors are untouched.

``MigrationPlan`` also carries the accounting the benchmarks need: which
experts physically moved rank, and how many bytes of weights that is —
plus the *pending* new table(s), so managers can stage a plan (old table
stays routable) and commit per layer as each slab lands
(:mod:`repro.serving.async_migrate`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import MIGRATION_BW_DEFAULT, ModelConfig
from repro.placement.table import PlacementTable

MOE_WEIGHT_KEYS = ("w_gate", "w_up", "w_down")


class MigrationBandwidth:
    """Measured slab-transfer bandwidth: an EWMA of observed
    ``apply_to_params`` bytes/s, seeded with a nominal prior.

    One instance is shared by everything that prices migration bytes —
    the manager's ``migration_seconds`` (virtual-clock charge), the async
    executor's per-iteration chunk budget, and the replan cost gates
    (``benchmarks.costmodel.ReplanCostGate.bandwidth``) — so a measured
    value replaces the static ICI constant *everywhere at once*
    (ROADMAP "migration-bandwidth calibration").  ``float(bw)`` reads the
    current bytes/s.
    """

    def __init__(self, init_bw: float = MIGRATION_BW_DEFAULT,
                 alpha: float = 0.25):
        self.init_bw = float(init_bw)
        self.alpha = float(alpha)
        self._bw = float(init_bw)
        self.n_obs = 0

    def observe(self, nbytes: int, seconds: float) -> None:
        """One timed slab transfer (wall clock of the apply)."""
        if nbytes <= 0 or seconds <= 0:
            return
        sample = float(nbytes) / float(seconds)
        # first measurement replaces the prior outright: a nominal ICI
        # constant should not anchor a host whose fabric is 1000x off
        self._bw = sample if self.n_obs == 0 \
            else (1.0 - self.alpha) * self._bw + self.alpha * sample
        self.n_obs += 1

    @property
    def bytes_per_s(self) -> float:
        return self._bw

    @property
    def calibrated(self) -> bool:
        return self.n_obs > 0

    def __float__(self) -> float:
        return self._bw

    def seconds(self, nbytes: int) -> float:
        """Transfer time of ``nbytes`` at the current estimate."""
        return float(nbytes) / max(self._bw, 1.0)

    def reset(self) -> None:
        self._bw = self.init_bw
        self.n_obs = 0


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    gather_idx: np.ndarray     # [E] new physical row -> old physical row
    moved_experts: np.ndarray  # logical expert ids whose rank changed
    moved_bytes: int           # total weight bytes crossing ranks
    new_table: Optional[PlacementTable] = None  # pending (staged) table

    @property
    def n_moved(self) -> int:
        return int(self.moved_experts.shape[0])

    @property
    def is_noop(self) -> bool:
        return self.n_moved == 0


@dataclasses.dataclass(frozen=True)
class LayerMigrationPlan:
    """Layer-diff migration across per-layer placement tables.

    ``gather_idx [L, E]`` permutes each scanned block's weight slab
    independently; unchanged layers carry the identity row, so migration
    traffic scales with the number of *changed* layers rather than
    ``n_layers×`` (HarMoEny-style layer-wise rebalancing).
    ``moved_per_layer [L]`` counts experts whose rank changed in each
    layer; ``moved_bytes`` charges only those (expert, layer) pairs."""
    gather_idx: np.ndarray      # [L, E] per-layer new row -> old row
    moved_per_layer: np.ndarray  # [L] experts that changed rank per layer
    moved_bytes: int            # cross-rank bytes, changed layers only
    new_tables: tuple = ()      # pending (staged) per-layer tables

    @property
    def n_layers(self) -> int:
        return int(self.gather_idx.shape[0])

    @property
    def changed_layers(self) -> np.ndarray:
        return np.flatnonzero(self.moved_per_layer)

    @property
    def n_moved(self) -> int:
        """Total (expert, layer) pairs that changed rank."""
        return int(self.moved_per_layer.sum())

    @property
    def is_noop(self) -> bool:
        return self.n_moved == 0


def expert_bytes_raw(d_model: int, d_ff: int, bytes_per_param: float,
                     n_moe_layers: int) -> float:
    """Weight bytes of ONE expert (gate+up+down) across the MoE stack —
    the single formula shared by the serving manager and the analytic
    cost model."""
    return 3.0 * d_model * d_ff * bytes_per_param * n_moe_layers


def expert_bytes(cfg: ModelConfig, n_moe_layers: int) -> int:
    """Weight bytes of ONE expert across the whole MoE stack."""
    itemsize = np.dtype(cfg.param_dtype).itemsize \
        if cfg.param_dtype != "bfloat16" else 2
    return int(expert_bytes_raw(cfg.d_model, cfg.moe.d_ff, itemsize,
                                n_moe_layers))


def diff(old: PlacementTable, new: PlacementTable,
         bytes_per_expert: int = 0) -> MigrationPlan:
    """The permutation (and cost) taking placed weights from old to new."""
    assert old.num_experts == new.num_experts, (old, new)
    assert old.n_ranks == new.n_ranks, (old.n_ranks, new.n_ranks)
    gather = old.pos[new.owner]
    moved = np.flatnonzero(old.e2r != new.e2r)
    return MigrationPlan(gather_idx=gather.astype(np.int64),
                         moved_experts=moved,
                         moved_bytes=int(moved.shape[0]) * bytes_per_expert,
                         new_table=new)


def diff_layers(old_tables, new_tables,
                bytes_per_expert: int = 0) -> LayerMigrationPlan:
    """Layer-diff between two per-layer table stacks.

    ``bytes_per_expert`` is the weight bytes of one expert in ONE scanned
    block (not the whole stack): only (expert, layer) pairs whose rank
    changed are charged."""
    assert len(old_tables) == len(new_tables), \
        (len(old_tables), len(new_tables))
    gather, moved = [], []
    for old, new in zip(old_tables, new_tables):
        p = diff(old, new)
        gather.append(p.gather_idx)
        moved.append(p.n_moved)
    moved = np.asarray(moved, np.int64)
    return LayerMigrationPlan(
        gather_idx=np.stack(gather).astype(np.int64),
        moved_per_layer=moved,
        moved_bytes=int(moved.sum()) * bytes_per_expert,
        new_tables=tuple(new_tables))


def moe_param_paths(params: Dict[str, Any]) -> List[Tuple[str, str]]:
    """(block_group, layer_key) pairs holding routed-expert weights."""
    out = []
    for group in ("blocks", "prefix"):
        sub = params.get(group)
        if not isinstance(sub, dict):
            continue
        for lname, lp in sub.items():
            if isinstance(lp, dict) and "moe" in lp:
                out.append((group, lname))
    return out


def apply_to_params(params: Dict[str, Any], plan) -> Dict[str, Any]:
    """Gather every routed-expert weight slab by the migration plan.

    Returns a new params tree (shallow-copied containers; non-MoE leaves
    aliased).  Works on stacked ``[n_blocks, E, ...]`` scan weights and on
    unstacked ``[E, ...]`` ones; the router is left in logical order.

    ``plan`` is anything exposing ``gather_idx`` / ``is_noop``: a
    bijective :class:`MigrationPlan` (``[E]`` permutation), a
    :class:`repro.replication.migrate.ReplicaMigrationPlan` (``[S]``
    slot gather over the replica-expanded weight layout), or a per-layer
    :class:`LayerMigrationPlan` / :class:`repro.replication.migrate.
    LayerReplicaMigrationPlan` (``[L, E|S]`` — each stacked scan block's
    slab gathered by its own layer's row).
    """
    if plan.is_noop:
        return params
    idx = plan.gather_idx
    out = dict(params)
    for group, lname in moe_param_paths(params):
        grp = dict(out[group])
        lp = dict(grp[lname])
        moe = dict(lp["moe"])
        for key in MOE_WEIGHT_KEYS:
            w = moe[key]
            if idx.ndim == 2:          # per-layer gather over scan stack
                if w.ndim == 3:        # unstacked layer: only L == 1 fits
                    assert idx.shape[0] == 1, \
                        (idx.shape, w.shape, "per-layer plan needs "
                         "stacked [n_blocks, ...] weights")
                    moe[key] = jnp_take(w, idx[0], 0)
                else:
                    assert w.ndim == 4 and w.shape[0] == idx.shape[0], \
                        (w.shape, idx.shape)
                    moe[key] = jnp_take_layers(w, idx)
            else:
                axis = w.ndim - 3      # [.., E|S, a, b]: expert-slot axis
                moe[key] = jnp_take(w, idx, axis)
        lp["moe"] = moe
        grp[lname] = lp
        out[group] = grp
    return out


@dataclasses.dataclass(frozen=True)
class _LayerSubsetPlan:
    """A plan-shaped view gathering only a subset of a layer plan's rows
    (identity rows everywhere else) — what ``apply_to_params`` needs."""
    gather_idx: np.ndarray
    is_noop: bool = False


def subset_plan(plan, layers: Sequence[int]):
    """The plan restricted to ``layers``: selected layers keep their
    gather rows, every other layer gets the identity row.

    For a *shared* (1-D) plan the only meaningful subset is the whole
    plan — layer index 0 stands for "the one shared chunk"."""
    idx = np.asarray(plan.gather_idx)
    sel = sorted({int(l) for l in layers})
    if idx.ndim == 1:
        assert sel == [0], \
            (sel, "a shared plan has exactly one chunk (layer 0)")
        return plan
    assert all(0 <= l < idx.shape[0] for l in sel), (sel, idx.shape)
    full = np.tile(np.arange(idx.shape[1], dtype=np.int64),
                   (idx.shape[0], 1))
    full[sel] = idx[sel]
    return _LayerSubsetPlan(gather_idx=full, is_noop=not sel)


def apply_layers_to_params(params: Dict[str, Any], plan,
                           layers: Sequence[int]) -> Dict[str, Any]:
    """Chunked subset apply: gather only ``layers``' weight slabs of a
    per-layer plan (placement or replication — anything with an
    ``[L, E|S]`` ``gather_idx``), leaving every other layer's slab
    untouched.  The unit of overlap of asynchronous migration
    (:mod:`repro.serving.async_migrate`): applying every changed layer,
    one call per layer, is exactly equivalent to one ``apply_to_params``
    of the whole plan."""
    return apply_to_params(params, subset_plan(plan, layers))


def jnp_take(w, idx, axis: int):
    """Gather that works for numpy and jax arrays without importing jax at
    module load (the planners/table are importable in pure-numpy tools)."""
    if isinstance(w, np.ndarray):
        return np.take(w, idx, axis=axis)
    import jax.numpy as jnp
    return jnp.take(w, jnp.asarray(idx), axis=axis)


def jnp_take_layers(w, idx):
    """Per-layer slot gather: ``out[l, p] = w[l, idx[l, p]]`` for stacked
    ``[L, S, a, b]`` scan weights and an ``[L, S]`` layer-diff index."""
    idx_r = np.asarray(idx, np.int64).reshape(
        idx.shape + (1,) * (w.ndim - 2))
    if isinstance(w, np.ndarray):
        return np.take_along_axis(w, idx_r, axis=1)
    import jax.numpy as jnp
    return jnp.take_along_axis(w, jnp.asarray(idx_r), axis=1)
