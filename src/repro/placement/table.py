"""Expert→rank placement tables (host side).

A :class:`PlacementTable` is the host-numpy twin of the traced
``repro.core.ep_moe.Placement`` tuple: ``e2r[e]`` names the EP rank that
owns logical expert ``e`` and ``local_slot[e]`` its position in that
rank's fixed-size weight slab.  Together they are a bijection onto
``rank * e_loc + slot`` — slabs hold exactly ``E // n_ranks`` experts
because the physical buffers (and the capacity-packed dispatch layout)
are statically shaped.

The *placed position* ``pos[e] = e2r[e] * e_loc + local_slot[e]`` is the
row at which expert ``e``'s weights live in the (physically permuted)
``[E, ...]`` weight arrays; ``owner`` is the inverse permutation
(physical row → logical expert).  Migration between two tables is a
gather of weight rows by ``owner`` composition — see
:mod:`repro.placement.migrate`.

A table is the single-replica special case of the redundant-expert
ownership matrix: :meth:`repro.replication.ReplicaSet.from_placement`
lifts one into a (possibly spare-padded) replica set, and the identity
replica set round-trips back to this exact layout.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PlacementTable:
    e2r: np.ndarray            # [E] int32: logical expert -> owning rank
    local_slot: np.ndarray     # [E] int32: slot within the owner's slab
    n_ranks: int

    def __post_init__(self):
        e2r = np.asarray(self.e2r, np.int32)
        ls = np.asarray(self.local_slot, np.int32)
        object.__setattr__(self, "e2r", e2r)
        object.__setattr__(self, "local_slot", ls)
        e = e2r.shape[0]
        assert ls.shape == (e,), (e2r.shape, ls.shape)
        assert e % self.n_ranks == 0, (e, self.n_ranks)
        e_loc = e // self.n_ranks
        counts = np.bincount(e2r, minlength=self.n_ranks)
        assert counts.shape[0] == self.n_ranks and (counts == e_loc).all(), \
            f"each rank must own exactly {e_loc} experts, got {counts}"
        pos = self.pos
        assert len(np.unique(pos)) == e, "e2r/local_slot is not a bijection"

    # -- derived views ----------------------------------------------------
    @property
    def num_experts(self) -> int:
        return int(self.e2r.shape[0])

    @property
    def e_loc(self) -> int:
        return self.num_experts // self.n_ranks

    @property
    def pos(self) -> np.ndarray:
        """[E] logical expert -> physical weight row (placed position)."""
        return self.e2r.astype(np.int64) * self.e_loc \
            + self.local_slot.astype(np.int64)

    @property
    def owner(self) -> np.ndarray:
        """[E] physical weight row -> logical expert (inverse of pos)."""
        inv = np.empty(self.num_experts, np.int64)
        inv[self.pos] = np.arange(self.num_experts)
        return inv

    def rank_loads(self, expert_load: np.ndarray) -> np.ndarray:
        """Aggregate per-logical-expert loads onto the placed ranks [R]."""
        out = np.zeros(self.n_ranks, np.float64)
        np.add.at(out, self.e2r, np.asarray(expert_load, np.float64))
        return out

    def as_tuple(self) -> Tuple[np.ndarray, np.ndarray]:
        """(e2r, local_slot) for the traced MoE layer."""
        return self.e2r, self.local_slot

    # -- constructors -----------------------------------------------------
    @classmethod
    def identity(cls, num_experts: int, n_ranks: int) -> "PlacementTable":
        ar = np.arange(num_experts, dtype=np.int32)
        e_loc = num_experts // n_ranks
        return cls(ar // e_loc, ar % e_loc, n_ranks)

    @classmethod
    def from_ranks(cls, e2r: np.ndarray, n_ranks: int) -> "PlacementTable":
        """Derive slots from a rank assignment: experts keep logical order
        within their rank (stable), so repeated planning is deterministic."""
        e2r = np.asarray(e2r, np.int32)
        slot = np.zeros_like(e2r)
        for r in range(n_ranks):
            members = np.flatnonzero(e2r == r)
            slot[members] = np.arange(members.shape[0])
        return cls(e2r, slot, n_ranks)
