"""Predictive expert→rank placement & live migration.

The slow-timescale complement to ReaLB's fast precision switching: an
EWMA predictor of per-expert routed load feeds capacity-constrained
planners (``identity`` / ``least_loaded`` / ``modality_aware``) whose
plans are applied as live weight-slab permutations on a configurable
cadence — so persistent routing skew is remapped away while FP4
compression absorbs the bursts no plan can anticipate.  See
``repro.core.ep_moe`` for how the traced table enters the MoE layer and
``repro.serving.engine`` for the serving-side loop.
"""
from repro.placement.manager import PlacementManager
from repro.placement.migrate import (LayerMigrationPlan, MigrationBandwidth,
                                     MigrationPlan, apply_layers_to_params,
                                     apply_to_params, diff, diff_layers,
                                     expert_bytes, moe_param_paths,
                                     subset_plan)
from repro.placement.planner import (PLANNERS, plan_identity,
                                     plan_least_loaded, plan_modality_aware,
                                     plan_placement)
from repro.placement.predictor import EWMAPredictor
from repro.placement.table import PlacementTable

__all__ = [
    "PlacementManager", "MigrationPlan", "LayerMigrationPlan",
    "MigrationBandwidth", "apply_to_params", "apply_layers_to_params",
    "subset_plan", "diff", "diff_layers",
    "expert_bytes", "moe_param_paths", "PLANNERS", "plan_identity",
    "plan_least_loaded", "plan_modality_aware", "plan_placement",
    "EWMAPredictor", "PlacementTable",
]
