"""ReaLB core: the paper's contribution (policy, quantization, EP MoE)."""
from repro.core.ep_moe import (AUX_SCALARS, ep_moe_forward, moe_spec,
                               moe_state_shape)
from repro.core.policy import (PolicyDecision, init_m_state, lb_gate,
                               realb_policy)
from repro.core.quant import (QTensor, dequantize_fp4, e4m3_round, fp4_round,
                              fp4_sim, matmul_w4a16, matmul_w4a4, quant_error,
                              quantize_fp4)
