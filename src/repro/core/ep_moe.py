"""Expert-parallel MoE layer with ReaLB runtime load balancing.

This is the paper's contribution as a composable JAX module.  The MoE layer
runs under a fully-manual ``jax.shard_map`` over the whole mesh; the EP
group is the "model" axis (each (pod, data) row of model-ranks forms an
independent EP group, mirroring the paper's DP-attention + EP-MoE
deployment generalized to a 2/3-D mesh).

Two execution paths:

* ``dispatch`` (train / prefill, large token counts): capacity-packed
  ``all_to_all`` token exchange over the EP axis, local re-sort by expert,
  grouped GEMM via ``lax.ragged_dot`` (per-rank time scales with the true
  received load — straggler dynamics are preserved on TPU), ``all_to_all``
  combine.  ReaLB's metadata collection (psum of routing counts) and the
  conditional BF16→FP4 weight transformation have **no data dependency on
  the dispatch all_to_all**, so XLA's latency-hiding scheduler overlaps
  them with communication — the paper's pipeline orchestration (§4.3),
  expressed structurally.  ``overlap=False`` (ReaLB-seq) inserts an
  artificial dependency to serialise, for the ablation.

* ``broadcast`` (decode, small token counts): tokens are replicated over
  the EP axis; each rank computes only its local experts' contributions and
  a ``psum`` combines.  This is the standard small-batch EP regime where
  the paper's LB gate keeps ReaLB off.

The per-rank precision decision is a *traced* ``lax.cond`` whose predicate
is rank-local — SPMD HLO ``conditional``, each EP rank dynamically takes
the FP4 or BF16 branch with zero host round-trips.

Expert placement
----------------
Both paths route through a traced :class:`Placement` table instead of the
hardwired contiguous expert→rank mapping: ``e2r[e]`` is the EP rank that
owns logical expert ``e`` and ``local_slot[e]`` its position in that
rank's weight slab.  The expert weight arrays are stored in *placed*
(physical) order — row ``r * e_loc + s`` holds the expert with
``e2r == r, local_slot == s`` — so live migration (see
:mod:`repro.placement`) is a host-side gather of the weight slabs plus a
new table; the traced graph never recompiles.  With the identity table
(the default) every index equals the old ``flat_e // e_loc`` arithmetic,
so outputs are bitwise-identical to the pre-placement layer.  Routing
counts, capacity packing, the per-rank load/vision statistics and the
ReaLB policy all observe the *placed* loads.

On a single device the physical EP group is 1, but the policy statistics
can still be computed over a *virtual* EP topology (``m_state`` of shape
``[1, vep]``): per-virtual-rank placed loads drive the ReaLB policy and
its AIMD state, which makes IB_d / FP4-duty / placement experiments
meaningful in CPU virtual-time serving runs.

Redundant experts (replication)
-------------------------------
The bijective table generalizes to a traced :class:`Replication` set
(see :mod:`repro.replication`): each logical expert owns up to ``R``
physical weight slots on distinct ranks, out of ``S >= E`` statically
shaped slots (``slots_per_rank`` may exceed ``E // n_ranks`` — the spare
slots hold replicas of hot experts).  Routed assignments are split
across an expert's replicas by a *deterministic round-robin* rule — the
``i``-th local assignment of expert ``e`` goes to replica
``i mod n_rep[e]`` — i.e. a proportional 1/c token split with no
randomness and no host round-trip.  Everything downstream observes the
*post-split physical* loads: capacity packing, ``load_d``/``vis_d``, the
LB gate, IB_d, and therefore the FP4 decision and the AIMD update react
to the balanced physical topology, not the logical one.  With the
identity set (one replica per expert, ``S == E``) every intermediate
equals the bijective-placement path bitwise.

Per-layer tables
----------------
This layer always consumes ONE table — the table of the layer being
computed.  Per-layer placement/replication (multimodal routing skew is
per-layer; paper Fig. 2) is realized one level up: the transformer stacks
the tables along a leading ``[n_blocks]`` axis and threads the slice
through its ``lax.scan`` xs alongside the block params (see
``repro.models.transformer.split_placement``), so each scanned block
routes through its own table while this module stays table-shape
agnostic.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig, MoEConfig, ReaLBConfig
from repro.core import quant
from repro.core.policy import realb_policy
from repro.kernels import nvfp4
from repro.kernels import ops as kops
from repro.models.common import P, current_mesh, resolve_spec, shard_map

Params = Dict[str, jax.Array]
F32 = jnp.float32


# --------------------------------------------------------------------------
# expert placement table
# --------------------------------------------------------------------------
class Placement(NamedTuple):
    """Traced logical-expert → (rank, slot) assignment.

    ``e2r [E]`` — owning EP rank per logical expert; ``local_slot [E]`` —
    index into that rank's weight slab.  Together they must form a
    bijection onto ``rank * e_loc + slot`` (each rank owns exactly
    ``E // n_ranks`` experts — slabs are fixed-size).
    """
    e2r: jax.Array
    local_slot: jax.Array


def identity_placement(num_experts: int, n_ranks: int) -> Placement:
    """The contiguous mapping (expert ``e`` on rank ``e // e_loc``)."""
    ar = jnp.arange(num_experts, dtype=jnp.int32)
    e_loc = num_experts // n_ranks
    return Placement(ar // e_loc, ar % e_loc)


def _placed_index(place: Placement, e_loc: int) -> jax.Array:
    """[E] logical expert -> placed position ``rank * e_loc + slot``."""
    return place.e2r.astype(jnp.int32) * e_loc \
        + place.local_slot.astype(jnp.int32)


def _placed_inverse(pos_e: jax.Array) -> jax.Array:
    """[E] placed position -> logical expert (inverse permutation)."""
    e = pos_e.shape[0]
    return jnp.zeros((e,), jnp.int32).at[pos_e].set(
        jnp.arange(e, dtype=jnp.int32))


# --------------------------------------------------------------------------
# expert replication (redundant experts, token-split dispatch)
# --------------------------------------------------------------------------
class Replication(NamedTuple):
    """Traced logical-expert → physical-replica-slot ownership matrix.

    ``rep_pos [E, R]`` — physical slot (``rank * s_loc + slot``) of each
    replica; entries at ``j >= n_rep[e]`` repeat the primary.
    ``n_rep [E]`` — valid replica count per expert (>= 1).
    ``slot_owner [S]`` — logical expert resident in each physical slot
    (``-1`` = empty spare; such slots are never routed to).

    The host-numpy twin is :class:`repro.replication.ReplicaSet`.
    """
    rep_pos: jax.Array
    n_rep: jax.Array
    slot_owner: jax.Array


class WeightedReplication(NamedTuple):
    """:class:`Replication` plus a weighted-split schedule:
    ``split_sched [E, Q]`` sends the ``occ``-th routed token of expert
    ``e`` to replica ``split_sched[e, occ % Q]`` (host-built deficit
    round-robin over residual-capacity weights; the plain 3-field
    ``Replication`` keeps the equal-share ``occ % n_rep`` split)."""
    rep_pos: jax.Array
    n_rep: jax.Array
    slot_owner: jax.Array
    split_sched: jax.Array


def identity_replication(num_experts: int, n_ranks: int) -> Replication:
    """One replica per expert, no spare slots ≡ the identity placement."""
    ar = jnp.arange(num_experts, dtype=jnp.int32)
    return Replication(ar[:, None], jnp.ones_like(ar), ar)


def _rep_from_entries(entries):
    if len(entries) == 4:
        return WeightedReplication(*entries)
    return Replication(*entries)


def _as_replication(placement, num_experts: int, pol_ep: int) -> Replication:
    """Normalize the user-facing ``placement`` argument: None (identity),
    a bijective ``Placement``/2-tuple, or a ``Replication``/3- or
    4-tuple (the 4th entry is the weighted-split schedule)."""
    if placement is None:
        return identity_replication(num_experts, pol_ep)
    if isinstance(placement, (Replication, WeightedReplication)):
        return placement
    entries = tuple(placement)
    if len(entries) in (3, 4):
        return _rep_from_entries(entries)
    place = placement if isinstance(placement, Placement) \
        else Placement(*entries)
    pos_e = _placed_index(place, num_experts // pol_ep)
    return Replication(pos_e[:, None],
                       jnp.ones((num_experts,), jnp.int32),
                       _placed_inverse(pos_e))


def _occurrence_index(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """[n] per-assignment rank among same-expert assignments (original
    order) — the deterministic round-robin counter of the token split.
    Entries equal to ``num_experts`` (masked-out assignments) count only
    against each other, never against real experts."""
    n = flat_e.shape[0]
    ord_e = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=num_experts + 1)
    offs = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    occ_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.take(offs,
                                                           flat_e[ord_e])
    return jnp.zeros((n,), jnp.int32).at[ord_e].set(occ_sorted)


def _split_assignments(rep: Replication, flat_e: jax.Array,
                       valid_flat: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """(flat_pos [n], is_secondary [n]): the physical slot each routed
    assignment is dispatched to, round-robin over the expert's replicas.

    The counter runs over *valid* assignments only — invalid ones
    (chunk-bucket padding, dummy decode rows) pin to the primary replica
    and are excluded from the count, so padding neither shifts which
    replica serves a real token nor moves the post-split policy stats
    (the invariant the valid-weighted counts established in PR 1).
    """
    if rep.rep_pos.shape[1] == 1:      # bijective: skip the counter
        flat_p = jnp.take(rep.rep_pos[:, 0], flat_e)
        return flat_p, jnp.zeros(flat_e.shape, jnp.bool_)
    e = rep.rep_pos.shape[0]
    occ = _occurrence_index(jnp.where(valid_flat, flat_e, e), e)
    sched = getattr(rep, "split_sched", None)
    if sched is not None:
        # weighted split: the schedule row encodes the replica shares
        # (deficit round-robin, host-built by ReplicaSet.split_schedule)
        q = sched.shape[1]
        ridx = jnp.where(valid_flat, sched[flat_e, occ % q], 0)
    else:
        ridx = jnp.where(valid_flat, occ % jnp.take(rep.n_rep, flat_e), 0)
    flat_p = rep.rep_pos[flat_e, ridx]
    return flat_p, ridx > 0


# --------------------------------------------------------------------------
# parameter declaration
# --------------------------------------------------------------------------
def moe_spec(cfg: ModelConfig) -> Dict[str, P]:
    e = cfg.moe
    d = cfg.d_model
    return {
        "router": P((d, e.num_experts), (None, None), dtype="float32"),
        "w_gate": P((e.num_experts, d, e.d_ff), ("expert", "embed", "ffn")),
        "w_up": P((e.num_experts, d, e.d_ff), ("expert", "embed", "ffn")),
        "w_down": P((e.num_experts, e.d_ff, d), ("expert", None, "embed")),
    }


# --------------------------------------------------------------------------
# communication abstraction (lets the same math run without a mesh)
# --------------------------------------------------------------------------
class Comm(NamedTuple):
    ep: int
    my_rank: Any                                   # traced int or 0
    psum_model: Callable[[jax.Array], jax.Array]
    all_gather_model: Callable[[jax.Array], jax.Array]   # adds leading ep dim
    a2a: Callable[[jax.Array], jax.Array]                # over leading ep dim
    fsdp_gather: Callable[[jax.Array, int], jax.Array]   # all-gather 'data'


def _dist_comm(ep: int, fsdp: bool) -> Comm:
    return Comm(
        ep=ep,
        my_rank=jax.lax.axis_index("model"),
        psum_model=lambda x: jax.lax.psum(x, "model"),
        all_gather_model=lambda x: jax.lax.all_gather(x, "model"),
        a2a=lambda x: jax.lax.all_to_all(x, "model", 0, 0, tiled=True),
        fsdp_gather=(lambda x, ax: jax.lax.all_gather(
            x, "data", axis=ax, tiled=True)) if fsdp
        else (lambda x, ax: x),
    )


def _local_comm() -> Comm:
    return Comm(ep=1, my_rank=0,
                psum_model=lambda x: x,
                all_gather_model=lambda x: x[None],
                a2a=lambda x: x,
                fsdp_gather=lambda x, ax: x)


def _gather_weights(p: Params, comm: Comm) -> Dict[str, jax.Array]:
    """FSDP all-gather of the locally-owned expert slab (ZeRO layout)."""
    return {"w_gate": comm.fsdp_gather(p["w_gate"], 1),
            "w_up": comm.fsdp_gather(p["w_up"], 1),
            "w_down": comm.fsdp_gather(p["w_down"], 2)}


# --------------------------------------------------------------------------
# routing
# --------------------------------------------------------------------------
def _route(router_w: jax.Array, x_t: jax.Array, e_cfg: MoEConfig
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """returns (gates [t,K] f32, eidx [t,K] i32, probs [t,E] f32)."""
    logits = x_t.astype(F32) @ router_w.astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, e_cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eidx.astype(jnp.int32), probs


def _aux_losses(probs: jax.Array, counts_global: jax.Array,
                group_tokens: jax.Array, e_cfg: MoEConfig,
                psum: Callable) -> Dict[str, jax.Array]:
    """GShard-style load-balance + router z losses (per EP group)."""
    e = e_cfg.num_experts
    f = counts_global / jnp.maximum(group_tokens * e_cfg.top_k, 1.0)
    p_mean = psum(probs.sum(0)) / jnp.maximum(group_tokens, 1.0)
    lb = e * jnp.sum(f * p_mean)
    lse = jax.scipy.special.logsumexp(
        jnp.log(jnp.maximum(probs, 1e-20)), axis=-1)
    z = psum(jnp.sum(lse ** 2)) / jnp.maximum(group_tokens, 1.0)
    return {"lb_loss": lb, "z_loss": z}


# --------------------------------------------------------------------------
# grouped expert compute (bf16 / fp4 branches)
# --------------------------------------------------------------------------
def _rdot(lhs, rhs, gs):
    return jax.lax.ragged_dot(lhs, rhs, gs,
                              preferred_element_type=F32).astype(lhs.dtype)


def _grouped_ffn(xs, gs, w_gate, w_up, w_down, act):
    """xs [m,D] sorted by group; gs [G]; w_* [G,.,.] (contraction on dim 1)."""
    g = _rdot(xs, w_gate.astype(xs.dtype), gs)
    u = _rdot(xs, w_up.astype(xs.dtype), gs)
    h = act(g.astype(F32)).astype(xs.dtype) * u
    return _rdot(h, w_down.astype(xs.dtype), gs)


def _dq_t(q: quant.QTensor, dtype) -> jax.Array:
    """Dequantize a [G,N,K]-layout QTensor to [G,K,N] for ragged_dot."""
    return quant.dequantize_fp4(q, F32).swapaxes(-1, -2).astype(dtype)


def _grouped_ffn_fp4(xs, gs, wq: Dict[str, quant.QTensor],
                     rcfg: ReaLBConfig, act):
    """NVFP4 W4A4 grouped FFN, backend-switched at trace time.

    With ``kernels.ops.ffn_backend() != "jnp"`` this is the fused Pallas
    grouped kernel (native on TPU, interpret-mode on CPU): packed weights
    stream HBM→VMEM at 4.25 bits/weight and the intermediate ``h`` never
    round-trips HBM.  The jnp fallback below is the numerics oracle the
    kernel is pinned against — same dynamic per-group-16 activation
    fake-quant (``nvfp4.fake_quant_a4``), dequantize + ``ragged_dot``.
    """
    if kops.ffn_backend() != "jnp":
        return kops.grouped_fp4_ffn(xs, gs, wq, group=rcfg.group_size,
                                    act=act)
    xq = nvfp4.fake_quant_a4(xs, rcfg.group_size).astype(xs.dtype)
    g = _rdot(xq, _dq_t(wq["w_gate"], xs.dtype), gs)
    u = _rdot(xq, _dq_t(wq["w_up"], xs.dtype), gs)
    h = act(g.astype(F32)).astype(xs.dtype) * u
    hq = nvfp4.fake_quant_a4(h, rcfg.group_size).astype(xs.dtype)
    return _rdot(hq, _dq_t(wq["w_down"], xs.dtype), gs)


def _quantize_experts(w: Dict[str, jax.Array], use_fp4: jax.Array,
                      rcfg: ReaLBConfig,
                      overlap_token: Optional[jax.Array]) -> Dict[str, Any]:
    """③ on-the-fly BF16→FP4 transformation, conditional on the plan.

    Consumes only resident weights plus the routing-metadata predicate, so
    the HLO has no dependency path from the dispatch all_to_all into these
    ops — XLA overlaps them with communication.  ``overlap_token``
    (ReaLB-seq ablation) injects a fake dependency on the a2a output to
    serialise the transformation after dispatch.
    """

    def do_quant(ws):
        out = {}
        use_kernel = kops.ffn_backend() != "jnp"
        for name, wt in ws.items():
            wt_t = wt.swapaxes(-1, -2)  # [G, N, K]: quantize along K
            if overlap_token is not None:
                wt_t = wt_t + overlap_token.astype(wt_t.dtype)
            if use_kernel:
                # Pallas quantize kernel — bitwise-identical to the jnp
                # recipe, but streams the slab once at 4.25 bits/wt out.
                out[name] = kops.quantize_experts_fp4(
                    wt_t, group=rcfg.group_size)
            else:
                out[name] = quant.quantize_fp4(wt_t, rcfg.group_size)
        return out

    def no_quant(ws):
        # zeros derived from the weights so the varying-manual-axes (VMA)
        # type matches the quantizing branch under shard_map
        out = {}
        for name, wt in ws.items():
            wt_t = wt.swapaxes(-1, -2)
            out[name] = quant.QTensor(
                (wt_t[..., ::2] * 0).astype(jnp.uint8),
                (wt_t[..., ::rcfg.group_size] * 0).astype(F32),
                (wt_t.reshape(-1)[0] * 0 + 1).astype(F32))
        return out

    return jax.lax.cond(use_fp4, do_quant, no_quant, w)


# --------------------------------------------------------------------------
# dispatch path (train / prefill)
# --------------------------------------------------------------------------
def _moe_dispatch(x_t, mod_t, val_t, p, m_vec, cfg, rcfg, comm, act, rep,
                  pol_ep, train, stop_stage=None):
    """x_t [t,D] local tokens; mod_t [t] vision flags; val_t [t] real-token
    flags (False = batch padding); m_vec [pol_ep] AIMD; rep maps logical
    experts onto replica slots strided over ``pol_ep`` policy ranks
    (== comm.ep on a real EP mesh; a virtual topology when comm.ep == 1).

    ``stop_stage`` (trace-time static) truncates the computation after the
    named phase and returns that phase's live boundary values — the
    profiler's instrumented mode jits each cumulative prefix and times it
    standalone; ``None`` (the default, and the last prefix) is the normal
    fused layer, so instrumentation shares every op with production."""
    e_cfg = cfg.moe
    ep, e = comm.ep, cfg.moe.num_experts
    n_slots = rep.slot_owner.shape[0]    # physical weight slots (>= E)
    s_loc = n_slots // ep                # physical slab size per rank
    s_pol = n_slots // pol_ep            # policy-topology slab size
    t, d = x_t.shape
    k = e_cfg.top_k

    # ① routing + metadata (the lightweight "S" collection) ---------------
    with jax.named_scope("route"):
        gates, eidx, probs = _route(p["router"], x_t, e_cfg)
        flat_e = eidx.reshape(t * k)
        # deterministic round-robin token split over each expert's replicas
        # (valid assignments only — padding pins to the primary)
        val_flat = jnp.repeat(val_t.astype(bool), k)
        flat_p, secondary = _split_assignments(rep, flat_e, val_flat)
        # counts are valid-weighted so the LB gate, IB_d, the AIMD update
        # and the dispatch packing all see only real tokens — chunk-bucket
        # padding neither moves the policy nor claims expert capacity
        w_val = jnp.repeat(val_t.astype(F32), k)
        w_vis = jnp.repeat((mod_t & val_t).astype(F32), k)
        counts_stat = jnp.bincount(flat_e, weights=w_val, length=e)
        vis_local = jnp.bincount(flat_e, weights=w_vis, length=e)
        counts_global = comm.psum_model(counts_stat)          # [E] logical
        vis_global = comm.psum_model(vis_local)
        # per-physical-slot *post-split* loads: the policy, the packing and
        # the diagnostics all observe the replica-balanced topology
        slot_stat = jnp.bincount(flat_p, weights=w_val, length=n_slots)
        slot_load = comm.psum_model(slot_stat)                # [S] physical
        slot_vis = comm.psum_model(
            jnp.bincount(flat_p, weights=w_vis, length=n_slots))
        load_d = slot_load.reshape(pol_ep, s_pol).sum(-1)
        vis_d = slot_vis.reshape(pol_ep, s_pol).sum(-1)
        split = comm.psum_model(jnp.sum(secondary.astype(F32) * w_val))

        # ② modality-aware LB scheduling (AIMD policy) ---------------------
        dec = realb_policy(load_d, vis_d, m_vec, rcfg)
        if ep == pol_ep:
            use_fp4_rank = dec.use_fp4[comm.my_rank]
        else:  # virtual policy topology on one physical rank: any -> all
            use_fp4_rank = jnp.any(dec.use_fp4)
        use_fp4_me = jnp.asarray(False) if train else use_fp4_rank
    if stop_stage == "route":
        return gates, flat_p, dec.m_new, load_d, use_fp4_me

    with jax.named_scope("weight_gather"):
        w = _gather_weights(p, comm)
    if stop_stage == "weight_gather":
        return gates, flat_p, dec.m_new, use_fp4_me, w

    # ③ conditional on-the-fly quantization (overlaps with a2a below) ------
    wq = None
    if not train and rcfg.overlap:
        with jax.named_scope("quantize_fp4"):
            wq = _quantize_experts(w, use_fp4_me, rcfg, None)
    if stop_stage == "quantize_fp4":
        # under ReaLB-seq / train the transformation has not run here —
        # its cost lands inside the dispatch prefix instead
        return gates, flat_p, dec.m_new, use_fp4_me, w if wq is None else wq

    # dispatch --------------------------------------------------------------
    # padding tokens are sorted to the back and never claim a capacity
    # slot, so they cannot crowd real tokens out of the per-rank cap (the
    # cap itself is provisioned from the static t, which over- rather than
    # under-provisions when chunks underfill the bucket)
    with jax.named_scope("dispatch"):
        dest = flat_p // s_loc
        valid_flat = val_flat
        order = jnp.argsort(jnp.where(valid_flat, dest, ep), stable=True)
        dest_s = dest[order]
        valid_s = valid_flat[order]
        send_counts = slot_stat.reshape(ep, s_loc).sum(-1) \
            .astype(jnp.int32)                                 # [ep] valid
        offsets = jnp.cumsum(send_counts) - send_counts
        pos_in_rank = jnp.arange(t * k, dtype=jnp.int32) - offsets[dest_s]
        cap = max(8, -(-math.ceil(t * k / ep * e_cfg.capacity_factor)
                       // 8) * 8)
        big = ep * cap + 7                   # OOB -> dropped (mode="drop")
        slot_s = jnp.where(valid_s & (pos_in_rank < cap),
                           dest_s * cap + pos_in_rank, big)

        tok_idx_s = (order // k).astype(jnp.int32)
        vals_s = jnp.take(x_t, tok_idx_s, axis=0)
        leid_s = (flat_p % s_loc)[order]
        send = jnp.zeros((ep * cap, d), x_t.dtype).at[slot_s].set(
            vals_s, mode="drop")
        eid_send = jnp.full((ep * cap,), s_loc, jnp.int32).at[slot_s].set(
            leid_s, mode="drop")
        slot_flat = jnp.full((t * k,), big, jnp.int32).at[order].set(
            slot_s.astype(jnp.int32))

        recv = comm.a2a(send.reshape(ep, cap, d)).reshape(ep * cap, d)
        eid_recv = comm.a2a(eid_send.reshape(ep, cap)).reshape(ep * cap)

    if not train and wq is None:   # ReaLB-seq: serialise T after dispatch
        with jax.named_scope("quantize_fp4"):
            token = (recv.sum() * 0.0).astype(F32)
            wq = _quantize_experts(w, use_fp4_me, rcfg, token)
    if stop_stage == "dispatch":
        return gates, dec.m_new, recv, eid_recv, slot_flat

    # ④ balanced local expert compute ---------------------------------------
    with jax.named_scope("expert_gemm"):
        order2 = jnp.argsort(eid_recv, stable=True)
        xs = jnp.take(recv, order2, axis=0)
        gs = jnp.bincount(eid_recv, length=s_loc + 1).astype(jnp.int32)
        pad_row = lambda a: jnp.concatenate([a, a[:1]], axis=0)
        w_pad = {n: pad_row(v) for n, v in w.items()}
        if train:
            ys = _grouped_ffn(xs, gs, w_pad["w_gate"], w_pad["w_up"],
                              w_pad["w_down"], act)
        else:
            wq_pad = {n: quant.QTensor(pad_row(v.packed), pad_row(v.scales),
                                       v.global_scale)
                      for n, v in wq.items()}
            ys = jax.lax.cond(
                use_fp4_me,
                lambda o: _grouped_ffn_fp4(o[0], gs, o[2], rcfg, act),
                lambda o: _grouped_ffn(o[0], gs, o[1]["w_gate"],
                                       o[1]["w_up"], o[1]["w_down"], act),
                (xs, w_pad, wq_pad))
        y_buf = jnp.zeros_like(ys).at[order2].set(ys)
    if stop_stage == "expert_gemm":
        return gates, dec.m_new, y_buf, slot_flat

    with jax.named_scope("combine"):
        ret = comm.a2a(y_buf.reshape(ep, cap, d)).reshape(ep * cap, d)
        y_flat = jnp.take(ret, slot_flat, axis=0, mode="fill", fill_value=0)
        y_flat = jnp.where((slot_flat < big)[:, None], y_flat, 0)
        out = jnp.sum(y_flat.reshape(t, k, d)
                      * gates[..., None].astype(y_flat.dtype), axis=1)

    # diagnostics ------------------------------------------------------------
    total = jnp.sum(load_d)
    dropped = comm.psum_model(
        jnp.sum((slot_flat >= big).astype(F32) * w_val))
    aux = _aux_losses(probs, counts_global, total / max(k, 1), e_cfg,
                      comm.psum_model)
    aux.update(drop_frac=dropped / jnp.maximum(total, 1.0),
               ib_global=dec.ib_global,
               fp4_ranks=jnp.sum(dec.use_fp4.astype(F32)),
               load_d=load_d, vis_d=vis_d,
               expert_load=counts_global, expert_vis=vis_global,
               slot_load=slot_load, slot_vis=slot_vis,
               split_frac=split / jnp.maximum(total, 1.0),
               gate_open=dec.gate_open.astype(F32))
    return out.astype(x_t.dtype), dec.m_new, aux


# --------------------------------------------------------------------------
# broadcast path (decode)
# --------------------------------------------------------------------------
def _moe_broadcast(x_t, mod_t, val_t, p, m_vec, cfg, rcfg, comm, act, rep,
                   pol_ep, stop_stage=None):
    """Decode-regime MoE: tokens replicated over the EP axis.

    ``stop_stage`` — see :func:`_moe_dispatch`; the broadcast path has no
    a2a, so its prefix vocabulary skips ``dispatch``."""
    e_cfg = cfg.moe
    ep, e = comm.ep, e_cfg.num_experts
    n_slots = rep.slot_owner.shape[0]
    s_loc = n_slots // ep
    s_pol = n_slots // pol_ep
    t = x_t.shape[0]
    k = e_cfg.top_k

    with jax.named_scope("route"):
        gates, eidx, probs = _route(p["router"], x_t, e_cfg)
        flat_e = eidx.reshape(t * k)
        # every rank sees the full (replicated) token set, so the
        # round-robin counter is identical on all ranks: each assignment
        # has exactly one computing replica and the psum combine never
        # double-counts
        flat_p, secondary = _split_assignments(
            rep, flat_e, jnp.repeat(val_t.astype(bool), k))
        # valid-weighted: dummy decode rows (inactive slots) don't count
        w_val = jnp.repeat(val_t.astype(F32), k)
        w_vis = jnp.repeat((mod_t & val_t).astype(F32), k)
        counts = jnp.bincount(flat_e, weights=w_val, length=e)  # row totals
        vis = jnp.bincount(flat_e, weights=w_vis, length=e)
        slot_load = jnp.bincount(flat_p, weights=w_val, length=n_slots)
        slot_vis = jnp.bincount(flat_p, weights=w_vis, length=n_slots)
        load_d = slot_load.reshape(pol_ep, s_pol).sum(-1)
        vis_d = slot_vis.reshape(pol_ep, s_pol).sum(-1)
        split = jnp.sum(secondary.astype(F32) * w_val)
        dec = realb_policy(load_d, vis_d, m_vec, rcfg)
        if ep == pol_ep:
            use_fp4_me = dec.use_fp4[comm.my_rank]
        else:
            use_fp4_me = jnp.any(dec.use_fp4)
    if stop_stage == "route":
        return gates, flat_p, dec.m_new, load_d, use_fp4_me

    with jax.named_scope("weight_gather"):
        w = _gather_weights(p, comm)
    if stop_stage == "weight_gather":
        return gates, flat_p, dec.m_new, use_fp4_me, w

    with jax.named_scope("quantize_fp4"):
        wq = _quantize_experts(w, use_fp4_me, rcfg, None)
    if stop_stage == "quantize_fp4":
        return gates, flat_p, dec.m_new, use_fp4_me, wq

    with jax.named_scope("expert_gemm"):
        pidx = flat_p.reshape(t, k)                            # [t,K] placed
        sel = (pidx // s_loc) == comm.my_rank                  # [t,K]
        local_gate = jnp.where(sel, gates, 0.0)
        leid = pidx % s_loc

        def per_expert(x_all, wg, wu, wd):
            g = jnp.einsum("td,edf->etf", x_all, wg.astype(x_all.dtype))
            u = jnp.einsum("td,edf->etf", x_all, wu.astype(x_all.dtype))
            h = act(g.astype(F32)).astype(x_all.dtype) * u
            return jnp.einsum("etf,efd->etd", h, wd.astype(x_all.dtype))

        def bf16_branch(o):
            x_, w_, _ = o
            return per_expert(x_, w_["w_gate"], w_["w_up"], w_["w_down"])

        def fp4_branch(o):
            # same dynamic per-group a4 recipe as the grouped kernel, so
            # decode and prefill FP4 numerics agree across backends
            x_, _, wq_ = o
            xq = nvfp4.fake_quant_a4(x_, rcfg.group_size).astype(x_.dtype)
            wd = {n: _dq_t(q, x_.dtype) for n, q in wq_.items()}
            g = jnp.einsum("td,edf->etf", xq, wd["w_gate"])
            u = jnp.einsum("td,edf->etf", xq, wd["w_up"])
            h = act(g.astype(F32)).astype(x_.dtype) * u
            hq = nvfp4.fake_quant_a4(h, rcfg.group_size).astype(x_.dtype)
            return jnp.einsum("etf,efd->etd", hq, wd["w_down"])

        y_e = jax.lax.cond(use_fp4_me, fp4_branch, bf16_branch,
                           (x_t, w, wq))
    if stop_stage == "expert_gemm":
        return gates, dec.m_new, y_e, leid

    with jax.named_scope("combine"):
        onehot = jax.nn.one_hot(leid, s_loc, dtype=y_e.dtype)  # [t,K,s_loc]
        weight_e = jnp.einsum("tk,tke->te", local_gate.astype(y_e.dtype),
                              onehot)
        y_partial = jnp.einsum("te,etd->td", weight_e, y_e)
        out = comm.psum_model(y_partial)

    total = jnp.sum(load_d)
    aux = _aux_losses(probs, counts, total / max(k, 1), e_cfg, lambda v: v)
    aux.update(drop_frac=jnp.zeros(()), ib_global=dec.ib_global,
               fp4_ranks=jnp.sum(dec.use_fp4.astype(F32)),
               load_d=load_d, vis_d=vis_d,
               expert_load=counts, expert_vis=vis,
               slot_load=slot_load, slot_vis=slot_vis,
               split_frac=split / jnp.maximum(total, 1.0),
               gate_open=dec.gate_open.astype(F32))
    return out.astype(x_t.dtype), dec.m_new, aux


# --------------------------------------------------------------------------
# public entry: shard_map wrapper
# --------------------------------------------------------------------------
AUX_SCALARS = ("lb_loss", "z_loss", "drop_frac", "ib_global", "fp4_ranks",
               "gate_open", "split_frac")


def _manual_fn(x, mod, val, m_state, router, w_gate, w_up, w_down,
               *tables, cfg, rcfg, ep, mode, fsdp, train):
    comm = _dist_comm(ep, fsdp)
    b, s, d = x.shape
    x_t = x.reshape(b * s, d)
    mod_t = mod.reshape(b * s)
    val_t = val.reshape(b * s)
    # every device holds its own scalar M_d; gather the EP-group vector via
    # psum-of-onehot (provably replicated over 'model' for the VMA checker)
    m_vec = comm.psum_model(
        jax.nn.one_hot(comm.my_rank, ep, dtype=F32) * m_state.reshape(()))
    p = {"router": router, "w_gate": w_gate, "w_up": w_up, "w_down": w_down}
    act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
    rep = _rep_from_entries(tables)
    if mode == "broadcast":
        y, m_new, aux = _moe_broadcast(x_t, mod_t, val_t, p, m_vec, cfg,
                                       rcfg, comm, act, rep, ep)
    else:
        y, m_new, aux = _moe_dispatch(x_t, mod_t, val_t, p, m_vec, cfg,
                                      rcfg, comm, act, rep, ep, train)
    y = y.reshape(b, s, d)
    m_out = m_new[comm.my_rank].reshape(m_state.shape)
    aux_s = jnp.stack([aux[n] for n in AUX_SCALARS]).reshape(1, -1)
    stats = jnp.stack([aux["load_d"], aux["vis_d"]]).reshape(1, 2, ep)
    estats = jnp.stack([aux["expert_load"], aux["expert_vis"]]
                       ).reshape(1, 2, -1)
    sstats = jnp.stack([aux["slot_load"], aux["slot_vis"]]
                       ).reshape(1, 2, -1)
    return y, m_out, aux_s, stats, estats, sstats


def ep_moe_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                   rcfg: ReaLBConfig, m_state: jax.Array,
                   modality: Optional[jax.Array] = None,
                   mode: str = "dispatch", train: bool = False,
                   fsdp: bool = False,
                   valid: Optional[jax.Array] = None,
                   placement: Optional[Placement] = None,
                   stop_stage: Optional[str] = None):
    """MoE layer with ReaLB.  x [B,S,D]; m_state [groups, ep] (see
    :func:`moe_state_shape`); valid [B,S] marks real tokens (None = all) —
    padding still computes but is excluded from the routing stats the
    policy consumes.  ``placement`` maps logical experts onto EP ranks:
    None = the contiguous identity mapping (bitwise-identical to the
    pre-placement layer), a :class:`Placement`/2-tuple = a bijective
    permutation, a :class:`Replication`/3-tuple = redundant experts with
    round-robin token splitting.  The expert weight arrays in ``p`` must
    be stored in the matching *placed* physical-slot order (``[S, ...]``
    with ``S >= num_experts`` under replication).
    Returns (y, new_m_state, aux_dict).

    ``stop_stage`` (instrumented profiling, local path only): truncate
    after the named phase (``route`` / ``weight_gather`` /
    ``quantize_fp4`` / ``dispatch`` / ``expert_gemm``) and return that
    prefix's raw boundary values instead — see
    :func:`repro.obs.profiler.time_moe_phases`."""
    mesh = current_mesh()
    if modality is None:
        modality = jnp.zeros(x.shape[:2], jnp.bool_)
    if valid is None:
        valid = jnp.ones(x.shape[:2], jnp.bool_)

    local = (mesh is None or "model" not in mesh.axis_names or
             dict(zip(mesh.axis_names, mesh.devices.shape))["model"] == 1)
    if local:
        # the policy/statistics topology is the trailing m_state dim: [1]
        # physically, but a serving engine may provision a *virtual* EP
        # group (m_state [1, vep]) so IB_d / FP4 duty are non-trivial on
        # one device.
        pol_ep = int(m_state.shape[-1]) if m_state.ndim else 1
        assert cfg.moe.num_experts % pol_ep == 0, \
            (cfg.moe.num_experts, pol_ep)
        rep = _as_replication(placement, cfg.moe.num_experts, pol_ep)
        assert rep.slot_owner.shape[0] % pol_ep == 0, \
            (rep.slot_owner.shape[0], pol_ep)
        comm = _local_comm()
        b, s, d = x.shape
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        fn = partial(_moe_broadcast, stop_stage=stop_stage) \
            if mode == "broadcast" else partial(
                _moe_dispatch, train=train, stop_stage=stop_stage)
        out = fn(x.reshape(b * s, d), modality.reshape(b * s),
                 valid.reshape(b * s), p, m_state.reshape(-1),
                 cfg, rcfg, comm, act, rep, pol_ep)
        if stop_stage is not None:       # instrumented prefix: raw boundary
            return out
        y, m_new, aux = out
        return (y.reshape(b, s, d), m_new.reshape(m_state.shape), aux)

    if stop_stage is not None:
        raise NotImplementedError(
            "stop_stage instrumentation is local-path only; profile real "
            "meshes with serve_bench --xprof-out (jax.profiler capture)")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes["model"]
    row_axes = tuple(a for a in mesh.axis_names if a != "model")
    row_entry = row_axes if len(row_axes) > 1 else row_axes[0]
    single_group = m_state.shape[0] == 1
    rep = _as_replication(placement, cfg.moe.num_experts, ep)
    assert rep.slot_owner.shape[0] % ep == 0, \
        (rep.slot_owner.shape[0], ep)

    x_axes = ("batch", "seq", None) if mode == "dispatch" \
        else ("batch", None, None)
    x_spec = resolve_spec(x.shape, x_axes, mesh)
    mod_spec = PartitionSpec(*x_spec[:2])
    m_spec = PartitionSpec(None if single_group else row_entry, "model")
    r_spec = PartitionSpec(None, None)
    t_spec = PartitionSpec(None)        # replicated [E]/[S] tables
    t2_spec = PartitionSpec(None, None)  # replicated [E, R] replica matrix
    wg_spec = resolve_spec(p["w_gate"].shape,
                           ("expert", "embed" if fsdp else None, None), mesh)
    wd_spec = resolve_spec(p["w_down"].shape,
                           ("expert", None, "embed" if fsdp else None), mesh)
    aux_spec = PartitionSpec(None if single_group else row_entry, None)
    stats_spec = PartitionSpec(None if single_group else row_entry,
                               None, None)

    fn = partial(_manual_fn, cfg=cfg, rcfg=rcfg, ep=ep, mode=mode,
                 fsdp=fsdp, train=train)
    table_args = (rep.rep_pos, rep.n_rep, rep.slot_owner)
    table_specs = (t2_spec, t_spec, t_spec)
    sched = getattr(rep, "split_sched", None)
    if sched is not None:                # replicated [E, Q] split schedule
        table_args += (sched,)
        table_specs += (t2_spec,)
    # check_rep=False: pallas_call (the FP4 quantize / grouped-FFN
    # kernels) has no replication rule; the out_specs above already state
    # the sharding we require, so only the static replication lint is lost
    y, m_new, aux_s, stats, estats, sstats = shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, mod_spec, mod_spec, m_spec, r_spec, wg_spec,
                  wg_spec, wd_spec) + table_specs,
        out_specs=(x_spec, m_spec, aux_spec, stats_spec, stats_spec,
                   stats_spec), check_rep=False,
    )(x, modality, valid, m_state, p["router"], p["w_gate"], p["w_up"],
      p["w_down"], *table_args)

    aux_mean = aux_s.mean(0)
    aux = {n: aux_mean[i] for i, n in enumerate(AUX_SCALARS)}
    aux["load_d"] = stats[:, 0, :]
    aux["vis_d"] = stats[:, 1, :]
    aux["expert_load"] = estats[:, 0, :].sum(0)
    aux["expert_vis"] = estats[:, 1, :].sum(0)
    aux["slot_load"] = sstats[:, 0, :].sum(0)
    aux["slot_vis"] = sstats[:, 1, :].sum(0)
    return y, m_new, aux


def moe_state_shape(mesh, global_batch: int,
                    virtual_ep: Optional[int] = None) -> Tuple[int, int]:
    """AIMD M-state shape [n_groups, ep] for a given mesh & batch.

    ``virtual_ep`` provisions the policy statistics over a virtual EP
    topology when there is no mesh (single-device serving simulations)."""
    if mesh is None:
        return (1, int(virtual_ep) if virtual_ep else 1)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes.get("model", 1)
    rows = 1
    for a in mesh.axis_names:
        if a != "model":
            rows *= sizes[a]
    if global_batch % max(rows, 1) != 0:
        rows = 1  # batch not shardable over rows -> single replicated group
    return (rows, ep)
