"""ReaLB control policy (paper §4.2): hotspot detection + modality
threshold + AIMD adaptation + LB gate.

Everything is expressed as pure jnp on per-EP-rank vectors so the policy
runs *inside* the traced MoE layer (zero host round-trips — the "real-time,
zero scheduling overhead" property).  The same functions drive the
benchmark simulator on host numpy arrays.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ReaLBConfig


class PolicyDecision(NamedTuple):
    use_fp4: jax.Array     # bool [R] — rank executes its experts in FP4
    hotspots: jax.Array    # bool [R]
    ib_d: jax.Array        # f32 [R] per-rank imbalance Load_d / Ideal
    ib_global: jax.Array   # f32 scalar max_d IB_d
    r_v: jax.Array         # f32 [R] vision token ratio per rank
    gate_open: jax.Array   # bool scalar — LB gate (Γ)
    m_new: jax.Array       # f32 [R] updated AIMD thresholds


def lb_gate(total_tokens: jax.Array, cfg: ReaLBConfig) -> jax.Array:
    """Γ gate: activate only in the GEMM-dominated (large-batch) regime."""
    return total_tokens > cfg.gate_gamma


def realb_policy(load_d: jax.Array, vis_d: jax.Array, m_d: jax.Array,
                 cfg: ReaLBConfig) -> PolicyDecision:
    """One synchronous control step for an EP group.

    load_d: f32 [R] tokens routed to each rank's experts this layer.
    vis_d:  f32 [R] vision tokens among them.
    m_d:    f32 [R] current AIMD modality thresholds.
    """
    load_d = load_d.astype(jnp.float32)
    total = jnp.sum(load_d)
    ideal = total / load_d.shape[0]
    ib_d = load_d / jnp.maximum(ideal, 1.0)
    ib_global = jnp.max(ib_d)
    hot = ib_d > cfg.capacity_c
    r_v = vis_d.astype(jnp.float32) / jnp.maximum(load_d, 1.0)
    gate = lb_gate(total, cfg)

    compress = hot & (r_v > m_d) & gate & cfg.enabled

    if cfg.adaptive:
        m_up = jnp.minimum(1.0, m_d + cfg.md_add)
        m_down = jnp.maximum(cfg.md_min, m_d * cfg.md_mult)
        m_new = jnp.where(ib_global > cfg.tau, m_down, m_up)
        # only adapt while the balancer is live (gate open); else hold.
        m_new = jnp.where(gate, m_new, m_d)
    else:
        m_new = m_d

    return PolicyDecision(compress, hot, ib_d, ib_global, r_v, gate, m_new)


def init_m_state(n_groups: int, ep: int, cfg: ReaLBConfig) -> jax.Array:
    """AIMD state M_d: one threshold per (EP group row, EP rank)."""
    return jnp.full((n_groups, ep), cfg.md_init, jnp.float32)
