"""NVFP4 quantization recipe (paper Appendix E), pure-jnp reference.

Weights & activations in FP4 E2M1 ({0,±0.5,±1,±1.5,±2,±3,±4,±6}), symmetric
min-max per group of 16 along the contraction dim; local scale = amax/6
stored in FP8 E4M3; one global FP32 scale per tensor aligns magnitudes so
local scales fit E4M3 range.  These functions are the numerical oracle for
the Pallas kernels in ``repro/kernels`` and the accuracy-measurement path
of the benchmarks (the simulated dequantized values are bit-identical to
what an NVFP4 GEMM consumes).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import nvfp4

# Explicit level table kept for tests/inspection; the rounding math is
# single-sourced in repro.kernels.nvfp4 (compare-select, bitwise identical
# to a table gather) so the Pallas kernels and this oracle cannot drift.
FP4_LEVELS = jnp.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], jnp.float32)
# decision boundaries between consecutive levels (round-to-nearest)
FP4_MIDPOINTS = jnp.array(nvfp4.FP4_MIDPOINTS, jnp.float32)
FP4_MAX = nvfp4.FP4_MAX
INV_FP4_MAX = nvfp4.INV_FP4_MAX
E4M3_MAX = nvfp4.E4M3_MAX
GROUP = nvfp4.GROUP

fp4_round = nvfp4.fp4_round
fp4_code = nvfp4.fp4_code
fp4_decode = nvfp4.decode_level
e4m3_round = nvfp4.e4m3_round


def pack_u4(codes: jax.Array) -> jax.Array:
    """Pack uint8 4-bit codes pairwise along the last dim -> uint8 [... , K/2]."""
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_u4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_u4` -> uint8 [..., K]."""
    lo = (packed & 0x0F).astype(jnp.uint8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.uint8)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                                packed.shape[-1] * 2)


class QTensor(NamedTuple):
    """Group-quantized NVFP4 tensor (packed along the last axis)."""

    packed: jax.Array        # uint8 [..., K/2]
    scales: jax.Array        # f32 (e4m3-valued) [..., K/GROUP]
    global_scale: jax.Array  # f32 scalar

    @property
    def k(self) -> int:
        return self.packed.shape[-1] * 2


def global_scale_for(w: jax.Array) -> jax.Array:
    """Per-tensor scale aligning group amaxes into E4M3 range (precomputed
    at PTQ calibration time in the paper; an input to the runtime kernel)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    return jnp.maximum(amax / (FP4_MAX * E4M3_MAX), 1e-20).astype(jnp.float32)


def quantize_fp4(w: jax.Array, group: int = GROUP,
                 global_scale: jax.Array | None = None) -> QTensor:
    """NVFP4 group quantization along the last axis (must divide by group)."""
    *lead, k = w.shape
    assert k % group == 0, (k, group)
    wf = w.astype(jnp.float32).reshape(*lead, k // group, group)
    amax = jnp.max(jnp.abs(wf), axis=-1)                      # [..., K/g]
    gscale = global_scale_for(w) if global_scale is None \
        else jnp.asarray(global_scale, jnp.float32)
    # multiply by the f32 reciprocal (not /6.0): keeps the expression
    # bit-identical between the jitted oracle and the Pallas kernel (XLA
    # rewrites constant divisions to reciprocal multiplies)
    s_local = e4m3_round(amax * INV_FP4_MAX / gscale)
    s_local = jnp.maximum(s_local, 2.0 ** -9)                 # avoid /0
    codes = fp4_code(wf / (s_local * gscale)[..., None])
    packed = pack_u4(codes.reshape(*lead, k))
    return QTensor(packed, s_local, gscale.astype(jnp.float32))


def dequantize_fp4(q: QTensor, dtype=jnp.float32) -> jax.Array:
    vals = fp4_decode(unpack_u4(q.packed))                    # [..., K]
    *lead, k = vals.shape
    g = k // q.scales.shape[-1]
    vals = vals.reshape(*lead, k // g, g) * q.scales[..., None] * q.global_scale
    return vals.reshape(*lead, k).astype(dtype)


def fp4_sim(x: jax.Array, group: int = GROUP) -> jax.Array:
    """Fake-quantize (quantize+dequantize) along the last axis, same dtype.

    Gradient-transparent (straight-through) so it can sit in train graphs.
    """
    q = quantize_fp4(jax.lax.stop_gradient(x), group)
    dq = dequantize_fp4(q, jnp.float32)
    xf = x.astype(jnp.float32)
    return (xf + jax.lax.stop_gradient(dq - xf)).astype(x.dtype)


def quant_error(w: jax.Array, group: int = GROUP) -> jax.Array:
    """Relative Frobenius error of the NVFP4 round-trip (accuracy proxy)."""
    wf = w.astype(jnp.float32)
    dq = dequantize_fp4(quantize_fp4(wf, group))
    return jnp.linalg.norm(dq - wf) / jnp.maximum(jnp.linalg.norm(wf), 1e-20)


# --------------------------------------------------------------------------
# quantized matmul references (the numerics the kernels must match)
# --------------------------------------------------------------------------
def matmul_w4a16(x: jax.Array, qw: QTensor) -> jax.Array:
    """x [M,K] @ dequant(qw) [K,N] with qw quantized along K (stored [N,K])."""
    w = dequantize_fp4(qw, jnp.float32)                       # [N,K]
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def matmul_w4a4(x: jax.Array, qw: QTensor, group: int = GROUP) -> jax.Array:
    """NVFP4 W4A4 GEMM simulation: both operands fake-quantized per group-K."""
    xq = fp4_sim(x.astype(jnp.float32), group)
    w = dequantize_fp4(qw, jnp.float32)
    return (xq @ w.T).astype(x.dtype)
