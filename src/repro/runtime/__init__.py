"""runtime subpackage."""
