"""Fault-tolerant training loop: checkpoint/restart, NaN guards, SIGTERM.

Designed for 1000+-node operation:

* periodic **async** checkpoints (snapshot on device→host, write off the
  critical path),
* **NaN/Inf guard** — a non-finite loss skips the update (the step fn
  already applied it, so we roll back by restoring the pre-step snapshot
  after ``nan_tolerance`` consecutive bad steps),
* **SIGTERM/SIGINT-safe** final save (preemption-friendly),
* byte-exact **restart**: the data pipeline is a pure function of step, so
  restore(step) resumes the identical stream; ReaLB's AIMD state is part
  of the checkpoint.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib

Tree = Any


class TrainLoop:
    def __init__(self, step_fn: Callable, *, ckpt_dir: str,
                 checkpoint_every: int = 100, keep: int = 3,
                 nan_tolerance: int = 3, log_every: int = 10,
                 logger: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = checkpoint_every
        self.nan_tolerance = nan_tolerance
        self.log_every = log_every
        self.log = logger
        self.checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep)
        self._stop = False

    def _install_signals(self):
        def handler(signum, frame):
            self.log(f"[ft] signal {signum}: finishing step then saving")
            self._stop = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread (tests)

    def restore_or_init(self, state: Dict[str, Tree]
                        ) -> tuple[int, Dict[str, Tree]]:
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return 0, state
        step, restored = ckpt_lib.restore(self.ckpt_dir, state)
        self.log(f"[ft] restored checkpoint at step {step}")
        return step, restored

    def run(self, state: Dict[str, Tree], data_iter, total_steps: int,
            start_step: int = 0) -> Dict[str, Tree]:
        self._install_signals()
        bad_streak = 0
        step = start_step
        t0 = time.time()
        while step < total_steps and not self._stop:
            batch = next(data_iter)
            new_state, metrics = self.step_fn(state, batch)
            loss = float(metrics.get("loss", np.nan))
            if not np.isfinite(loss):
                bad_streak += 1
                self.log(f"[ft] step {step}: non-finite loss "
                         f"({bad_streak}/{self.nan_tolerance}) — "
                         "update skipped")
                if bad_streak >= self.nan_tolerance:
                    self.checkpointer.wait()
                    last = ckpt_lib.latest_step(self.ckpt_dir)
                    if last is not None:
                        _, state = ckpt_lib.restore(self.ckpt_dir, state)
                        self.log(f"[ft] rolled back to step {last}")
                        step = last
                    bad_streak = 0
                # drop new_state (the poisoned update)
            else:
                bad_streak = 0
                state = new_state
                step += 1
                if step % self.log_every == 0:
                    dt = (time.time() - t0) / max(self.log_every, 1)
                    t0 = time.time()
                    self.log(f"[ft] step {step}: loss={loss:.4f} "
                             f"({dt*1e3:.0f} ms/step)")
                if step % self.checkpoint_every == 0:
                    self.checkpointer.save(step, state)
        self.checkpointer.wait()
        ckpt_lib.save(self.ckpt_dir, step, state)
        self.log(f"[ft] final checkpoint at step {step}")
        return state
