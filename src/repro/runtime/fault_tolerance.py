"""Fault-tolerant training loop: checkpoint/restart, NaN guards, SIGTERM.

Designed for 1000+-node operation:

* periodic **async** checkpoints (snapshot on device→host, write off the
  critical path),
* **NaN/Inf guard** — a non-finite loss skips the update (the step fn
  already applied it, so we roll back by restoring the pre-step snapshot
  after ``nan_tolerance`` consecutive bad steps),
* **SIGTERM/SIGINT-safe** final save (preemption-friendly),
* byte-exact **restart**: the data pipeline is a pure function of step, so
  restore(step) resumes the identical stream; ReaLB's AIMD state is part
  of the checkpoint.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib

Tree = Any


class FaultEvent:
    """One scripted rank fault: ``kind`` is 'fail' or 'rejoin'."""

    __slots__ = ("it", "kind", "rank")

    def __init__(self, it: int, kind: str, rank: int):
        assert kind in ("fail", "rejoin"), kind
        self.it, self.kind, self.rank = int(it), kind, int(rank)

    def __repr__(self):
        return f"FaultEvent(it={self.it}, kind={self.kind!r}, " \
               f"rank={self.rank})"


class FaultInjector:
    """Deterministic scripted rank-fault schedule for serving.

    The engine polls :meth:`due` once per iteration and dispatches the
    returned events to its elastic coordinator (``fail_rank`` /
    ``rejoin_rank``) — the serving twin of this module's training-side
    fault tolerance, and the first wiring of ``runtime`` into the
    serving event loop.  Events are (iteration, kind, rank) triples,
    e.g. ``FaultInjector([(40, "fail", 2), (90, "rejoin", 2)])``.
    """

    def __init__(self, events):
        evs = [e if isinstance(e, FaultEvent) else FaultEvent(*e)
               for e in events]
        self.events = sorted(evs, key=lambda e: e.it)
        self._i = 0

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self.events)

    def due(self, it: int):
        """Events scheduled at or before ``it`` that have not fired yet
        (each event fires exactly once, in schedule order)."""
        out = []
        while self._i < len(self.events) and self.events[self._i].it <= it:
            out.append(self.events[self._i])
            self._i += 1
        return out


class TrainLoop:
    def __init__(self, step_fn: Callable, *, ckpt_dir: str,
                 checkpoint_every: int = 100, keep: int = 3,
                 nan_tolerance: int = 3, log_every: int = 10,
                 logger: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = checkpoint_every
        self.nan_tolerance = nan_tolerance
        self.log_every = log_every
        self.log = logger
        self.checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep)
        self._stop = False

    def _install_signals(self):
        def handler(signum, frame):
            self.log(f"[ft] signal {signum}: finishing step then saving")
            self._stop = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread (tests)

    def restore_or_init(self, state: Dict[str, Tree]
                        ) -> tuple[int, Dict[str, Tree]]:
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return 0, state
        step, restored = ckpt_lib.restore(self.ckpt_dir, state)
        self.log(f"[ft] restored checkpoint at step {step}")
        return step, restored

    def run(self, state: Dict[str, Tree], data_iter, total_steps: int,
            start_step: int = 0) -> Dict[str, Tree]:
        self._install_signals()
        bad_streak = 0
        step = start_step
        t0 = time.perf_counter()
        while step < total_steps and not self._stop:
            batch = next(data_iter)
            new_state, metrics = self.step_fn(state, batch)
            loss = float(metrics.get("loss", np.nan))
            if not np.isfinite(loss):
                bad_streak += 1
                self.log(f"[ft] step {step}: non-finite loss "
                         f"({bad_streak}/{self.nan_tolerance}) — "
                         "update skipped")
                if bad_streak >= self.nan_tolerance:
                    self.checkpointer.wait()
                    last = ckpt_lib.latest_step(self.ckpt_dir)
                    if last is not None:
                        _, state = ckpt_lib.restore(self.ckpt_dir, state)
                        self.log(f"[ft] rolled back to step {last}")
                        step = last
                    bad_streak = 0
                # drop new_state (the poisoned update)
            else:
                bad_streak = 0
                state = new_state
                step += 1
                if step % self.log_every == 0:
                    dt = (time.perf_counter() - t0) / max(self.log_every, 1)
                    t0 = time.perf_counter()
                    self.log(f"[ft] step {step}: loss={loss:.4f} "
                             f"({dt*1e3:.0f} ms/step)")
                if step % self.checkpoint_every == 0:
                    self.checkpointer.save(step, state)
        self.checkpointer.wait()
        ckpt_lib.save(self.ckpt_dir, step, state)
        self.log(f"[ft] final checkpoint at step {step}")
        return state
