"""Elastic scaling: move a checkpoint between meshes of different shape.

When a pod (or any data-parallel slice) is lost, training resumes on a
smaller mesh: parameters keep their logical axes, so resharding is just
re-resolving logical→mesh specs on the new mesh and ``device_put``-ing the
host checkpoint through the new shardings.  EP degree changes re-bucket
experts automatically because the expert dimension is a logical axis like
any other.  The reverse (scale-up) works identically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

from repro.models.common import resolve_spec, use_mesh
from jax.sharding import NamedSharding

Tree = Any


def reshard(tree: Tree, spec_tree: Tree, new_mesh) -> Tree:
    """Re-distribute `tree` onto `new_mesh` using the P-spec tree (the same
    declaration used at init — single source of truth for layouts)."""
    from repro.models.common import P

    def mk(p, leaf):
        spec = resolve_spec(leaf.shape if hasattr(leaf, "shape") else p.shape,
                            _axes_for(p, leaf), new_mesh)
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    def _axes_for(p, leaf):
        axes = tuple(p.axes)
        extra = len(leaf.shape) - len(axes)
        return (("layers",) * extra) + axes   # stacked scan dims lead

    return jax.tree.map(mk, spec_tree, tree,
                        is_leaf=lambda x: isinstance(x, P))


def shrink_mesh(mesh, lost_axis: str = "pod",
                lost_index: Optional[int] = None):
    """Mesh minus one slice of `lost_axis` (node-failure simulation).

    ``lost_index`` selects WHICH slice is lost (default: the last) — the
    serving-side elastic coordinator shrinks the specific EP rank that
    failed, not necessarily the tail one."""
    names = list(mesh.axis_names)
    shape = list(mesh.devices.shape)
    i = names.index(lost_axis)
    if shape[i] <= 1:
        raise ValueError(f"cannot shrink axis {lost_axis} below 1")
    lost = shape[i] - 1 if lost_index is None else int(lost_index)
    if not 0 <= lost < shape[i]:
        raise ValueError(f"lost_index {lost} out of [0, {shape[i]})")
    keep = mesh.devices.take([j for j in range(shape[i]) if j != lost],
                             axis=i)
    from jax.sharding import Mesh
    return Mesh(keep, axis_names=tuple(names))
