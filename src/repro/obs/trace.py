"""Low-overhead span tracer with Chrome-trace (Perfetto) JSON export.

The serving stack answers "where inside the iteration did the time go"
with *nested spans*: the engine opens one ``iter`` span per serving
iteration and nests admission / chunk-forward / decode / migration-drain
/ table-commit spans inside it; the managers wrap their planning, the
:class:`~repro.serving.async_migrate.MigrationExecutor` wraps each chunk
batch, the :class:`~repro.serving.elastic.ElasticCoordinator` stamps its
events as instants.  Spans read the *engine clock* — under the virtual
clock of a seeded benchmark run the whole trace is deterministic and
CI-diffable; under wall clocks it is an honest profile.

Zero-cost when disabled: :data:`NULL_TRACER` is a shared singleton whose
``span``/``instant``/``complete`` are no-ops returning one cached null
span — no dict allocation, no clock read, nothing recorded — so an
engine built without a tracer is bitwise identical to one predating the
obs layer.  Hot loops guard annotation work with ``tracer.enabled``.

Export follows the Chrome Trace Event format (the JSON Perfetto and
``chrome://tracing`` load): ``X`` complete events with microsecond
``ts``/``dur``, ``i`` instants, one process/thread.  Extra run metadata
rides in the top-level ``metadata`` object (ignored by viewers, read by
``benchmarks/trace_report.py`` for stall-vs-hidden reconciliation).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional


class Span:
    """One open span; annotate with :meth:`set`, close via ``with``."""
    __slots__ = ("_tracer", "name", "cat", "t0", "args")

    def __init__(self, tracer: "Tracer", name: str, cat: str):
        self._tracer = tracer
        self.name, self.cat = name, cat
        self.t0 = 0.0
        self.args: Optional[Dict[str, Any]] = None

    def set(self, **kw) -> "Span":
        """Attach args shown in the trace viewer (numbers/strings)."""
        if self.args is None:
            self.args = kw
        else:
            self.args.update(kw)
        return self

    def __enter__(self) -> "Span":
        self.t0 = self._tracer.clock()
        self._tracer._depth += 1
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        tr._depth -= 1
        tr._events.append(("X", self.name, self.cat, self.t0,
                           tr.clock() - self.t0, self.args))
        return False


class _NullSpan:
    """The shared do-nothing span of :class:`NullTracer`."""
    __slots__ = ()

    def set(self, **kw) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every call is a no-op on shared singletons.

    ``enabled`` is the hot-loop guard — code computing span annotations
    checks it first so a disabled tracer costs one attribute read."""
    enabled = False

    def span(self, name: str, cat: str = "serving") -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "serving",
                args: Optional[Dict[str, Any]] = None) -> None:
        pass

    def complete(self, name: str, t0: float, dur: float,
                 cat: str = "serving",
                 args: Optional[Dict[str, Any]] = None) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Records spans/instants against ``clock`` (seconds; the engine's
    virtual clock for deterministic traces, ``time.perf_counter`` for
    wall profiles)."""
    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock if clock is not None else time.perf_counter
        # (ph, name, cat, t0_s, dur_s, args) tuples; instants carry
        # dur_s=0.  Append-only in program order => deterministic.
        self._events: List[tuple] = []
        self._depth = 0

    def span(self, name: str, cat: str = "serving") -> Span:
        return Span(self, name, cat)

    def instant(self, name: str, cat: str = "serving",
                args: Optional[Dict[str, Any]] = None) -> None:
        self._events.append(("i", name, cat, self.clock(), 0.0, args))

    def complete(self, name: str, t0: float, dur: float,
                 cat: str = "serving",
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Append an already-measured span (e.g. a migration drain whose
        duration is the stall+hidden attribution, not two clock reads)."""
        self._events.append(("X", name, cat, float(t0), float(dur), args))

    def __len__(self) -> int:
        return len(self._events)

    # -- export ------------------------------------------------------------
    def to_chrome(self, metadata: Optional[Dict[str, Any]] = None) -> Dict:
        """The Chrome Trace Event JSON object (Perfetto-loadable)."""
        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "repro.serving"}},
            {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
             "args": {"name": "engine"}},
        ]
        for ph, name, cat, t0, dur, args in self._events:
            ev: Dict[str, Any] = {"ph": ph, "pid": 0, "tid": 0,
                                  "name": name, "cat": cat,
                                  "ts": t0 * 1e6}
            if ph == "X":
                ev["dur"] = max(dur, 0.0) * 1e6
            else:                              # instant: thread scope
                ev["s"] = "t"
            if args:
                ev["args"] = args
            events.append(ev)
        out: Dict[str, Any] = {"traceEvents": events,
                               "displayTimeUnit": "ms"}
        if metadata:
            out["metadata"] = metadata
        return out

    def write(self, path: str,
              metadata: Optional[Dict[str, Any]] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(metadata), f, indent=1, default=float)
        return path


def validate_chrome_trace(obj: Dict) -> List[Dict]:
    """Schema-check a Chrome-trace object; returns its event list.

    Raises ``ValueError`` on structural problems — the CI trace artifact
    must stay loadable by Perfetto across refactors."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' is not a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E", "C"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if "name" not in ev:
            raise ValueError(f"event {i}: missing 'name'")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                raise ValueError(f"event {i}: missing numeric 'ts'")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: 'X' needs dur >= 0, "
                                 f"got {dur!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: 'args' is not an object")
    return events


def load_trace(path: str) -> Dict:
    """Load + validate a trace file written by :meth:`Tracer.write`."""
    with open(path) as f:
        obj = json.load(f)
    validate_chrome_trace(obj)
    return obj
