"""FLOP/byte ledger — exact per-iteration accounting of the MoE hot loop.

The ledger turns the *realized* routing statistics already threaded
through the layer scan (``aux["moe_stats"]``: per-layer per-rank routed
assignment counts, plus the ``fp4_ranks`` policy scalar) into exact
arithmetic/byte counts and analytic per-phase seconds:

- **flops** per phase: router GEMM (``route``), grouped expert GEMM
  (``expert_gemm``, counted at the precision each rank actually ran —
  BF16 vs FP4-at-the-int8-MXU-rate), and the dense remainder of the
  model (``other``: attention, dense FFN, embeddings, norms).
- **HBM bytes** per phase: expert weight streaming (4.25-bit FP4 packs
  vs 2-byte BF16), activation traffic, the BF16→FP4 transformation's
  read+write traffic on compressed ranks, and dense weight streaming.
- **ICI bytes**: the dispatch and combine all-to-alls over the (virtual)
  EP group.
- **predicted seconds** per phase, mirroring ``benchmarks/costmodel.py``
  formula-for-formula (``expert_gemm_time`` / ``quantize_time`` /
  ``dispatch_time`` / ``nongemm_time``) from the same single-sourced
  hardware constants (:mod:`repro.configs.hw`), so the profiler's
  drift detector compares measured time against exactly the model the
  replan cost gates price migrations with.  The ledger re-implements
  rather than imports them because ``src/repro`` cannot depend on
  ``benchmarks/``; ``tests/test_profiler.py`` pins the numeric match.

Approximation (documented, deliberate): the policy aux exposes how
*many* ranks ran FP4 per layer, not which — the ledger attributes FP4 to
the most-loaded ranks of each layer, faithful to ReaLB's
compress-the-hot-ranks policy.

``model_flops`` (the MFU numerator) is the standard useful-work count
``2 · active_param_count · routed_tokens`` — padding computed by the
hardware does not earn utilization.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.hw import HBM_BW, PEAK_BF16, PEAK_INT8

# mirrored from benchmarks/costmodel.py (pinned equal by test_profiler)
FIXED_US = 12.0               # dispatch/kernel fixed overhead per stage
BYTES_BF16 = 2.0
BYTES_FP4 = 0.53125           # 4 bits + e4m3 scale per 16-group = 4.25 b

#: phase vocabulary — matches the ``jax.named_scope`` annotations in
#: ``core/ep_moe.py`` plus the non-MoE remainder of the forward.
PHASES = ("route", "weight_gather", "quantize_fp4", "dispatch",
          "expert_gemm", "combine", "other")


def _zero_phases() -> Dict[str, float]:
    return {ph: 0.0 for ph in PHASES}


@dataclasses.dataclass
class IterLedger:
    """One iteration's accounting: flops / bytes / predicted seconds."""
    tokens: float                       # routed (non-pad) tokens
    batch_tokens: float                 # padded batch size the step ran at
    flops: Dict[str, float]             # per phase
    flops_by_rate: Dict[str, float]     # {"bf16": ..., "int8": ...} GEMM split
    hbm_bytes: Dict[str, float]         # per phase
    ici_bytes: Dict[str, float]         # per phase (dispatch/combine only)
    pred_s: Dict[str, float]            # analytic per-phase seconds
    model_flops: float                  # MFU numerator

    @property
    def flops_total(self) -> float:
        return sum(self.flops.values())

    @property
    def hbm_total(self) -> float:
        return sum(self.hbm_bytes.values())

    @property
    def ici_total(self) -> float:
        return sum(self.ici_bytes.values())

    @property
    def pred_total(self) -> float:
        return sum(self.pred_s.values())


class FlopByteLedger:
    """Per-iteration FLOP/byte accounting for one model config.

    ``ep`` is the *policy* EP width (the virtual group dispatch packs
    for), matching the geometry the cost gates price — on the virtual
    single-process bench that is ``vep``, on a real mesh the EP axis
    size.
    """

    def __init__(self, cfg, ep: int, fused: bool = False):
        if cfg.moe is None:
            raise ValueError("FlopByteLedger needs an MoE config")
        self.cfg = cfg
        self.ep = int(ep)
        # fused=True: the hot loop runs the fused Pallas grouped FP4 FFN +
        # quantize kernels (kernels.ops.ffn_fused()) — FP4 weights stream
        # packed (no BF16 dequant HBM round-trip) and the transformation
        # issues inside the dispatch window, so only its excess over
        # dispatch is wall-visible (paper §4.3).  fused=False: the jnp
        # fallback — dequantized BF16 slab round-trips HBM and the
        # transformation is a fully-visible stage.
        self.fused = bool(fused)
        self.d = int(cfg.d_model)
        self.d_ff = int(cfg.moe.d_ff)
        self.n_experts = int(cfg.moe.num_experts)
        self.top_k = int(cfg.moe.top_k)
        self.e_loc = max(self.n_experts // self.ep, 1)
        self.mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
        self.n_moe = sum(1 for k in cfg.ffn_kinds() if k == "moe")
        self.active_params = float(cfg.active_param_count())
        # params outside the routed-expert GEMMs and the router: the
        # "other" phase streams these (attention, dense FFN, shared
        # experts, embeddings, norms)
        moe_routed = self.n_moe * self.top_k * self.mult * self.d * self.d_ff
        router = self.n_moe * self.d * self.n_experts
        self.other_params = max(self.active_params - moe_routed - router, 0.0)

    # -- costmodel mirrors (same formulas, same hw constants) ------------
    def _expert_gemm_s(self, tokens_r: float, fp4: bool) -> float:
        flops = tokens_r * 2.0 * self.mult * self.d * self.d_ff
        w_raw = self.e_loc * self.mult * self.d * self.d_ff
        w_bytes = w_raw * (BYTES_FP4 if fp4 else BYTES_BF16)
        if fp4 and not self.fused:
            w_bytes += w_raw * 2.0 * BYTES_BF16  # dequant round-trip
        act_bytes = tokens_r * self.d * BYTES_BF16 * 4.0
        rate = PEAK_INT8 if fp4 else PEAK_BF16
        return max(flops / rate, (w_bytes + act_bytes) / HBM_BW)

    def _quantize_s(self) -> float:
        w = self.e_loc * self.mult * self.d * self.d_ff
        return (w * BYTES_BF16 + w * BYTES_FP4) / HBM_BW

    def _quantize_visible_s(self, dispatch_s: float) -> float:
        # mirrors costmodel.quantize_visible_time: fused T hides inside
        # the dispatch window (only the excess peeks out); unfused T is a
        # standalone stage — visible bytes + per-stage launch overhead
        q = self._quantize_s()
        if self.fused:
            return max(0.0, q - dispatch_s)
        return q + FIXED_US * 1e-6

    def _dispatch_s(self, tokens_total: float, ici_bw: float) -> float:
        per_rank = (tokens_total / self.ep * (self.ep - 1) / self.ep
                    * self.d * BYTES_BF16)
        return per_rank / ici_bw + FIXED_US * 1e-6

    def _nongemm_s(self, tokens_r: float) -> float:
        return (tokens_r * self.d * 6.0) / HBM_BW + 3 * FIXED_US * 1e-6

    # --------------------------------------------------------------------
    def predict_graph_census(self, t_local: int, layers: int,
                             itemsize: int = 2,
                             n_slots: Optional[int] = None
                             ) -> Dict[str, Dict[str, int]]:
        """Predicted *graph-level* collective census for the mesh
        (shard_map) dispatch path — the third leg of the jaxpr ↔ HLO ↔
        ledger reconciliation (``repro.analysis``).

        Unlike :meth:`account` (realized routed bytes), this predicts
        what the traced graph materially moves: the all-to-alls carry
        the full capacity buffer ``[ep, cap, d]`` regardless of how many
        slots are real, so census bytes upper-bound the ledger's routed
        ``ici_bytes``.  Per layer, ``core/ep_moe.py``'s dispatch path
        emits exactly 3 all_to_alls (x send, expert-id send, combine
        return) and 9 psums (counts/visitation globals, slot load/vis,
        split+dropped scalars, p_mean, z, the fp4 one-hot m_vec).

        ``t_local``: per-device token count entering the MoE layer;
        ``itemsize``: activation dtype bytes (2 = bf16); ``n_slots``:
        replication slot count (defaults to n_experts — no replicas).
        """
        import math
        ep = self.ep
        cap_raw = math.ceil(t_local * self.top_k / ep
                            * float(self.cfg.moe.capacity_factor))
        cap = max(8, -(-cap_raw // 8) * 8)   # mirrors ep_moe.py capacity
        s = int(n_slots) if n_slots is not None else self.n_experts
        a2a_bytes = (2 * ep * cap * self.d * itemsize   # x out + combine
                     + ep * cap * 4)                    # eid_send (int32)
        psum_elems = (ep            # m_vec one-hot [ep]
                      + 3 * self.n_experts  # counts, vis, p_mean [E]
                      + 2 * s               # slot_load, slot_vis [S]
                      + 3)                  # split, dropped, z scalars
        return {
            "all_to_all": {"count": 3 * layers,
                           "bytes": a2a_bytes * layers},
            "psum": {"count": 9 * layers,
                     "bytes": 4 * psum_elems * layers},
        }

    def rank_loads(self, moe_stats) -> np.ndarray:
        """``[L, ep]`` realized per-layer per-rank assignment counts from
        the scan's ``aux["moe_stats"]`` (``[L, 2, groups, ep]`` or
        ``[L, 2, ep]``); the groups axis is averaged (rows are replicas
        of the same loads in local mode)."""
        ms = np.asarray(moe_stats, dtype=np.float64)
        load = ms[:, 0] if ms.ndim >= 3 else ms[None, 0]
        if load.ndim == 3:                      # [L, groups, ep]
            load = load.mean(axis=1)
        return load.reshape(load.shape[0], -1)[:, -self.ep:]

    def account(self, moe_stats, fp4_layers: float, tokens: float,
                batch_tokens: float, ici_bw: Optional[float] = None
                ) -> IterLedger:
        """Account one iteration.

        ``moe_stats``: the scan's ``aux["moe_stats"]``; ``fp4_layers``:
        mean FP4 rank count per layer (the engine's ``stat.fp4_ranks``);
        ``tokens``/``batch_tokens``: routed vs padded token counts;
        ``ici_bw``: optional measured ICI bytes/s (defaults to the
        migration-bandwidth constant the cost model prices at).
        """
        from repro.configs.base import MIGRATION_BW_DEFAULT
        bw = float(ici_bw) if ici_bw else MIGRATION_BW_DEFAULT
        load = self.rank_loads(moe_stats)            # [L, ep]
        n_rows, ep = load.shape
        tokens = float(tokens)
        batch_tokens = float(batch_tokens)
        k_fp4 = int(np.clip(round(float(fp4_layers)), 0, ep))

        flops = _zero_phases()
        by_rate = {"bf16": 0.0, "int8": 0.0}
        hbm = _zero_phases()
        ici = _zero_phases()
        pred = _zero_phases()

        gemm_per_tok = 2.0 * self.mult * self.d * self.d_ff
        w_slab = self.e_loc * self.mult * self.d * self.d_ff
        for l in range(n_rows):
            row = load[l]
            # FP4 on the k hottest ranks of this layer (approximation:
            # the aux scalar says how many, ReaLB's policy says hottest)
            fp4_mask = np.zeros(ep, dtype=bool)
            if k_fp4 > 0:
                fp4_mask[np.argsort(row)[-k_fp4:]] = True

            # route: router GEMM over this layer's local tokens + the
            # sort/softmax non-gemm traffic
            flops["route"] += tokens * self.d * self.n_experts * 2.0
            hbm["route"] += row.sum() * self.d * 6.0
            pred["route"] += self._nongemm_s(row.max(initial=0.0))

            # weight_gather: a local-FSDP no-op on the virtual bench
            # (the mesh path's all-gather is charged by the roofline)

            # quantize_fp4: read BF16, write packed, on FP4 ranks only.
            # Bytes are real traffic either way; the *visible* seconds
            # depend on fusion — the fused kernel issues inside the
            # dispatch window and only its excess peeks out.
            q_bytes = fp4_mask.sum() * w_slab * (BYTES_BF16 + BYTES_FP4)
            hbm["quantize_fp4"] += q_bytes
            if k_fp4 > 0:
                pred["quantize_fp4"] += self._quantize_visible_s(
                    self._dispatch_s(tokens * self.top_k, bw))

            # dispatch / combine: a2a of routed activations both ways
            a2a_rank = (tokens * self.top_k / ep * (ep - 1) / ep
                        * self.d * BYTES_BF16)
            ici["dispatch"] += a2a_rank * ep
            ici["combine"] += a2a_rank * ep
            pred["dispatch"] += self._dispatch_s(tokens * self.top_k, bw)
            pred["combine"] += self._dispatch_s(tokens * self.top_k, bw)

            # expert_gemm: per-rank grouped GEMM; wall time is the
            # straggler rank, flops/bytes sum over ranks
            for r in range(ep):
                f = row[r] * gemm_per_tok
                by_rate["int8" if fp4_mask[r] else "bf16"] += f
                flops["expert_gemm"] += f
                wb = w_slab * (BYTES_FP4 if fp4_mask[r] else BYTES_BF16)
                if fp4_mask[r] and not self.fused:
                    wb += w_slab * 2.0 * BYTES_BF16  # dequant round-trip
                hbm["expert_gemm"] += (
                    wb + row[r] * self.d * BYTES_BF16 * 4.0)
            pred["expert_gemm"] += max(
                self._expert_gemm_s(row[r], bool(fp4_mask[r]))
                for r in range(ep))

        # other: the dense remainder, roofline-priced
        flops["other"] = 2.0 * self.other_params * tokens
        hbm["other"] = (self.other_params * BYTES_BF16
                        + tokens * self.d * BYTES_BF16 * 8.0)
        pred["other"] = max(flops["other"] / PEAK_BF16,
                            hbm["other"] / HBM_BW)

        as_f = lambda d: {k: float(v) for k, v in d.items()}
        return IterLedger(
            tokens=tokens, batch_tokens=batch_tokens,
            flops=as_f(flops), flops_by_rate=as_f(by_rate),
            hbm_bytes=as_f(hbm), ici_bytes=as_f(ici), pred_s=as_f(pred),
            model_flops=2.0 * self.active_params * tokens)
