"""Hot-loop profiler: per-phase time attribution + costmodel drift.

Three jobs, one object:

1. **Phase attribution.**  Every recorded engine iteration feeds
   :meth:`Profiler.observe_iter` with the realized routing stats and the
   iteration's measured forward seconds (virtual-clock charge on the
   bench, wall seconds on hardware).  The :class:`~repro.obs.ledger.
   FlopByteLedger` turns the stats into analytic per-phase seconds; the
   measured iteration time is attributed to phases proportionally to
   those predictions.  The attribution is exhaustive by construction —
   ``sum(phase seconds) == forward seconds`` is the reconciliation
   invariant ``benchmarks/profile_report.py`` enforces (the same
   accounting-integrity discipline as ``trace_report.py``).  Real
   unattributed per-phase wall numbers come from the two instrumented
   paths below.
2. **MFU / roofline gauges.**  Cumulative ledger flops over cumulative
   measured forward seconds against the single-sourced
   :data:`repro.configs.hw.PEAK_BF16`, plus the compute-vs-memory-vs-
   collective roofline fraction — pushed into the shared
   :class:`~repro.obs.metrics.MetricsRegistry` (``mfu``,
   ``roofline_fraction``) so ``Telemetry.summary()`` and every arm's
   ``BENCH_serve.json`` carry them.
3. **Costmodel drift.**  ``time_scale()`` is the EWMA of measured-over-
   predicted iteration seconds — the calibration factor the replan cost
   gates (``ReplanCostGate.time_scale``) multiply predicted savings by.
   Per-phase drift ratios (cumulative measured / predicted) land in the
   ``costmodel_drift`` gauge.

Instrumented execution mode (:func:`time_moe_phases`) runs the MoE layer
as separately-jitted cumulative *prefixes* (``stop_stage`` in
``core/ep_moe.py``), timing each with ``block_until_ready``; phase time
is the difference of adjacent prefix times.  The full prefix is
literally the fused computation, so its output is bitwise identical to
the normal path (pinned by test).  Caveat: prefix timings are
*unoverlapped* standalone costs — the fused graph overlaps FP4
quantization with the dispatch all-to-all, so the sum of phases is an
upper bound on fused time, and the ``dispatch + quantize_fp4`` share is
exactly the number ROADMAP item 1's Pallas kernel must shrink.

Disabled profiling follows the tracer's null-object discipline:
:data:`NULL_PROFILER` is a shared no-op singleton — no stats
conversion, no clock reads, bitwise-identical engine outputs (pinned by
``tests/test_profiler.py``).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.configs.hw import PEAK_BF16
from repro.obs.ledger import PHASES, FlopByteLedger, IterLedger

#: MoE phase order of the instrumented prefixes, per dispatch mode.
MOE_STAGES = {
    "dispatch": ("route", "weight_gather", "quantize_fp4", "dispatch",
                 "expert_gemm", "combine"),
    "broadcast": ("route", "weight_gather", "quantize_fp4",
                  "expert_gemm", "combine"),
}

PROFILE_SCHEMA = "repro.profile.v1"


class NullProfiler:
    """Shared no-op: the engine's default when no profiler is wired."""
    enabled = False

    def observe_iter(self, *a, **kw) -> None:
        pass

    def time_scale(self) -> float:
        return 1.0

    def mfu(self) -> float:
        return 0.0

    def span_args(self) -> Dict[str, Any]:
        return {}


NULL_PROFILER = NullProfiler()


class Profiler:
    """Per-iteration phase/FLOP/drift accounting around a ledger.

    ``registry`` (optional): a :class:`~repro.obs.metrics.MetricsRegistry`
    — pass the telemetry's so gauges surface in ``summary()``.
    ``clock`` is unused for attribution (the engine passes measured
    ``fwd_s`` explicitly) but stamped into written profiles.
    ``ewma_alpha`` smooths ``time_scale`` and per-phase drift.
    """
    enabled = True

    def __init__(self, ledger: FlopByteLedger,
                 registry=None, clock: Optional[Callable[[], float]] = None,
                 ewma_alpha: float = 0.25):
        self.ledger = ledger
        self.registry = registry
        self.clock = clock
        self.alpha = float(ewma_alpha)
        self.n_iters = 0
        self.fwd_s_total = 0.0
        self.model_flops_total = 0.0
        self.flops_total = 0.0
        # fractional bytes are correct here: FP4 weights price at 4.25
        # bits/elem (ledger BYTES_FP4 = 0.53125), so per-iter HBM totals
        # are analytic floats, not buffer sizes
        self.hbm_bytes_total = 0.0  # repro-lint: disable=RPL006
        self.ici_bytes_total = 0.0  # repro-lint: disable=RPL006
        self._meas_s = {ph: 0.0 for ph in PHASES}
        self._pred_s = {ph: 0.0 for ph in PHASES}
        self._scale_ewma: Optional[float] = None
        self.last: Optional[IterLedger] = None
        if registry is not None:
            self._g_mfu = registry.gauge(
                "mfu", "model flops / (measured s * peak bf16)")
            self._g_roof = registry.gauge(
                "roofline_fraction", "compute share of the roofline bound")
            self._g_scale = registry.gauge(
                "costmodel_time_scale", "EWMA measured/predicted iter s")
            self._g_drift = registry.gauge(
                "costmodel_drift", "cumulative measured/predicted per phase",
                labels=("phase",))
            self._c_flops = registry.counter(
                "model_flops", "cumulative useful model flops")
            self._c_phase = registry.counter(
                "phase_seconds", "measured seconds attributed per phase",
                labels=("phase",))
            self._c_pred = registry.counter(
                "phase_seconds_pred", "ledger-predicted seconds per phase",
                labels=("phase",))

    # --------------------------------------------------------------------
    def observe_iter(self, *, moe_stats, fp4_layers: float, tokens: float,
                     batch_tokens: float, fwd_s: float,
                     phase: str = "decode",
                     measured_phases: Optional[Dict[str, float]] = None
                     ) -> IterLedger:
        """Account one recorded iteration.

        ``fwd_s`` is the measured forward time (virtual-clock charge or
        wall seconds).  Without ``measured_phases`` the iteration time is
        attributed to phases by the ledger's predicted shares (exhaustive
        by construction); an instrumented caller may pass real per-phase
        seconds instead and they are rescaled to sum to ``fwd_s`` so the
        reconciliation invariant holds either way.
        """
        led = self.ledger.account(moe_stats, fp4_layers, tokens,
                                  batch_tokens)
        self.last = led
        self.n_iters += 1
        fwd_s = max(float(fwd_s), 0.0)
        self.fwd_s_total += fwd_s
        self.model_flops_total += led.model_flops
        self.flops_total += led.flops_total
        self.hbm_bytes_total += led.hbm_total
        self.ici_bytes_total += led.ici_total

        weights = dict(measured_phases) if measured_phases else led.pred_s
        wtot = sum(max(v, 0.0) for v in weights.values())
        for ph in PHASES:
            self._pred_s[ph] += led.pred_s[ph]
            share = (max(weights.get(ph, 0.0), 0.0) / wtot) if wtot > 0 \
                else 1.0 / len(PHASES)
            self._meas_s[ph] += fwd_s * share

        pred_total = led.pred_total
        if pred_total > 0 and fwd_s > 0:
            r = fwd_s / pred_total
            self._scale_ewma = r if self._scale_ewma is None else (
                self.alpha * r + (1.0 - self.alpha) * self._scale_ewma)

        if self.registry is not None:
            self._g_mfu.set(self.mfu())
            self._g_roof.set(self.roofline_fraction())
            self._g_scale.set(self.time_scale())
            self._c_flops.inc(led.model_flops)
            for ph in PHASES:
                self._c_phase.inc(fwd_s * (
                    (max(weights.get(ph, 0.0), 0.0) / wtot) if wtot > 0
                    else 1.0 / len(PHASES)), phase=ph)
                if led.pred_s[ph] > 0:
                    self._c_pred.inc(led.pred_s[ph], phase=ph)
                if self._pred_s[ph] > 0:
                    self._g_drift.set(
                        self._meas_s[ph] / self._pred_s[ph], phase=ph)
        return led

    # -- derived quantities ----------------------------------------------
    def mfu(self) -> float:
        if self.fwd_s_total <= 0:
            return 0.0
        return self.model_flops_total / (self.fwd_s_total * PEAK_BF16)

    def roofline_fraction(self) -> float:
        from repro.launch.roofline import roofline_terms
        if self.flops_total <= 0:
            return 0.0
        return roofline_terms(self.flops_total, self.hbm_bytes_total,
                              self.ici_bytes_total)["roofline_fraction"]

    def time_scale(self) -> float:
        """EWMA of measured/predicted iteration seconds (1.0 until the
        first observation) — the cost gates' savings-side calibration."""
        return 1.0 if self._scale_ewma is None else float(self._scale_ewma)

    def phase_seconds(self) -> Dict[str, float]:
        return dict(self._meas_s)

    def phase_seconds_pred(self) -> Dict[str, float]:
        return dict(self._pred_s)

    def drift(self) -> Dict[str, float]:
        """Cumulative measured/predicted ratio per phase (1.0 when the
        phase never carried predicted time)."""
        return {ph: (self._meas_s[ph] / self._pred_s[ph]
                     if self._pred_s[ph] > 0 else 1.0) for ph in PHASES}

    def span_args(self) -> Dict[str, Any]:
        """Per-iteration metadata for the engine's ``iter`` trace span."""
        if self.last is None:
            return {}
        return {"mfu": round(self.mfu(), 6),
                "model_flops": self.last.model_flops,
                "pred_s": round(self.last.pred_total, 9)}

    def summary(self) -> Dict[str, Any]:
        return {
            "n_iters": self.n_iters,
            "mfu": self.mfu(),
            "roofline_fraction": self.roofline_fraction(),
            "time_scale": self.time_scale(),
            "model_flops_total": self.model_flops_total,
            "flops_total": self.flops_total,
            "hbm_bytes_total": self.hbm_bytes_total,
            "ici_bytes_total": self.ici_bytes_total,
            "forward_s_total": self.fwd_s_total,
            "phase_seconds": self.phase_seconds(),
            "phase_seconds_pred": self.phase_seconds_pred(),
            "drift": self.drift(),
        }

    def write(self, path: str, metadata: Optional[Dict[str, Any]] = None
              ) -> Dict[str, Any]:
        """Write the profile JSON ``profile_report.py`` consumes."""
        doc = {
            "schema": PROFILE_SCHEMA,
            "metadata": dict(metadata or {}),
            "n_iters": self.n_iters,
            "phases": {ph: {"measured_s": self._meas_s[ph],
                            "predicted_s": self._pred_s[ph]}
                       for ph in PHASES},
            "totals": {
                "forward_s": self.fwd_s_total,
                "predicted_s": sum(self._pred_s.values()),
                "model_flops": self.model_flops_total,
                "flops": self.flops_total,
                "hbm_bytes": self.hbm_bytes_total,
                "ici_bytes": self.ici_bytes_total,
                "mfu": self.mfu(),
                "roofline_fraction": self.roofline_fraction(),
                "time_scale": self.time_scale(),
            },
            "drift": self.drift(),
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc


# --------------------------------------------------------------------------
# instrumented execution mode: separately-jitted cumulative prefixes
# --------------------------------------------------------------------------
def time_moe_phases(p, x, cfg, rcfg, m_state, *, mode: str = "dispatch",
                    modality=None, valid=None, placement=None,
                    repeats: int = 3, warmup: int = 1
                    ) -> Tuple[Dict[str, float], Any]:
    """Per-phase wall seconds of one MoE layer via prefix timing.

    Jits the layer once per cumulative ``stop_stage`` prefix, times each
    with ``block_until_ready`` (min over ``repeats`` after ``warmup``),
    and reports ``phase[k] = t(prefix_k) − t(prefix_{k−1})`` clamped at
    zero.  Returns ``(phase_seconds, full_output)`` where
    ``full_output`` is the final prefix's ``(y, m_new, aux)`` — bitwise
    identical to ``ep_moe_forward`` without instrumentation (the full
    prefix *is* the fused computation).

    Local (virtual-EP) path only; quantize timing requires the overlap
    pipeline (``rcfg.overlap``) — under ReaLB-seq the transformation
    cost lands inside the ``dispatch`` phase instead.
    """
    import jax

    from repro.core import ep_moe

    stages = MOE_STAGES[mode]

    def make(stop):
        def fn(p_, x_, m_):
            return ep_moe.ep_moe_forward(
                p_, x_, cfg, rcfg, m_, modality=modality, valid=valid,
                mode=mode, placement=placement, stop_stage=stop)
        return jax.jit(fn)

    def measure(fn):
        out = None
        for _ in range(max(warmup, 1)):
            out = jax.block_until_ready(fn(p, x, m_state))
        best = float("inf")
        for _ in range(max(repeats, 1)):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(p, x, m_state))
            best = min(best, time.perf_counter() - t0)
        return best, out

    seconds: Dict[str, float] = {}
    prev = 0.0
    full_out = None
    for stage in stages:
        stop = None if stage == stages[-1] else stage
        t, out = measure(make(stop))
        seconds[stage] = max(t - prev, 0.0)
        prev = max(t, prev)
        if stop is None:
            full_out = out
    return seconds, full_out
