"""repro.obs — observability for the serving stack.

Three cooperating pieces, all host-side and dependency-light:

- :mod:`repro.obs.trace` — a low-overhead nested-span tracer recording
  engine / manager / executor / elastic activity per iteration, exported
  as Chrome-trace (Perfetto-loadable) JSON.  Disabled tracing is a
  shared no-op singleton: no dict churn, no clock reads, bitwise-
  identical engine outputs.
- :mod:`repro.obs.metrics` — a typed metrics registry (counters /
  gauges / histograms with labels) that ``serving.telemetry`` is built
  on, plus the per-layer per-rank expert-load heatmap recorder and the
  predicted-vs-realized peak-rank-load accuracy tracker.
- :mod:`repro.obs.audit` — the replan-decision audit log: every
  ``ReplanDiscipline`` verdict (cadence, warmup, min-gain, churn
  budget, cost gate, must-plan) as one structured event, queryable
  after a run.
- :mod:`repro.obs.ledger` / :mod:`repro.obs.profiler` — the hot-loop
  FLOP/byte ledger (exact per-layer per-rank flops and HBM/ICI bytes
  from the realized routing stats) and the per-phase profiler feeding
  ``mfu`` / ``roofline_fraction`` / costmodel-drift gauges into the
  registry; disabled profiling is the same no-op-singleton discipline
  as the tracer.
"""
from repro.obs.audit import ReplanAudit
from repro.obs.ledger import PHASES, FlopByteLedger, IterLedger
from repro.obs.metrics import (Counter, Gauge, HeatmapRecorder, Histogram,
                               MetricsRegistry, PredictionTracker)
from repro.obs.profiler import (MOE_STAGES, NULL_PROFILER, NullProfiler,
                                Profiler, time_moe_phases)
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer,
                             validate_chrome_trace)

__all__ = [
    "Counter", "FlopByteLedger", "Gauge", "HeatmapRecorder", "Histogram",
    "IterLedger", "MetricsRegistry", "MOE_STAGES", "NULL_PROFILER",
    "NULL_TRACER", "NullProfiler", "NullTracer", "PHASES",
    "PredictionTracker", "Profiler", "ReplanAudit", "Tracer",
    "time_moe_phases", "validate_chrome_trace",
]
