"""repro.obs — observability for the serving stack.

Three cooperating pieces, all host-side and dependency-light:

- :mod:`repro.obs.trace` — a low-overhead nested-span tracer recording
  engine / manager / executor / elastic activity per iteration, exported
  as Chrome-trace (Perfetto-loadable) JSON.  Disabled tracing is a
  shared no-op singleton: no dict churn, no clock reads, bitwise-
  identical engine outputs.
- :mod:`repro.obs.metrics` — a typed metrics registry (counters /
  gauges / histograms with labels) that ``serving.telemetry`` is built
  on, plus the per-layer per-rank expert-load heatmap recorder and the
  predicted-vs-realized peak-rank-load accuracy tracker.
- :mod:`repro.obs.audit` — the replan-decision audit log: every
  ``ReplanDiscipline`` verdict (cadence, warmup, min-gain, churn
  budget, cost gate, must-plan) as one structured event, queryable
  after a run.
"""
from repro.obs.audit import ReplanAudit
from repro.obs.metrics import (Counter, Gauge, HeatmapRecorder, Histogram,
                               MetricsRegistry, PredictionTracker)
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer,
                             validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "HeatmapRecorder", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "NullTracer", "PredictionTracker", "ReplanAudit",
    "Tracer", "validate_chrome_trace",
]
