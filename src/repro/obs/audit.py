"""Replan-decision audit log.

Every ``ReplanDiscipline.maybe_replan`` call ends in exactly one
verdict; the audit log records it as one structured event so a run can
answer "why did the planner (not) replan at iteration N" after the
fact.  Verdicts and their extra fields:

- ``no-cadence`` / ``disabled`` / ``in-flight`` / ``blocked`` /
  ``warmup`` / ``already-replanned`` — the cadence gate said no (the
  reason is the verdict itself).
- ``zero-load`` — cadence hit but the predictor had nothing to plan on.
- ``min-gain`` — predicted gain below ``min_gain`` (fields:
  ``pred_gain``).
- ``noop`` — planner produced the current layout (per-layer: every
  per-layer plan was a noop or churn-budget-trimmed away; fields:
  ``changed_layers=0``).
- ``cost-gate`` — the analytic gate rejected the priced plan (fields:
  ``pred_gain``, ``migration_bytes``, ``migration_s``, ``n_moved``).
- ``staged`` — plan accepted and staged for (a)synchronous application
  (same pricing fields, plus ``changed_layers`` and ``must`` for
  elastic must-plans).

Events carry a monotone ``seq`` (program order, deterministic under the
virtual clock), the iteration, the manager kind (``placement`` /
``replication``), and the cadence ``regime`` (``mixed`` / ``decode``)
when one fired.
"""
from __future__ import annotations

import json
from collections import Counter as _TallyCounter
from typing import Any, Dict, List, Optional


class ReplanAudit:
    """Append-only decision log shared by both managers of a run."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def record(self, *, it: int, manager: str, verdict: str,
               regime: Optional[str] = None, **fields) -> Dict[str, Any]:
        ev: Dict[str, Any] = {"seq": len(self.events), "it": int(it),
                              "manager": manager, "verdict": verdict}
        if regime is not None:
            ev["regime"] = regime
        for k, v in fields.items():
            if v is not None:
                ev[k] = v
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    # -- queries -----------------------------------------------------------
    def query(self, *, manager: Optional[str] = None,
              verdict: Optional[str] = None,
              it: Optional[int] = None) -> List[Dict[str, Any]]:
        out = self.events
        if manager is not None:
            out = [e for e in out if e["manager"] == manager]
        if verdict is not None:
            out = [e for e in out if e["verdict"] == verdict]
        if it is not None:
            out = [e for e in out if e["it"] == it]
        return list(out)

    def counts(self, by: str = "verdict") -> Dict[str, int]:
        """Tally events by any field (missing field -> 'none')."""
        tally = _TallyCounter(str(e.get(by, "none")) for e in self.events)
        return dict(sorted(tally.items()))

    def cadence_hits(self) -> List[Dict[str, Any]]:
        """Events where the cadence gate opened (a plan was attempted):
        everything past the cheap cadence rejections."""
        skip = {"no-cadence", "disabled", "in-flight", "blocked",
                "warmup", "already-replanned"}
        return [e for e in self.events if e["verdict"] not in skip]

    # -- export ------------------------------------------------------------
    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev, default=float) + "\n")
        return path

    @staticmethod
    def load_jsonl(path: str) -> List[Dict[str, Any]]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]
