"""Typed metrics registry + serving-specific recorders.

The registry gives the serving stack one vocabulary for numbers that
are not per-iteration time series: **counters** (monotonic cumulative
sums — bytes migrated, plans committed), **gauges** (last-written
values — current capacity factor), and **histograms** (bounded sample
windows summarized as percentiles — recovery seconds).  Metrics carry
declared label names; a labeled metric holds one value per label-value
tuple, so e.g. one ``replan_decisions`` counter covers every verdict
kind without a metric per verdict.

Two domain recorders build on the same percentile math:

- :class:`HeatmapRecorder` — per-layer per-rank expert-load occupancy
  from the ``[L, E]`` expert stats (or exact ``[L, slots]`` slot stats)
  already threaded through the scan, folded to rank totals by the live
  placement/replication tables.
- :class:`PredictionTracker` — the predicted-vs-realized peak-rank-load
  accuracy metric (ROADMAP item 5's bake-off criterion): each committed
  replan opens a window stamped with the predictor's per-layer rank
  loads; realized loads accumulate until the next commit; the window
  closes with per-layer |predicted − realized| peak-share errors.

``percentile`` / ``summarize`` live here (dependency-light, directly
unit-tested) and are re-exported by ``repro.serving.telemetry``.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy 'linear' method).

    q in [0, 100].  Defined locally (not np.percentile) so the telemetry
    math is dependency-light and directly unit-tested.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q out of range: {q}")
    xs = sorted(xs)
    if not xs:
        raise ValueError("percentile of empty sequence")
    if len(xs) == 1:
        return float(xs[0])
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


def summarize(xs: Sequence[float], qs=(50, 90, 99)) -> Dict[str, float]:
    """{"p50": ..., "p90": ..., ...} plus mean; empty input -> {}."""
    xs = list(xs)
    if not xs:
        return {}
    out = {f"p{int(q)}": percentile(xs, q) for q in qs}
    out["mean"] = sum(xs) / len(xs)
    return out


class _Metric:
    """Shared label plumbing: values keyed by label-value tuples."""
    kind = "metric"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._data: Dict[Tuple, Any] = {}

    def _key(self, kw: Dict[str, Any]) -> Tuple:
        if tuple(sorted(kw)) != tuple(sorted(self.labels)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labels}, "
                f"got {tuple(sorted(kw))}")
        return tuple(kw[k] for k in self.labels)

    def labelsets(self) -> List[Tuple]:
        return list(self._data)

    def _fmt_key(self, key: Tuple) -> str:
        return ",".join(f"{k}={v}" for k, v in zip(self.labels, key))


class Counter(_Metric):
    """Monotonic cumulative sum.  Integer-valued increments keep the
    stored value integral (byte counters stay exact ints)."""
    kind = "counter"

    def inc(self, amount=1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r}: negative inc")
        key = self._key(labels)
        self._data[key] = self._data.get(key, 0) + amount

    def value(self, **labels):
        return self._data.get(self._key(labels), 0)

    def total(self):
        """Sum over every labelset (0 when never incremented)."""
        return sum(self._data.values()) if self._data else 0

    def snapshot(self) -> Any:
        if not self.labels:
            return self.value()
        return {self._fmt_key(k): v for k, v in sorted(self._data.items())}


class Gauge(_Metric):
    """Last-written value per labelset."""
    kind = "gauge"

    def set(self, value, **labels) -> None:
        self._data[self._key(labels)] = value

    def value(self, default=None, **labels):
        return self._data.get(self._key(labels), default)

    def snapshot(self) -> Any:
        if not self.labels:
            return self.value()
        return {self._fmt_key(k): v for k, v in sorted(self._data.items())}


class Histogram(_Metric):
    """Sample collector summarized as percentiles.

    ``window=None`` keeps every observation (recoveries: a handful per
    run); a finite window bounds memory like telemetry's deques."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 window: Optional[int] = None):
        super().__init__(name, help, labels)
        self.window = window

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        bucket = self._data.get(key)
        if bucket is None:
            bucket = deque(maxlen=self.window) if self.window else []
            self._data[key] = bucket
        bucket.append(float(value))

    def values(self, **labels) -> List[float]:
        return list(self._data.get(self._key(labels), ()))

    def count(self, **labels) -> int:
        return len(self._data.get(self._key(labels), ()))

    def summary(self, qs=(50, 90, 99), **labels) -> Dict[str, float]:
        return summarize(self.values(**labels), qs=qs)

    def snapshot(self) -> Any:
        def one(bucket):
            s = summarize(list(bucket))
            s["count"] = len(bucket)
            if bucket:
                s["max"] = max(bucket)
            return s
        if not self.labels:
            return one(self._data.get((), ()))
        return {self._fmt_key(k): one(v)
                for k, v in sorted(self._data.items())}


class MetricsRegistry:
    """Register-or-get home for every metric; one per Telemetry."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_make(self, cls, name, help, labels, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls or m.labels != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{m.kind}{m.labels}")
            return m
        m = cls(name, help=help, labels=labels, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  window: Optional[int] = None) -> Histogram:
        return self._get_or_make(Histogram, name, help, labels,
                                 window=window)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """Flat {metric-name: value/summary} dict, JSON-serializable."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}


def _as_2d(x) -> np.ndarray:
    a = np.asarray(x, dtype=np.float64)
    return a[None, :] if a.ndim == 1 else a


class HeatmapRecorder:
    """Per-layer per-rank expert-load occupancy over the run.

    Feed one ``[L, R]`` rank-load matrix per iteration (tokens routed to
    each rank's experts at each layer).  Keeps the cumulative sum, the
    last matrix, and every ``every``-th iteration a normalized snapshot
    in a bounded deque — enough to see skew drift without storing the
    full time series.
    """

    def __init__(self, every: int = 32, keep: int = 8):
        self.every = max(int(every), 1)
        self.keep = keep
        self.n_records = 0
        self._sum: Optional[np.ndarray] = None
        self.last: Optional[np.ndarray] = None
        self.snapshots: Deque[Dict[str, Any]] = deque(maxlen=keep)

    def record(self, heatmap) -> None:
        hm = _as_2d(heatmap)
        if self._sum is None or self._sum.shape != hm.shape:
            # shape change (elastic resize / first record) restarts the
            # accumulation — a mixed-geometry sum would be meaningless
            self._sum = np.zeros_like(hm)
            self.n_records = 0
            self.snapshots.clear()
        self._sum += hm
        self.last = hm
        self.n_records += 1
        if self.n_records % self.every == 0:
            self.snapshots.append({"n": self.n_records,
                                   "share": self.shares().tolist()})

    def shares(self) -> np.ndarray:
        """Cumulative ``[L, R]`` with each layer row normalized to 1
        (zero rows stay zero)."""
        if self._sum is None:
            return np.zeros((0, 0))
        rows = self._sum.sum(axis=1, keepdims=True)
        return np.divide(self._sum, np.where(rows > 0, rows, 1.0))

    def summary(self) -> Dict[str, Any]:
        if self._sum is None or self.n_records == 0:
            return {}
        share = self.shares()
        L, R = share.shape
        peak = share.max(axis=1)
        # max/mean ratio per layer: 1.0 = perfectly balanced, R = one
        # rank took everything
        imbalance = peak * R
        rank_total = self._sum.sum(axis=0)
        tot = rank_total.sum()
        return {
            "n_records": self.n_records,
            "layers": L,
            "ranks": R,
            "rank_share": (rank_total / tot if tot > 0
                           else rank_total).tolist(),
            "layer_peak_rank": share.argmax(axis=1).tolist(),
            "layer_peak_share": peak.tolist(),
            "imbalance_mean": float(imbalance.mean()),
            "imbalance_max": float(imbalance.max()),
            "share": share.tolist(),
            "n_snapshots": len(self.snapshots),
        }


class PredictionTracker:
    """Predicted-vs-realized peak-rank load per replan window, per layer.

    Protocol: on each committed replan the manager predicts per-layer
    rank loads for the fresh tables; :meth:`open` stamps them and closes
    the previous window.  Every iteration's realized ``[L, R]`` rank
    loads accumulate via :meth:`record`.  A window's per-layer error is
    ``|predicted peak-rank share − realized peak-rank share|`` plus
    whether the predicted peak rank was the realized one — exactly the
    quantity the cost gate trusted when it priced the migration.
    """

    def __init__(self):
        self.windows: List[Dict[str, Any]] = []
        self._open_it: Optional[int] = None
        self._pred: Optional[np.ndarray] = None
        self._acc: Optional[np.ndarray] = None
        self._n_acc = 0

    def open(self, it: int, predicted) -> None:
        """Close any open window and start one at iteration ``it`` with
        the predictor's per-layer rank loads (``[L, R]`` or ``[R]``)."""
        self._close(end_it=int(it))
        if predicted is None:
            return
        self._open_it = int(it)
        self._pred = _as_2d(predicted)
        self._acc = np.zeros_like(self._pred)
        self._n_acc = 0

    def record(self, realized) -> None:
        if self._pred is None:
            return
        r = _as_2d(realized)
        if self._acc.shape[0] == 1 and r.shape[1:] == self._acc.shape[1:]:
            # a shared-table prediction is one depth-aggregated row;
            # fold the per-layer realized loads the same way
            r = r.sum(axis=0, keepdims=True)
        if r.shape != self._acc.shape:
            return                      # geometry changed mid-window
        self._acc += r
        self._n_acc += 1

    def _window_stats(self, end_it: Optional[int]) -> Optional[Dict]:
        if self._pred is None or self._n_acc == 0:
            return None
        per_layer = []
        for l in range(self._pred.shape[0]):
            p, r = self._pred[l], self._acc[l]
            if p.sum() <= 0 or r.sum() <= 0:
                continue
            ps, rs = p / p.sum(), r / r.sum()
            per_layer.append({
                "layer": l,
                "pred_peak_share": float(ps.max()),
                "real_peak_share": float(rs.max()),
                "abs_err": float(abs(ps.max() - rs.max())),
                "rank_match": bool(ps.argmax() == rs.argmax()),
            })
        if not per_layer:
            return None
        return {"start_it": self._open_it, "end_it": end_it,
                "n_iters": self._n_acc, "per_layer": per_layer}

    def _close(self, end_it: Optional[int]) -> None:
        w = self._window_stats(end_it)
        if w is not None:
            self.windows.append(w)
        self._open_it = self._pred = self._acc = None
        self._n_acc = 0

    def summary(self) -> Dict[str, Any]:
        """Aggregate over closed windows plus the open one (virtually
        closed — :meth:`record` keeps working afterwards)."""
        ws = list(self.windows)
        virt = self._window_stats(end_it=None)
        if virt is not None:
            ws.append(virt)
        if not ws:
            return {}
        rows = [pl for w in ws for pl in w["per_layer"]]
        return {
            "n_windows": len(ws),
            "n_iters_observed": sum(w["n_iters"] for w in ws),
            "pred_peak_share_mean": float(np.mean(
                [r["pred_peak_share"] for r in rows])),
            "real_peak_share_mean": float(np.mean(
                [r["real_peak_share"] for r in rows])),
            "peak_share_abs_err": summarize(
                [r["abs_err"] for r in rows], qs=(50, 90)),
            "rank_match_frac": float(np.mean(
                [1.0 if r["rank_match"] else 0.0 for r in rows])),
        }
