"""Pallas TPU kernels for the paper's compute hot-spots (NVFP4 quantize +
W4A4 GEMM) with jnp oracles in ref.py and jit wrappers in ops.py."""
