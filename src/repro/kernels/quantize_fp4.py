"""Pallas TPU kernel: on-the-fly NVFP4 (E2M1 + E4M3 group scales) quantization.

This is the paper's "Precision Transformation (T)" stage (§4.3) as a TPU
kernel: BF16 expert weights resident in HBM are streamed through VMEM in
``(block_n, block_k)`` tiles, quantized per group of 16 along the
contraction axis, and written back as packed 4-bit codes + FP8-E4M3-valued
scales — 4.25 bits/weight of HBM traffic on the way out.  The per-tensor
``global_scale`` is precomputed at PTQ-calibration time (an input, exactly
as the paper stores "precomputed scaling factors").

Layout: ``w [N, K]`` (contraction on K) → ``packed u8 [N, K/2]``,
``scales f32 [N, K/16]``.  Tile sizes default to (256, 512): the tile +
outputs occupy 256·512·(2+0.5+0.25) ≈ 360 KiB of VMEM, and K blocks are
multiples of the 128-lane register width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.nvfp4 import (E4M3_MAX, FP4_MAX, GROUP, INV_FP4_MAX,
                                 e4m3_round as _e4m3_round,
                                 fp4_code as _fp4_code)


def _quantize_kernel(gscale_ref, w_ref, packed_ref, scales_ref, *,
                     group: int):
    w = w_ref[...].astype(jnp.float32)              # [bn, bk]
    bn, bk = w.shape
    gs = gscale_ref[0, 0]
    wg = w.reshape(bn, bk // group, group)
    amax = jnp.max(jnp.abs(wg), axis=-1)            # [bn, bk/g]
    s_local = _e4m3_round(amax * INV_FP4_MAX / gs)  # see core/quant.py note
    s_local = jnp.maximum(s_local, 2.0 ** -9)
    codes = _fp4_code(wg / (s_local * gs)[..., None])
    codes = codes.reshape(bn, bk)
    pair = codes.reshape(bn, bk // 2, 2)
    packed_ref[...] = (pair[..., 0] | (pair[..., 1] << 4)).astype(jnp.uint8)
    scales_ref[...] = s_local


@functools.partial(jax.jit,
                   static_argnames=("group", "block_n", "block_k",
                                    "interpret"))
def quantize_fp4_kernel(w: jax.Array, global_scale: jax.Array, *,
                        group: int = GROUP, block_n: int = 256,
                        block_k: int = 512, interpret: bool = False):
    """w [N,K] bf16/f32 → (packed u8 [N,K/2], scales f32 [N,K/group])."""
    n, k = w.shape
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert n % block_n == 0 and k % block_k == 0, (w.shape, block_n, block_k)
    assert block_k % (2 * group) == 0
    grid = (n // block_n, k // block_k)
    kernel = functools.partial(_quantize_kernel, group=group)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_k // 2), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_k // group), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k // 2), jnp.uint8),
            jax.ShapeDtypeStruct((n, k // group), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(global_scale, jnp.float32).reshape(1, 1), w)
