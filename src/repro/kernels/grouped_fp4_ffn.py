"""Pallas TPU kernel: grouped NVFP4 expert FFN over the slot dimension.

This is the serving hot loop's expert compute (``_grouped_ffn_fp4`` in
``repro.core.ep_moe``) as ONE fused ragged-GEMM pipeline instead of
dequantize → ``ragged_dot`` × 3:

* tokens arrive sorted by local expert slot (``xs [M, D]``), with per-slot
  counts ``gs [G]``; the prefix-sum offsets and a skip map over empty
  slots are **scalar-prefetched** so BlockSpec index maps can steer weight
  DMA before the grid step runs;
* packed E2M1 codes + E4M3-valued group-16 scales stream HBM→VMEM at
  4.25 bits/weight and are dequantized in-register (compare-select decode
  from ``repro.kernels.nvfp4`` — no gathers);
* activation fake-quant (a4), the SwiGLU ``act(x·Wg) ⊙ (x·Wu)`` elementwise
  stage, and the down projection all happen on the same VMEM-resident
  tiles, so the intermediate ``h [M, d_ff]`` never round-trips HBM and the
  BF16 dequantized weights never exist outside a register tile.

Grid ``(M/bm, G, F/bf)``: token-block outermost so the f32 output
accumulator (VMEM scratch, zeroed at ``g==f==0``, flushed at the last
``(g, f)`` step) is revisited only on consecutive steps.  A slot with no
tokens (or no row overlap with the current token block) skips all compute
via ``pl.when``; its weight-block index is remapped to the last non-empty
slot at or before it (``gmap``), so consecutive grid steps see the same
block index and Pallas elides the DMA — empty slots cost neither flops nor
HBM traffic.

VMEM per step (full-model shapes D=2048, F=1408 → bf=128, bm=128):
x 512 KiB + acc 1 MiB + gate/up packed 2·128 KiB + down packed 128 KiB +
scales ~48 KiB ≈ 1.9 MiB, comfortably inside ~16 MiB with double
buffering.  On CPU the same kernel runs under ``interpret=True`` for
oracle parity (see ``repro.kernels.ops.ffn_backend``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nvfp4 import GROUP, decode_level, fake_quant_a4


def _dequant_tile(packed, scales, gscale, group, dtype):
    """[R, C/2] u8 + [R, C/group] scales -> [R, C] weight tile in ``dtype``.

    Mirrors the jnp oracle's multiply order exactly:
    ``(levels * local_scale) * global_scale`` (see quant.dequantize_fp4).
    """
    r, c2 = packed.shape
    lo = decode_level(packed & 0x0F)
    hi = decode_level((packed >> 4) & 0x0F)
    vals = jnp.stack([lo, hi], axis=-1).reshape(r, c2 * 2)
    w = (vals.reshape(r, c2 * 2 // group, group) * scales[..., None]) * gscale
    return w.reshape(r, c2 * 2).astype(dtype)


def _ffn_kernel(offs_ref, gmap_ref, x_ref, gsc_ref,
                wgp_ref, wgs_ref, wup_ref, wus_ref, wdp_ref, wds_ref,
                o_ref, acc_ref, *, group, act, n_g, n_f, block_m):
    i = pl.program_id(0)
    g = pl.program_id(1)
    f = pl.program_id(2)

    @pl.when((g == 0) & (f == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    r0 = offs_ref[g]
    r1 = offs_ref[g + 1]
    row0 = i * block_m

    # Skip empty slots and token blocks with no rows in this slot.
    @pl.when((r1 > r0) & (row0 < r1) & (row0 + block_m > r0))
    def _compute():
        dtype = x_ref.dtype
        x = x_ref[...].astype(jnp.float32)                   # [bm, D]
        rows = row0 + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, 1), 0)
        mask = (rows >= r0) & (rows < r1)
        x = jnp.where(mask, x, 0.0)
        # oracle: xq = fake_quant_a4(xs) once over all rows — row-local, so
        # recomputing per (block, slot) with masked rows is identical.
        xq = fake_quant_a4(x, group).astype(dtype)

        gsc = gsc_ref[...]                                    # [1, 3]
        wg = _dequant_tile(wgp_ref[0], wgs_ref[0], gsc[0, 0], group, dtype)
        wu = _dequant_tile(wup_ref[0], wus_ref[0], gsc[0, 1], group, dtype)

        gate = jax.lax.dot_general(                           # [bm, bf]
            xq, wg, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dtype)
        up = jax.lax.dot_general(
            xq, wu, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dtype)
        h = (act(gate.astype(jnp.float32)).astype(dtype) * up)
        hq = fake_quant_a4(h, group).astype(dtype)

        wd = _dequant_tile(wdp_ref[0], wds_ref[0], gsc[0, 2], group, dtype)
        acc_ref[...] += jax.lax.dot_general(                  # [bm, D]
            hq, wd, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when((g == n_g - 1) & (f == n_f - 1))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block_f(f: int, group: int) -> int:
    """Largest divisor of d_ff ≤ 512 that keeps group-16 scale tiles whole."""
    for cand in (512, 256, 128, 64, 32, 16):
        if f % cand == 0 and cand % group == 0:
            return cand
    return f


@functools.partial(jax.jit,
                   static_argnames=("group", "act", "block_m",
                                    "interpret", "out_dtype"))
def grouped_fp4_ffn_kernel(xs: jax.Array, gs: jax.Array,
                           gate_packed: jax.Array, gate_scales: jax.Array,
                           up_packed: jax.Array, up_scales: jax.Array,
                           down_packed: jax.Array, down_scales: jax.Array,
                           global_scales: jax.Array, *,
                           group: int = GROUP, act=jax.nn.silu,
                           block_m: int = 128, interpret: bool = False,
                           out_dtype=None) -> jax.Array:
    """Fused grouped FP4 SwiGLU FFN: ``xs [M, D]`` sorted by slot → ``[M, D]``.

    ``gs [G]`` int32 token counts per slot (``sum(gs) == M``);
    gate/up quantized along D (``packed [G, F, D/2]``, ``scales
    [G, F, D/group]``), down along F (``[G, D, F/2]``, ``[G, D, F/group]``);
    ``global_scales [3]`` f32 per-tensor scales (gate, up, down).
    Rows are padded to ``block_m`` internally — callers pass real ``M``.
    """
    m, d = xs.shape
    n_groups = gs.shape[0]
    f = gate_packed.shape[1]
    assert d % (2 * group) == 0 and f % (2 * group) == 0, (d, f)

    block_m = min(block_m, max(8, m))
    mp = -(-m // block_m) * block_m
    if mp != m:
        xs = jnp.pad(xs, ((0, mp - m), (0, 0)))
    block_f = _pick_block_f(f, group)
    grid = (mp // block_m, n_groups, f // block_f)

    gs = gs.astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(gs)])
    # empty-slot skip map: index of the last non-empty slot at or before g
    # (0 if none yet) — consecutive grid steps then reuse the same weight
    # block and the DMA is elided.
    nz = gs > 0
    gmap = jnp.maximum(
        jax.lax.cummax(jnp.where(nz, jnp.arange(n_groups, dtype=jnp.int32),
                                 -1)), 0)

    kernel = functools.partial(_ffn_kernel, group=group, act=act,
                               n_g=n_groups, n_f=grid[2], block_m=block_m)
    out_dtype = out_dtype or xs.dtype
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, g, f, offs, gmap: (i, 0)),
                pl.BlockSpec((1, 3), lambda i, g, f, offs, gmap: (0, 0)),
                pl.BlockSpec((1, block_f, d // 2),
                             lambda i, g, f, offs, gmap: (gmap[g], f, 0)),
                pl.BlockSpec((1, block_f, d // group),
                             lambda i, g, f, offs, gmap: (gmap[g], f, 0)),
                pl.BlockSpec((1, block_f, d // 2),
                             lambda i, g, f, offs, gmap: (gmap[g], f, 0)),
                pl.BlockSpec((1, block_f, d // group),
                             lambda i, g, f, offs, gmap: (gmap[g], f, 0)),
                pl.BlockSpec((1, d, block_f // 2),
                             lambda i, g, f, offs, gmap: (gmap[g], 0, f)),
                pl.BlockSpec((1, d, block_f // group),
                             lambda i, g, f, offs, gmap: (gmap[g], 0, f)),
            ],
            out_specs=pl.BlockSpec((block_m, d),
                                   lambda i, g, f, offs, gmap: (i, 0)),
            scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((mp, d), out_dtype),
        interpret=interpret,
    )(offs, gmap, xs,
      jnp.asarray(global_scales, jnp.float32).reshape(1, 3),
      gate_packed, gate_scales, up_packed, up_scales,
      down_packed, down_scales)
    return out[:m]
