"""Pallas TPU kernel: W4(A4) GEMM with in-VMEM NVFP4 dequantization.

The paper's FP4 GEMM runs on Blackwell FP4 tensor cores.  TPU v5e has no
FP4 MXU mode, so the TPU-native adaptation keeps FP4 as a *storage* format:
packed 4-bit weights (+ group-16 E4M3 scales) are streamed HBM→VMEM at
4.25 bits/weight — a 3.76× reduction in weight traffic vs BF16 — then
dequantized inside VMEM with pure vector ops (compare-select level decode,
no gathers) and fed to the MXU as bf16.  In the memory-bound expert-GEMM
regimes (decode, skinny per-expert batches) this converts directly into
the latency win the paper obtains from FP4 flops.

Grid (m, n, k), k innermost as the reduction dimension; a VMEM f32
accumulator tile is zeroed at k==0 and flushed at the final k step.
Default tiles (128, 256, 512):
  x tile 128·512·2 = 128 KiB, w tile 256·512/2 = 64 KiB (+16 KiB scales),
  acc 128·256·4 = 128 KiB — comfortably inside the ~16 MiB VMEM with
  double buffering, MXU dims all multiples of 128.

``a4=True`` additionally fake-quantizes the activation tile to the E2M1
grid per group-16 (W4A4 — numerics identical to the paper's NVFP4 GEMM;
the accuracy benchmarks run through this path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nvfp4 import (E4M3_MAX, FP4_MAX, GROUP,
                                 decode_level as _decode_level,
                                 fake_quant_a4 as _fake_quant_a4)


def _matmul_kernel(gscale_ref, x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                   group: int, a4: bool, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)               # [bm, bk]
    if a4:
        x = _fake_quant_a4(x, group)
    packed = w_ref[...]                              # [bn, bk/2] u8
    bn, bk2 = packed.shape
    lo = _decode_level(packed & 0x0F)                # [bn, bk/2]
    hi = _decode_level((packed >> 4) & 0x0F)
    codes = jnp.stack([lo, hi], axis=-1).reshape(bn, bk2 * 2)
    scales = s_ref[...] * gscale_ref[0, 0]           # [bn, bk/group]
    w = (codes.reshape(bn, bk2 * 2 // group, group)
         * scales[..., None]).reshape(bn, bk2 * 2)   # dequant [bn, bk]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("group", "a4", "block_m", "block_n",
                                    "block_k", "interpret", "out_dtype"))
def fp4_matmul_kernel(x: jax.Array, packed: jax.Array, scales: jax.Array,
                      global_scale: jax.Array, *, group: int = GROUP,
                      a4: bool = False, block_m: int = 128,
                      block_n: int = 256, block_k: int = 512,
                      interpret: bool = False, out_dtype=jnp.float32):
    """x [M,K] @ dequant(packed,scales) [N,K]^T -> [M,N].

    packed u8 [N,K/2], scales f32(E4M3-valued) [N,K/group], global f32.
    """
    m, k = x.shape
    n = packed.shape[0]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % (2 * group) == 0
    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_matmul_kernel, group=group, a4=a4,
                               n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_n, block_k // group),
                         lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(global_scale, jnp.float32).reshape(1, 1), x, packed,
      scales)
