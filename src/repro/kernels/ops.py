"""Public jit'd wrappers for the Pallas kernels + the serving backend switch.

On TPU backends the kernels compile natively; on CPU (this container) they
execute in ``interpret=True`` mode, which runs the kernel body in Python —
the correctness tests sweep shapes/dtypes against :mod:`repro.kernels.ref`.

The serving hot loop (``repro.core.ep_moe``) picks its FP4 expert-FFN
implementation through :func:`ffn_backend`:

* ``"pallas"``    — fused grouped kernel, compiled natively (TPU default);
* ``"interpret"`` — same kernel under the Pallas interpreter (CPU oracle
  parity; slow, used by tests and the profiled CI bench arm);
* ``"jnp"``       — the dequantize + ``ragged_dot`` jnp oracle (CPU
  default: fast enough to serve, numerically the reference).

The choice is read at *trace* time: call :func:`set_ffn_backend` (or set
``REPRO_FFN_BACKEND``) before building/jitting an engine; already-compiled
functions keep the backend they were traced with.

All wrappers pad inputs to block multiples internally and slice the
result, so real routed token counts (``ep·cap`` with cap rounded to 8,
arbitrary d_ff) need no caller-side padding.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, global_scale_for
from repro.kernels.fp4_matmul import fp4_matmul_kernel
from repro.kernels.grouped_fp4_ffn import grouped_fp4_ffn_kernel
from repro.kernels.quantize_fp4 import quantize_fp4_kernel

FFN_BACKENDS = ("pallas", "interpret", "jnp")
_ffn_backend_override: Optional[str] = None


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# serving backend switch
# --------------------------------------------------------------------------
def ffn_backend() -> str:
    """Resolve the FP4 expert-FFN backend for the serving hot loop."""
    if _ffn_backend_override is not None:
        return _ffn_backend_override
    env = os.environ.get("REPRO_FFN_BACKEND", "").strip().lower()
    if env in FFN_BACKENDS:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def set_ffn_backend(name: Optional[str]) -> str:
    """Override the backend ("pallas" | "interpret" | "jnp"); ``None`` or
    ``"auto"`` restores env/default resolution.  Returns the active backend.
    Takes effect for functions traced *after* the call."""
    global _ffn_backend_override
    if name is None or name == "auto":
        _ffn_backend_override = None
    else:
        if name not in FFN_BACKENDS:
            raise ValueError(f"unknown ffn backend {name!r}; "
                             f"expected one of {FFN_BACKENDS} or 'auto'")
        _ffn_backend_override = name
    return ffn_backend()


def ffn_fused() -> bool:
    """True when the hot loop runs the fused grouped kernel (either mode),
    i.e. FP4 weights stream packed and ``h`` stays in VMEM — the ledger /
    costmodel should then drop the BF16 dequant HBM round-trip."""
    return ffn_backend() != "jnp"


# --------------------------------------------------------------------------
# padding helpers (satellite: no hard shape asserts at the call sites)
# --------------------------------------------------------------------------
def _pad_dim(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fit_block(size: int, block: int, align: int) -> int:
    """Largest usable block ≤ ``block`` that is a multiple of ``align``;
    sizes below one block collapse to the (aligned-up) size itself."""
    if size <= block:
        return -(-size // align) * align
    return max(align, (block // align) * align)


def quantize_fp4(w: jax.Array, global_scale: jax.Array | None = None, *,
                 group: int = 16, block_n: int = 256, block_k: int = 512,
                 interpret: bool | None = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """NVFP4-quantize ``w [N,K]`` along K. Returns (packed, scales, gscale).

    ``K`` must be a multiple of ``2·group`` (the storage format); ``N`` and
    ``K`` are otherwise arbitrary — tiles are padded internally.
    """
    n, k = w.shape
    assert k % (2 * group) == 0, (w.shape, group)
    if global_scale is None:
        global_scale = global_scale_for(w)
    interpret = _interpret_default() if interpret is None else interpret
    bn = _fit_block(n, block_n, 8)
    bk = _fit_block(k, block_k, 2 * group)
    wp = _pad_dim(_pad_dim(w, 0, bn), 1, bk)
    packed, scales = quantize_fp4_kernel(
        wp, global_scale, group=group, block_n=bn, block_k=bk,
        interpret=interpret)
    return (packed[:n, :k // 2], scales[:n, :k // group],
            jnp.asarray(global_scale, jnp.float32))


def fp4_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array,
               global_scale: jax.Array, *, group: int = 16,
               a4: bool = False, out_dtype=jnp.float32,
               block_m: int = 128, block_n: int = 256, block_k: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """``x [M,K] @ W^T`` with W stored as packed NVFP4 ``[N,K/2]``.

    Arbitrary M/N; K must be a multiple of ``2·group``.  Inputs are padded
    to block multiples (zero rows/cols/groups contribute exact zeros) and
    the result is sliced back to ``[M,N]``.
    """
    interpret = _interpret_default() if interpret is None else interpret
    m, k = x.shape
    n = packed.shape[0]
    assert k % (2 * group) == 0, (x.shape, group)
    bm = _fit_block(m, block_m, 8)
    bn = _fit_block(n, block_n, 8)
    bk = _fit_block(k, block_k, 2 * group)
    xp = _pad_dim(_pad_dim(x, 0, bm), 1, bk)
    pp = _pad_dim(_pad_dim(packed, 0, bn), 1, bk // 2)
    sp = _pad_dim(_pad_dim(scales, 0, bn), 1, bk // group)
    out = fp4_matmul_kernel(
        xp, pp, sp, global_scale, group=group, a4=a4,
        block_m=bm, block_n=bn, block_k=bk,
        interpret=interpret, out_dtype=out_dtype)
    return out[:m, :n]


def fp4_linear(x: jax.Array, w: jax.Array, *, a4: bool = False,
               group: int = 16, interpret: bool | None = None) -> jax.Array:
    """Convenience: quantize-then-matmul (the full on-the-fly T + GEMM path).

    x [M,K] bf16 @ w [K,N] bf16 → [M,N] f32 with NVFP4 weight (and
    optionally activation) numerics.
    """
    packed, scales, gs = quantize_fp4(w.swapaxes(0, 1), group=group,
                                      interpret=interpret)
    return fp4_matmul(x, packed, scales, gs, group=group, a4=a4,
                      interpret=interpret)


# --------------------------------------------------------------------------
# serving hot-loop entry points (grouped over the expert-slot dimension)
# --------------------------------------------------------------------------
def quantize_experts_fp4(wt: jax.Array, *, group: int = 16,
                         interpret: bool | None = None) -> QTensor:
    """Quantize a ``[G, N, K]`` expert weight stack along K via the Pallas
    kernel.  Bitwise-identical to ``quant.quantize_fp4`` (same global
    scale over the whole stack, same per-group recipe)."""
    g, n, k = wt.shape
    gscale = global_scale_for(wt)
    interpret = (ffn_backend() != "pallas") if interpret is None else interpret
    packed, scales = quantize_fp4(wt.reshape(g * n, k), gscale, group=group,
                                  interpret=interpret)[:2]
    return QTensor(packed.reshape(g, n, k // 2),
                   scales.reshape(g, n, k // group), gscale)


def grouped_fp4_ffn(xs: jax.Array, gs: jax.Array,
                    wq: Dict[str, QTensor], *, group: int = 16,
                    act=jax.nn.silu,
                    interpret: bool | None = None) -> jax.Array:
    """Fused grouped FP4 SwiGLU FFN over slot-sorted tokens (see
    ``repro.kernels.grouped_fp4_ffn``).  ``wq`` holds ``w_gate``/``w_up``
    quantized along D and ``w_down`` quantized along d_ff, exactly as
    produced by ``_quantize_experts`` in the hot loop."""
    qg, qu, qd = wq["w_gate"], wq["w_up"], wq["w_down"]
    interpret = (ffn_backend() != "pallas") if interpret is None else interpret
    gscales = jnp.stack([
        jnp.asarray(qg.global_scale, jnp.float32).reshape(()),
        jnp.asarray(qu.global_scale, jnp.float32).reshape(()),
        jnp.asarray(qd.global_scale, jnp.float32).reshape(())])
    return grouped_fp4_ffn_kernel(
        xs, gs, qg.packed, qg.scales, qu.packed, qu.scales,
        qd.packed, qd.scales, gscales, group=group, act=act,
        interpret=interpret)
