"""Public jit'd wrappers for the Pallas kernels.

On TPU backends the kernels compile natively; on CPU (this container) they
execute in ``interpret=True`` mode, which runs the kernel body in Python —
the correctness tests sweep shapes/dtypes against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import global_scale_for
from repro.kernels.fp4_matmul import fp4_matmul_kernel
from repro.kernels.quantize_fp4 import quantize_fp4_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def quantize_fp4(w: jax.Array, global_scale: jax.Array | None = None, *,
                 group: int = 16, block_n: int = 256, block_k: int = 512,
                 interpret: bool | None = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """NVFP4-quantize ``w [N,K]`` along K. Returns (packed, scales, gscale)."""
    if global_scale is None:
        global_scale = global_scale_for(w)
    interpret = _interpret_default() if interpret is None else interpret
    packed, scales = quantize_fp4_kernel(
        w, global_scale, group=group, block_n=block_n, block_k=block_k,
        interpret=interpret)
    return packed, scales, jnp.asarray(global_scale, jnp.float32)


def fp4_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array,
               global_scale: jax.Array, *, group: int = 16,
               a4: bool = False, out_dtype=jnp.float32,
               block_m: int = 128, block_n: int = 256, block_k: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """``x [M,K] @ W^T`` with W stored as packed NVFP4 ``[N,K/2]``."""
    interpret = _interpret_default() if interpret is None else interpret
    return fp4_matmul_kernel(
        x, packed, scales, global_scale, group=group, a4=a4,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret, out_dtype=out_dtype)


def fp4_linear(x: jax.Array, w: jax.Array, *, a4: bool = False,
               group: int = 16, interpret: bool | None = None) -> jax.Array:
    """Convenience: quantize-then-matmul (the full on-the-fly T + GEMM path).

    x [M,K] bf16 @ w [K,N] bf16 → [M,N] f32 with NVFP4 weight (and
    optionally activation) numerics.
    """
    packed, scales, gs = quantize_fp4(w.swapaxes(0, 1), group=group,
                                      interpret=interpret)
    return fp4_matmul(x, packed, scales, gs, group=group, a4=a4,
                      interpret=interpret)
