"""Pure-jnp oracles for the Pallas kernels (bit-exact NVFP4 numerics).

These delegate to :mod:`repro.core.quant` — the same functions that define
the paper's quantization recipe — so the kernels, the EP-MoE jnp
simulation path and the accuracy benchmarks all share one numerical
ground truth.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import quant


def quantize_fp4_ref(w: jax.Array, global_scale: jax.Array,
                     group: int = 16) -> Tuple[jax.Array, jax.Array]:
    """w [N,K] -> (packed u8 [N,K/2], scales f32 [N,K/group])."""
    q = quant.quantize_fp4(w, group, global_scale=global_scale)
    return q.packed, q.scales


def fp4_matmul_ref(x: jax.Array, packed: jax.Array, scales: jax.Array,
                   global_scale: jax.Array, group: int = 16,
                   a4: bool = False, out_dtype=jnp.float32) -> jax.Array:
    """x [M,K] @ dequant(packed [N,K/2], scales [N,K/g])^T -> [M,N]."""
    q = quant.QTensor(packed, scales, jnp.asarray(global_scale, jnp.float32))
    w = quant.dequantize_fp4(q, jnp.float32)                  # [N,K]
    xf = x.astype(jnp.float32)
    if a4:
        # dynamic per-group activation fake-quant (amax/6 scale, E2M1 grid)
        m, k = xf.shape
        xg = xf.reshape(m, k // group, group)
        amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
        gs = jnp.maximum(amax / quant.FP4_MAX, 1e-20)
        xf = (quant.fp4_round(xg / gs) * gs).reshape(m, k)
    return (xf @ w.T).astype(out_dtype)


def dequantize_ref(packed, scales, global_scale, dtype=jnp.float32):
    q = quant.QTensor(packed, scales, jnp.asarray(global_scale, jnp.float32))
    return quant.dequantize_fp4(q, dtype)
