"""Single-source NVFP4 (E2M1 + E4M3 group scales) numerics.

Every implementation of the FP4 grid in this repo — the jnp oracle in
``repro.core.quant``, the Pallas quantize kernel
(``repro.kernels.quantize_fp4``), the W4A4 GEMM kernel
(``repro.kernels.fp4_matmul``) and the grouped expert-FFN kernel
(``repro.kernels.grouped_fp4_ffn``) — imports the helpers below instead of
re-implementing the level table.  Everything here is pure ``jnp`` vector
math (compare-select, no gathers) so the same functions trace both inside
Pallas kernel bodies and in ordinary jitted code, and the kernels cannot
drift from the oracle (``tests/test_nvfp4.py`` pins identity and bitwise
parity against the explicit level table).

Format recap (paper Appendix E): values quantize to E2M1
``{0, ±0.5, ±1, ±1.5, ±2, ±3, ±4, ±6}``; symmetric min-max per group of 16
along the contraction dim with local scale ``amax/6`` rounded to FP8 E4M3;
one global f32 scale per tensor keeps local scales inside E4M3 range.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

GROUP = 16
FP4_MAX = 6.0
INV_FP4_MAX = float(jnp.float32(1.0) / jnp.float32(6.0))
E4M3_MAX = 448.0
# round-to-nearest decision boundaries between consecutive E2M1 levels
FP4_MIDPOINTS = (0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0)


def fp4_index(mag: jax.Array) -> jax.Array:
    """Level index in [0,7] for a non-negative magnitude (int32)."""
    idx = jnp.zeros(mag.shape, jnp.int32)
    for mid in FP4_MIDPOINTS:
        idx = idx + (mag > mid).astype(jnp.int32)
    return idx


def fp4_level(idx: jax.Array) -> jax.Array:
    """E2M1 magnitude for a level index, via compare-select (no gather).

    levels [0, .5, 1, 1.5, 2, 3, 4, 6] == idx/2 for idx<4, idx-2 for
    idx in {4,5,6}, and 6 for idx==7.  Bitwise identical to a
    ``FP4_LEVELS[idx]`` table gather (all values exact in f32).
    """
    idxf = idx.astype(jnp.float32)
    hi = jnp.where(idxf == 7.0, 6.0, idxf - 2.0)
    return jnp.where(idxf < 4.0, 0.5 * idxf, hi)


def fp4_round(x: jax.Array) -> jax.Array:
    """Round to the nearest E2M1-representable value. Any shape, f32 math."""
    xf = x.astype(jnp.float32)
    return jnp.sign(xf) * fp4_level(fp4_index(jnp.abs(xf)))


def fp4_code(x: jax.Array) -> jax.Array:
    """4-bit code: bit3 = sign, bits0..2 = level index. uint8 in [0,15]."""
    xf = x.astype(jnp.float32)
    idx = fp4_index(jnp.abs(xf))
    sign = (xf < 0).astype(jnp.int32)
    return (sign * 8 + idx).astype(jnp.uint8)


def decode_level(code: jax.Array) -> jax.Array:
    """Signed E2M1 value from a 4-bit code (f32)."""
    idx = (code & 7).astype(jnp.int32)
    sign = 1.0 - 2.0 * ((code >> 3) & 1).astype(jnp.float32)
    return sign * fp4_level(idx)


def e4m3_round(x: jax.Array) -> jax.Array:
    """Round-to-nearest-even onto FP8 E4M3 (±448, denormals at 2^-9)."""
    xf = x.astype(jnp.float32)
    mag = jnp.clip(jnp.abs(xf), 0.0, E4M3_MAX)
    # exponent of the representation bucket; denormal floor at 2^-6
    e = jnp.floor(jnp.log2(jnp.maximum(mag, 1e-38)))
    e = jnp.clip(e, -6.0, 8.0)
    ulp = jnp.exp2(e - 3.0)                    # 3 mantissa bits
    q = jnp.round(mag / ulp) * ulp
    # rounding up may bump the exponent (e.g. 1.9375 -> 2.0): representable.
    q = jnp.where(mag == 0.0, 0.0, jnp.minimum(q, E4M3_MAX))
    return jnp.sign(xf) * q


def fake_quant_a4(x: jax.Array, group: int = GROUP) -> jax.Array:
    """Activation NVFP4 fake-quant with *dynamic* per-group scales.

    Groups of ``group`` along the last axis; local scale = amax/6 kept in
    exact f32 (activations are quantized on the fly, so there is no E4M3
    storage constraint — this matches the kernels and ``ref.fp4_matmul_ref``,
    not the PTQ weight recipe).  Returns f32; callers cast as needed.
    Works for any leading shape; last axis must divide by ``group``.
    """
    xf = x.astype(jnp.float32)
    shape = xf.shape
    xg = xf.reshape(shape[:-1] + (shape[-1] // group, group))
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    gs = jnp.maximum(amax / FP4_MAX, 1e-20)       # dynamic per-group scale
    q = jnp.sign(xg / gs) * fp4_level(fp4_index(jnp.abs(xg / gs))) * gs
    return q.reshape(shape)
