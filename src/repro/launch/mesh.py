"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialisation and only then builds meshes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.configs.base import MeshConfig, MULTI_POD_MESH, SINGLE_POD_MESH


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16×16 single-pod (256 chips) or
    2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def mesh_for(kind: str):
    if kind in ("single", "single_pod"):
        return make_production_mesh(multi_pod=False)
    if kind in ("multi", "multi_pod"):
        return make_production_mesh(multi_pod=True)
    if kind == "host":  # whatever the host actually has (tests/examples)
        n = len(jax.devices())
        return jax.make_mesh((1, n), ("data", "model"))
    raise ValueError(f"unknown mesh kind {kind!r}")


def mesh_config_for(kind: str) -> MeshConfig:
    return MULTI_POD_MESH if kind in ("multi", "multi_pod") \
        else SINGLE_POD_MESH
