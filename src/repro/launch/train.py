"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --preset tiny --steps 200 --ckpt-dir /tmp/ckpt

Wires together: config → model init → (optional) mesh + shardings →
AdamW → deterministic data pipeline → fault-tolerant loop (async
checkpoints, NaN guard, restart).  ``--preset tiny`` trains the reduced
same-family config on CPU; ``--preset full`` is the production entry
(requires a real TPU slice).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ReaLBConfig, TrainConfig, get_config, reduced
from repro.core import ep_moe
from repro.data.pipeline import DataConfig, DataLoader
from repro.launch.mesh import mesh_for
from repro.models import transformer as tf
from repro.models.common import current_mesh, use_mesh
from repro.optim import adamw
from repro.runtime.fault_tolerance import TrainLoop


def build(arch: str, preset: str, batch: int, seq: int, tcfg: TrainConfig,
          rcfg: ReaLBConfig, mesh=None):
    cfg = get_config(arch)
    if preset == "tiny":
        cfg = reduced(cfg)
    params = tf.init_model(cfg, jax.random.PRNGKey(tcfg.seed))
    opt = adamw.init_opt_state(params, tcfg)
    groups, ep = ep_moe.moe_state_shape(mesh, batch)
    m_state = jnp.full((groups, ep), rcfg.md_init, jnp.float32)

    def step_fn_inner(params, opt, m_state, batch):
        (loss, (m2, metrics)), g = jax.value_and_grad(
            tf.train_loss, has_aux=True)(params, cfg, rcfg, batch, m_state)
        params, opt, om = adamw.adamw_update(params, g, opt, tcfg)
        return params, opt, m2, {**metrics, **om, "loss": loss}

    jstep = jax.jit(step_fn_inner, donate_argnums=(0, 1))

    def step_fn(state, np_batch):
        b = {k: jnp.asarray(v) for k, v in np_batch.items()}
        if cfg.family == "vlm" and "vision_embeds" not in b:
            b["vision_embeds"] = jnp.zeros(
                (batch, cfg.n_vision_tokens, cfg.d_model), cfg.param_dtype)
        if cfg.is_encdec and "enc_embeds" not in b:
            b["enc_embeds"] = jnp.zeros(
                (batch, cfg.enc_seq_len, cfg.d_model), cfg.param_dtype)
        params, opt, m2, metrics = jstep(state["params"], state["opt"],
                                         state["m"], b)
        metrics = {k: float(v) for k, v in metrics.items()}
        return {"params": params, "opt": opt, "m": m2}, metrics

    state = {"params": params, "opt": opt, "m": m_state}
    return cfg, state, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single_pod", "multi_pod"])
    ap.add_argument("--multimodal", action="store_true")
    args = ap.parse_args(argv)

    mesh = None if args.mesh == "none" else mesh_for(args.mesh)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                       total_steps=args.steps,
                       checkpoint_every=args.checkpoint_every)
    rcfg = ReaLBConfig()

    with use_mesh(mesh):
        cfg, state, step_fn = build(args.arch, args.preset, args.batch,
                                    args.seq, tcfg, rcfg, mesh)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch, seed=tcfg.seed)
        loop = TrainLoop(step_fn, ckpt_dir=args.ckpt_dir,
                         checkpoint_every=args.checkpoint_every)
        start, state = loop.restore_or_init(state)
        data = DataLoader(dc, multimodal=args.multimodal,
                          d_model=cfg.d_model if args.multimodal else 0,
                          start_step=start)
        t0 = time.perf_counter()
        state = loop.run(state, data, args.steps, start_step=start)
        dt = time.perf_counter() - t0
        print(f"done: {args.steps - start} steps in {dt:.1f}s "
              f"({cfg.param_count()/1e6:.1f}M params)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
