"""Step functions (train / prefill / decode) + abstract input specs.

``input_specs(cfg, shape, mesh)`` returns ShapeDtypeStruct stand-ins for
every model input — weak-type-correct, sharded, zero device allocation —
which is what both the multi-pod dry-run and the roofline analysis lower
against.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ReaLBConfig, ShapeConfig,
                                TrainConfig)
from repro.core import ep_moe
from repro.models import transformer as tf
from repro.models.common import named_sharding, use_mesh
from repro.optim import adamw

Tree = Any


def _sds(shape, dtype, axes, mesh):
    sh = named_sharding(shape, axes, mesh) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """Abstract input batch for one (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        out = {
            "tokens": _sds((b, 1), "int32", ("batch", None), mesh),
            "pos": _sds((b,), "int32", ("batch",), mesh),
            "modality": _sds((b, 1), "bool", ("batch", None), mesh),
        }
        return out
    out = {
        "tokens": _sds((b, s), "int32", ("batch", "seq"), mesh),
        "modality": _sds((b, s), "bool", ("batch", "seq"), mesh),
    }
    if shape.kind == "train":
        out["labels"] = _sds((b, s), "int32", ("batch", "seq"), mesh)
    if cfg.family == "vlm":
        out["vision_embeds"] = _sds((b, cfg.n_vision_tokens, cfg.d_model),
                                    cfg.param_dtype, ("batch", None, None),
                                    mesh)
    if cfg.is_encdec:
        out["enc_embeds"] = _sds((b, cfg.enc_seq_len, cfg.d_model),
                                 cfg.param_dtype, ("batch", None, None),
                                 mesh)
    return out


def m_state_spec(cfg: ModelConfig, shape: ShapeConfig, mesh):
    groups, ep = ep_moe.moe_state_shape(mesh, shape.global_batch)
    axes = (None, "model") if groups == 1 else ("batch", "model")
    return _sds((groups, ep), "float32", axes, mesh)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """All abstract inputs for the cell's step function."""
    with use_mesh(mesh):
        specs: Dict[str, Any] = {
            "params": tf.abstract_model(cfg),
            "m_state": m_state_spec(cfg, shape, mesh),
            "batch": batch_specs(cfg, shape, mesh),
        }
        if shape.kind == "decode":
            specs["cache"] = tf.abstract_cache(cfg, shape.global_batch,
                                               shape.seq_len)
        if shape.kind == "train":
            specs["opt_state"] = adamw.abstract_opt_state(
                specs["params"], TrainConfig())
    return specs


# --------------------------------------------------------------------------
# step functions (pure; cfg/rcfg/tcfg closed over statically)
# --------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, rcfg: ReaLBConfig, tcfg: TrainConfig):
    def train_step(params, opt_state, m_state, batch):
        (loss, (m_new, metrics)), grads = jax.value_and_grad(
            tf.train_loss, has_aux=True)(params, cfg, rcfg, batch, m_state)
        params, opt_state, opt_metrics = adamw.adamw_update(
            params, grads, opt_state, tcfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, m_new, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rcfg: ReaLBConfig,
                      cache_len: int = 0):
    def prefill_step(params, m_state, batch):
        res = tf.prefill_forward(params, cfg, rcfg, batch, m_state,
                                 cache_len=cache_len)
        return res.logits, res.cache, res.m_state

    return prefill_step


def make_serve_step(cfg: ModelConfig, rcfg: ReaLBConfig):
    def serve_step(params, cache, m_state, batch):
        res = tf.decode_forward(params, cfg, rcfg, batch, cache, m_state)
        return res.logits, res.cache, res.m_state

    return serve_step


def build_step(cfg: ModelConfig, shape: ShapeConfig, rcfg: ReaLBConfig,
               tcfg: Optional[TrainConfig] = None):
    """(step_fn, example_args_builder) for a cell; args order is fixed."""
    tcfg = tcfg or TrainConfig()
    if shape.kind == "train":
        step = make_train_step(cfg, rcfg, tcfg)
        arg_names = ("params", "opt_state", "m_state", "batch")
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, rcfg, cache_len=shape.seq_len)
        arg_names = ("params", "m_state", "batch")
    else:
        step = make_serve_step(cfg, rcfg)
        arg_names = ("params", "cache", "m_state", "batch")
    return step, arg_names


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               rcfg: Optional[ReaLBConfig] = None,
               tcfg: Optional[TrainConfig] = None,
               donate: bool = True):
    """jit-lower one (arch × shape × mesh) cell against abstract inputs."""
    rcfg = rcfg or ReaLBConfig()
    step, arg_names = build_step(cfg, shape, rcfg, tcfg)
    specs = input_specs(cfg, shape, mesh)
    args = [specs[n] for n in arg_names]
    donate_argnums = tuple(i for i, n in enumerate(arg_names)
                           if donate and n in ("params", "opt_state",
                                               "cache"))
    with use_mesh(mesh):
        jitted = jax.jit(step, donate_argnums=donate_argnums)
        lowered = jitted.lower(*args)
    return lowered
