"""HLO-text cost analyzer for the dry-run roofline.

XLA:CPU's ``compiled.cost_analysis()`` visits each ``while`` body once and
does not scale by trip count, so a scan-over-layers model under-reports
flops/bytes by ~n_layers.  This analyzer walks the post-optimization HLO
call graph (the compiled per-device module, SPMD-partitioned shapes),
multiplying loop bodies by ``known_trip_count`` from the scheduler's
backend_config, and accounts:

* **flops** — every ``dot`` (2 · prod(out) · prod(contracting dims)),
  including dots inside fusions / nested loops / conditional branches,
* **traffic** — per-instruction HBM proxy: output bytes + operand bytes
  for every materialising op (fusions count boundary IO only; bitcasts,
  tuples and GTEs are free),
* **collective bytes** — output-shape bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (per-device shapes),
  with a per-kind breakdown.

``conditional`` branches take the elementwise max (conservative: the
ReaLB precision branches have compute ≤ the BF16 branch).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_OP_RE = re.compile(r" ([a-z][a-z0-9\-]*)\(")

_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "opt-barrier", "domain", "custom-call",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

#: jaxpr collective primitive names, as they appear as the leaf of an
#: op_name metadata name stack when the *traced program* issued the
#: collective (vs. partitioner-inserted resharding collectives)
_USER_COLL_PRIMS = ("psum", "all_to_all", "ppermute", "all_gather",
                    "reduce_scatter", "pmax", "pmin")


@dataclass
class Instr:
    name: str
    op: str
    out_bytes: int
    out_elems: int
    out_dims: Tuple[int, ...]
    operands: List[str]
    attrs: str


@dataclass
class Cost:
    # byte counts are exact integers end-to-end (shapes x trip counts);
    # only the flop count stays float (it can exceed 2**53 on big models)
    flops: float = 0.0
    traffic: int = 0
    coll: int = 0
    coll_by_kind: Dict[str, int] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.traffic += o.traffic
        self.coll += o.coll
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        return self

    def scaled(self, m: int) -> "Cost":
        return Cost(self.flops * m, self.traffic * m, self.coll * m,
                    {k: v * m for k, v in self.coll_by_kind.items()})

    def emax(self, o: "Cost") -> "Cost":
        kinds = set(self.coll_by_kind) | set(o.coll_by_kind)
        return Cost(max(self.flops, o.flops), max(self.traffic, o.traffic),
                    max(self.coll, o.coll),
                    {k: max(self.coll_by_kind.get(k, 0),
                            o.coll_by_kind.get(k, 0)) for k in kinds})


def _shape_info(text: str) -> Tuple[int, int, Tuple[int, ...]]:
    """(bytes, elems, dims-of-first-shape) of all shapes in `text`."""
    total_b = 0
    total_e = 0
    first_dims: Tuple[int, ...] = ()
    for i, m in enumerate(_SHAPE_RE.finditer(text)):
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        n = 1
        for d in dims:
            n *= d
        total_b += n * _DTYPE_BYTES[m.group(1)]
        total_e += n
        if i == 0:
            first_dims = dims
    return total_b, total_e, first_dims


def _balanced(s: str, start: int) -> Tuple[str, int]:
    """Return the contents of the paren group starting at s[start]=='('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1:i], i + 1
    return s[start + 1:], len(s)


def parse_module(hlo: str) -> Tuple[Dict[str, Dict[str, Instr]], str]:
    """-> ({computation: {instr_name: Instr}}, entry_name)."""
    comps: Dict[str, Dict[str, Instr]] = {}
    order: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry = ""
    for raw in hlo.splitlines():
        if not raw:
            continue
        if not raw[0].isspace():
            m = re.match(r"(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$", raw)
            if m:
                cur = m.group(2)
                comps[cur] = {}
                order[cur] = []
                if m.group(1):
                    entry = cur
            elif raw.startswith("}"):
                cur = None
            continue
        if cur is None or " = " not in raw:
            continue
        s = raw.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        if not s.startswith("%"):
            continue
        name, rhs = s.split(" = ", 1)
        name = name.strip().lstrip("%")
        om = _OP_RE.search(rhs)
        if om is None:
            continue
        op = om.group(1)
        shape_txt = rhs[:om.start()]
        out_b, out_e, out_dims = _shape_info(shape_txt)
        args, end = _balanced(rhs, om.end() - 1)
        operands = re.findall(r"%([\w.\-]+)", args)
        attrs = rhs[end:]
        comps[cur][name] = Instr(name, op, out_b, out_e, out_dims,
                                 operands, attrs)
    return comps, entry


def _dot_flops(ins: Instr, defs: Dict[str, Instr]) -> float:
    out_elems = ins.out_elems
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    k = 1
    if m and ins.operands:
        lhs = defs.get(ins.operands[0])
        if lhs is not None:
            for d in filter(None, m.group(1).split(",")):
                di = int(d)
                if di < len(lhs.out_dims):
                    k *= lhs.out_dims[di]
    return 2.0 * out_elems * k


_TRIP_RE = re.compile(r'"known_trip_count":\s*\{"n":"(\d+)"\}')


def _called(attrs: str, key: str) -> List[str]:
    m = re.search(key + r"=%([\w.\-]+)", attrs)
    return [m.group(1)] if m else []


def analyze(hlo: str) -> Dict[str, float]:
    comps, entry = parse_module(hlo)
    memo: Dict[str, Cost] = {}

    def comp_cost(cname: str) -> Cost:
        if cname in memo:
            return memo[cname]
        memo[cname] = Cost()  # cycle guard
        defs = comps.get(cname, {})
        c = Cost()
        for ins in defs.values():
            if ins.op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                for key in ("body", "condition"):
                    for callee in _called(ins.attrs, key):
                        c += comp_cost(callee).scaled(trip)
            elif ins.op == "conditional":
                branches: List[str] = []
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if bm:
                    branches = re.findall(r"%([\w.\-]+)", bm.group(1))
                else:
                    branches = (_called(ins.attrs, "true_computation")
                                + _called(ins.attrs, "false_computation"))
                if branches:
                    bc = comp_cost(branches[0])
                    for b in branches[1:]:
                        bc = bc.emax(comp_cost(b))
                    c += bc
            elif ins.op in ("fusion", "call", "custom-call", "map",
                            "reduce", "reduce-window", "sort", "scatter",
                            "select-and-scatter"):
                for callee in (_called(ins.attrs, "calls")
                               + _called(ins.attrs, "to_apply")):
                    sub = comp_cost(callee)
                    c.flops += sub.flops        # dots inside fusions
                    c.coll += sub.coll
                    for k, v in sub.coll_by_kind.items():
                        c.coll_by_kind[k] = c.coll_by_kind.get(k, 0) + v
                io = ins.out_bytes + sum(
                    defs[o].out_bytes for o in ins.operands if o in defs)
                c.traffic += io
            elif ins.op == "dot":
                c.flops += _dot_flops(ins, defs)
                c.traffic += ins.out_bytes + sum(
                    defs[o].out_bytes for o in ins.operands if o in defs)
            elif ins.op in ("dynamic-slice", "gather"):
                # reads only the extracted region (+ writes it)
                c.traffic += 2 * ins.out_bytes
            elif ins.op in ("dynamic-update-slice", "scatter"):
                # XLA updates these in place inside loop bodies (aliased
                # buffers): traffic = the update region, not the operand
                upd = (defs[ins.operands[1]].out_bytes
                       if len(ins.operands) > 1 and ins.operands[1] in defs
                       else ins.out_bytes)
                c.traffic += 2 * upd
            elif any(ins.op.startswith(k) for k in _COLLECTIVES):
                kind = next(k for k in _COLLECTIVES if ins.op.startswith(k))
                if ins.op.endswith("-done"):
                    continue  # counted at -start
                b = max(ins.out_bytes, sum(
                    defs[o].out_bytes for o in ins.operands if o in defs))
                c.coll += b
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0) + b
                c.traffic += ins.out_bytes
            elif ins.op in _SKIP_TRAFFIC:
                continue
            else:
                c.traffic += ins.out_bytes + sum(
                    defs[o].out_bytes for o in ins.operands if o in defs)
        memo[cname] = c
        return c

    total = comp_cost(entry)
    return {
        "flops": total.flops,
        "traffic_bytes": total.traffic,
        "collective_bytes": total.coll,
        "collective_by_kind": dict(sorted(total.coll_by_kind.items())),
    }


def _comp_multipliers(comps: Dict[str, Dict[str, Instr]],
                      entry: str) -> Dict[str, int]:
    """Execution multiplier per computation reachable from `entry`:
    the product of enclosing `while` trip counts (known_trip_count)."""
    mult: Dict[str, int] = {entry: 1}
    stack = [entry]
    while stack:
        cname = stack.pop()
        m = mult[cname]
        for ins in comps.get(cname, {}).values():
            trip = 1
            tm = _TRIP_RE.search(ins.attrs)
            if tm:
                trip = int(tm.group(1))
            for key in ("body", "condition", "calls", "to_apply",
                        "true_computation", "false_computation"):
                for callee in _called(ins.attrs, key):
                    factor = m * (trip if ins.op == "while" else 1)
                    if mult.get(callee, 0) < factor:
                        mult[callee] = factor
                        stack.append(callee)
            bm = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
            if bm:
                for callee in re.findall(r"%([\w.\-]+)", bm.group(1)):
                    if mult.get(callee, 0) < m:
                        mult[callee] = m
                        stack.append(callee)
    return mult


def _coll_kind(op: str) -> Optional[str]:
    if op.endswith("-done"):
        return None  # async pair: counted at -start
    for k in _COLLECTIVES:
        if op.startswith(k):
            return k
    return None


def collective_census(hlo: str) -> Dict:
    """Post-XLA collective census: per-kind instruction counts and
    payload bytes (per-device shapes, `while` trip counts multiplied
    through), split into the steady-state per-layer body (instructions
    inside a known-trip-count loop) and one-off collectives outside it.

    Returns ``{"total": {kind: {count, bytes}},
               "user": {kind: {count, bytes}},        # see below
               "per_layer": {kind: {count, bytes}},   # one trip's worth
               "outside": {kind: {count, bytes}},
               "layers": L}``.

    ``user`` restricts to instructions whose ``op_name`` metadata (the
    jax name stack) ends in a collective *primitive* — collectives the
    traced program issued, as opposed to all-reduces the SPMD
    partitioner inserts to resolve shardings.  The jaxpr-level census
    (:func:`repro.analysis.jaxpr_audit.collective_census_jaxpr`) and the
    :meth:`FlopByteLedger.predict_graph_census` prediction reconcile
    against ``user`` (counts may shrink where XLA merges or hoists
    loop-invariant psums; bytes must agree within a small tolerance).
    """
    comps, entry = parse_module(hlo)
    mult = _comp_multipliers(comps, entry)
    total: Dict[str, Dict[str, int]] = {}
    user: Dict[str, Dict[str, int]] = {}
    per_layer: Dict[str, Dict[str, int]] = {}
    outside: Dict[str, Dict[str, int]] = {}
    layers = 0

    def bump(table, kind, count, nbytes):
        ent = table.setdefault(kind, {"count": 0, "bytes": 0})
        ent["count"] += count
        ent["bytes"] += nbytes

    for cname, defs in comps.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue  # unreachable from entry
        for ins in defs.values():
            kind = _coll_kind(ins.op)
            if kind is None:
                continue
            b = max(ins.out_bytes, sum(
                defs[o].out_bytes for o in ins.operands if o in defs))
            bump(total, kind, m, b * m)
            nm = re.search(r'op_name="([^"]*)"', ins.attrs)
            leaf = nm.group(1).rsplit("/", 1)[-1] if nm else ""
            if any(leaf.startswith(p) for p in _USER_COLL_PRIMS):
                bump(user, kind, m, b * m)
            if m > 1:
                layers = max(layers, m)
                bump(per_layer, kind, 1, b)
            else:
                bump(outside, kind, 1, b)
    return {"total": total, "user": user, "per_layer": per_layer,
            "outside": outside, "layers": layers}


def top_collectives(hlo: str, n: int = 12) -> List[Dict]:
    """The n largest collective instructions (with loop multipliers) —
    the §Perf iteration starts from this list."""
    comps, entry = parse_module(hlo)
    mult = _comp_multipliers(comps, entry)
    out = []
    for cname, defs in comps.items():
        for ins in defs.values():
            if _coll_kind(ins.op) is not None:
                b = max(ins.out_bytes, sum(
                    defs[o].out_bytes for o in ins.operands if o in defs))
                meta = re.search(r'op_name="([^"]*)"', ins.attrs)
                out.append({
                    "op": ins.op, "bytes": b,
                    "mult": mult.get(cname, 1),
                    "total": b * mult.get(cname, 1),
                    "where": (meta.group(1) if meta else cname)[:140],
                })
    out.sort(key=lambda r: -r["total"])
    return out[:n]


def top_traffic(hlo: str, n: int = 15) -> List[Dict]:
    """The n largest memory-traffic instructions (with loop multipliers)."""
    comps, entry = parse_module(hlo)
    mult = _comp_multipliers(comps, entry)
    rows = []
    for cname, defs in comps.items():
        for ins in defs.values():
            if ins.op in _SKIP_TRAFFIC or ins.op == "dot":
                if ins.op != "dot":
                    continue
            io = ins.out_bytes + sum(
                defs[o].out_bytes for o in ins.operands if o in defs)
            meta = re.search(r'op_name="([^"]*)"', ins.attrs)
            rows.append({"op": ins.op, "bytes": io,
                         "mult": mult.get(cname, 1),
                         "total": io * mult.get(cname, 1),
                         "where": (meta.group(1) if meta else cname)[-130:]})
    rows.sort(key=lambda r: -r["total"])
    return rows[:n]
