"""Roofline terms + hardware constants (TPU v5e).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  Terms are reported in seconds-per-step using per-device quantities:

    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

``collective_bytes`` is not in ``cost_analysis()`` — we parse the
post-SPMD-partitioning HLO (``compiled.as_text()``, per-device shapes) and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute ops.  Ops inside loop bodies (``lax.scan`` over
layers) are multiplied by the trip count of the enclosing while loop,
recovered from the loop condition constant.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.configs.base import MIGRATION_BW_DEFAULT
from repro.configs.hw import HBM_BW, PEAK_FLOPS  # single-sourced (v5e)

ICI_BW = MIGRATION_BW_DEFAULT  # B/s / link — same constant the cost
                               # gates and migration planner price at

def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    comp = flops_per_dev / PEAK_FLOPS
    mem = bytes_per_dev / HBM_BW
    coll = coll_bytes_per_dev / ICI_BW
    dominant = max(("compute", comp), ("memory", mem),
                   ("collective", coll), key=lambda kv: kv[1])[0]
    total = max(comp, mem, coll)
    return {
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": (comp / total) if total > 0 else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """6·N_active·D forward(+backward) reference flops (global)."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch          # one token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + 2x bwd
    return 2.0 * n * tokens * mult
