"""Serving driver: batched multimodal requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch moonshot-v1-16b-a3b \
        --preset tiny --requests 12 --max-new 8

Generates synthetic multimodal requests (vision-prefix prompts with the
paper's skewed modality mix), runs the continuous-batching engine with
ReaLB live, and reports throughput + per-iteration balance stats.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ReaLBConfig, get_config, reduced
from repro.launch.mesh import mesh_for
from repro.models import transformer as tf
from repro.models.common import use_mesh
from repro.serving.engine import Engine
from repro.serving.scheduler import Request


def make_requests(cfg, n: int, rng, max_new: int, max_prompt: int):
    reqs = []
    for i in range(n):
        p_len = int(rng.integers(8, max_prompt))
        vis_frac = float(np.clip(rng.normal(0.6, 0.3), 0.0, 0.9))
        n_vis = int(p_len * vis_frac)
        toks = rng.integers(0, cfg.vocab_size, p_len).astype(np.int32)
        toks[:n_vis] = (cfg.vocab_size // 2
                        + toks[:n_vis] % (cfg.vocab_size // 2))
        modality = np.arange(p_len) < n_vis
        reqs.append(Request(uid=i, tokens=toks, modality=modality,
                            max_new_tokens=max_new))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=40)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single_pod", "multi_pod"])
    ap.add_argument("--gate-gamma", type=int, default=8,
                    help="LB gate Γ (small default so tiny runs exercise it)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    mesh = None if args.mesh == "none" else mesh_for(args.mesh)
    rcfg = ReaLBConfig(gate_gamma=args.gate_gamma)

    with use_mesh(mesh):
        params = tf.init_model(cfg, jax.random.PRNGKey(0))
        max_len = args.max_prompt + args.max_new + 8
        eng = Engine(cfg, params, rcfg, max_slots=args.slots,
                     max_len=max_len)
        rng = np.random.default_rng(0)
        for r in make_requests(cfg, args.requests, rng, args.max_new,
                               args.max_prompt):
            eng.submit(r)
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0

    out_toks = sum(len(r.generated) for r in done)
    in_toks = sum(r.prompt_len for r in done)
    gates = [s.gate_open for s in eng.stats]
    print(f"served {len(done)} requests, {in_toks} prompt + {out_toks} "
          f"generated tokens in {dt:.2f}s "
          f"({(in_toks + out_toks) / dt:.1f} tok/s)")
    if eng.stats:
        print(f"iterations: {len(eng.stats)}, "
              f"mean IB_global={np.mean([s.ib_global for s in eng.stats]):.2f}, "
              f"gate-open frac={np.mean(gates):.2f}, "
              f"mean fp4 ranks={np.mean([s.fp4_ranks for s in eng.stats]):.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
