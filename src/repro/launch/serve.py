"""Serving driver: workload-generated multimodal requests through the
chunked-prefill engine.

    PYTHONPATH=src python -m repro.launch.serve --arch moonshot-v1-16b-a3b \
        --preset tiny --requests 12 --max-new 8

Synthesizes a request stream from a named workload profile (the same
calibration the trace benchmarks use), runs the continuous-batching engine
with ReaLB live, and reports throughput, TTFT/TPOT percentiles and
per-iteration balance stats.  ``benchmarks/serve_bench.py`` is the full
open-loop experiment (arrival processes, virtual clock, record/replay);
this driver is the quick interactive entry point.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ReaLBConfig, get_config, reduced
from repro.launch.mesh import mesh_for
from repro.models import transformer as tf
from repro.models.common import use_mesh
from repro.serving.engine import Engine
from repro.serving.telemetry import Telemetry
from repro.workloads import make_stream, profile
from repro.workloads.profiles import WORKLOADS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moonshot-v1-16b-a3b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--workload", default="MMMU", choices=sorted(WORKLOADS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=40)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-budget", type=int, default=256,
                    help="tokens of batched prefill per iteration "
                         "(0 = legacy one-shot per-request prefill)")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single_pod", "multi_pod"])
    ap.add_argument("--gate-gamma", type=int, default=8,
                    help="LB gate Γ (small default so tiny runs exercise it)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    mesh = None if args.mesh == "none" else mesh_for(args.mesh)
    rcfg = ReaLBConfig(gate_gamma=args.gate_gamma)

    prof = profile(args.workload,
                   prompt_len_mean=max(args.max_prompt * 2 // 3, 8),
                   prompt_len_std=args.max_prompt // 4,
                   prompt_len_min=8, prompt_len_max=args.max_prompt,
                   max_new_mean=args.max_new, max_new_min=args.max_new,
                   max_new_max=args.max_new)
    specs = make_stream(prof, np.zeros(args.requests), cfg.vocab_size,
                        seed=args.seed)

    with use_mesh(mesh):
        params = tf.init_model(cfg, jax.random.PRNGKey(0))
        max_len = args.max_prompt + args.max_new + 8
        telemetry = Telemetry()
        eng = Engine(cfg, params, rcfg, max_slots=args.slots,
                     max_len=max_len, prefill_budget=args.prefill_budget,
                     telemetry=telemetry)
        for spec in specs:
            req = spec.to_request()
            req.arrival_time = None    # stamp with the wall clock at submit
            eng.submit(req)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0

    out_toks = sum(len(r.generated) for r in done)
    in_toks = sum(r.prompt_len for r in done)
    print(f"served {len(done)} requests, {in_toks} prompt + {out_toks} "
          f"generated tokens in {dt:.2f}s "
          f"({(in_toks + out_toks) / dt:.1f} tok/s)")
    if eng.stats:
        s = telemetry.summary()
        gates = [st.gate_open for st in eng.stats]
        print(f"iterations: {len(eng.stats)} "
              f"(prefill chunked={eng.chunked}), "
              f"mean IB_global="
              f"{np.mean([st.ib_global for st in eng.stats]):.2f}, "
              f"gate-open frac={np.mean(gates):.2f}, "
              f"gate duty prefill={s['gate_duty_prefill']:.2f}, "
              f"mean fp4 ranks="
              f"{np.mean([st.fp4_ranks for st in eng.stats]):.2f}")
        if s["ttft"]:
            print(f"TTFT p50/p99: {s['ttft']['p50']:.3f}/"
                  f"{s['ttft']['p99']:.3f}s  "
                  f"TPOT p50: {s['tpot'].get('p50', float('nan')):.4f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
