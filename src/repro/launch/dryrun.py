import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against ShapeDtypeStruct inputs on the production meshes.

The two lines above MUST stay the first statements of this module — jax
locks the device count at first initialisation, and the dry-run (and only
the dry-run) needs 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
        --shape train_4k --mesh single_pod
    PYTHONPATH=src python -m repro.launch.dryrun --all   # subprocess/cell

Each cell writes a JSON record (memory analysis, cost analysis, collective
bytes, roofline terms) under experiments/dryrun/; --all skips cells whose
record already exists, so the sweep is resumable.
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             realb_overrides=None) -> dict:
    import jax

    from repro.configs import (ReaLBConfig, get_config, get_shape,
                               shape_supported)
    from repro.launch import hlo_analysis, roofline
    from repro.launch.mesh import mesh_for
    from repro.launch.steps import lower_cell

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "params": cfg.param_count(), "active_params":
           cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = mesh_for(mesh_kind)
    n_dev = mesh.devices.size
    rcfg = ReaLBConfig(**(realb_overrides or {}))
    t0 = time.perf_counter()
    lowered = lower_cell(cfg, shape, mesh, rcfg=rcfg)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits
    cost = compiled.cost_analysis()
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed")})

    hlo = compiled.as_text()
    # XLA:CPU cost_analysis does not scale while-loop bodies by trip count;
    # analyze the post-SPMD HLO ourselves (dots, fusion IO, collectives).
    an = hlo_analysis.analyze(hlo)
    flops_dev = float(an["flops"])
    bytes_dev = int(an["traffic_bytes"])
    coll_total = int(an["collective_bytes"])
    terms = roofline.roofline_terms(flops_dev, bytes_dev, coll_total)
    mf = roofline.model_flops(cfg, shape)
    hlo_total_flops = flops_dev * n_dev
    rec.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        memory=dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            alias_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
        ),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=int(coll_total),
        collective_by_kind={k: int(v) for k, v
                            in an["collective_by_kind"].items()},
        top_collectives=hlo_analysis.top_collectives(hlo, 8),
        top_traffic=hlo_analysis.top_traffic(hlo, 10),
        xla_cost_analysis={
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        roofline=terms,
        model_flops_global=mf,
        hlo_flops_global=hlo_total_flops,
        useful_flop_ratio=(mf / hlo_total_flops) if hlo_total_flops else 0.0,
        hlo_bytes_chars=len(hlo),
    )
    return rec


def _out_path(outdir: pathlib.Path, arch, shape, mesh, tag="") -> pathlib.Path:
    t = f".{tag}" if tag else ""
    return outdir / f"{arch}__{shape}__{mesh}{t}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    ap.add_argument("--all", action="store_true",
                    help="sweep all cells × both meshes, one subprocess each")
    ap.add_argument("--meshes", default="single_pod,multi_pod")
    ap.add_argument("--tag", default="", help="record suffix (perf variants)")
    ap.add_argument("--realb", default="",
                    help="comma k=v ReaLB overrides, e.g. overlap=False")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import all_cells
        cells = all_cells()
        rc = 0
        for arch, shape, ok, why in cells:
            for mesh in args.meshes.split(","):
                path = _out_path(outdir, arch, shape, mesh, args.tag)
                if path.exists() and not args.force:
                    continue
                if not ok:
                    path.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "skipped", "reason": why}, indent=1))
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh,
                       "--outdir", str(outdir)]
                if args.tag:
                    cmd += ["--tag", args.tag]
                if args.realb:
                    cmd += ["--realb", args.realb]
                print(f"=== {arch} × {shape} × {mesh} ===", flush=True)
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    rc = 1
        return rc

    overrides = {}
    for kv in filter(None, args.realb.split(",")):
        k, v = kv.split("=")
        overrides[k] = {"True": True, "False": False}.get(v) or (
            float(v) if "." in v else int(v))
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, overrides)
    except Exception as e:  # record the failure for the sweep report
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(rec["error"], file=sys.stderr)
    path = _out_path(pathlib.Path(args.outdir), args.arch, args.shape,
                     args.mesh, args.tag)
    path.write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=1))
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
