"""Runtime sentinel — the dynamic third of the invariant checker.

Two hot-loop properties that no functional test catches when they
regress:

* **Implicit device→host syncs.**  A stray ``float(jax_array)`` /
  ``int()`` / ``bool()`` inside the iteration blocks the Python thread
  on device completion and serializes dispatch.  The sentinel guards
  the engine's hot window two ways: it arms ``jax.transfer_guard``
  (authoritative on real accelerators, where device→host is a physical
  transfer) **and** it hooks ``jax.Array``'s host-materialisation seam
  (the ``_value`` cache property), which catches scalar coercions even
  on the CPU backend where arrays already live in host memory and the
  transfer guard never fires.  Sanctioned pull sites (sampling,
  telemetry/statistics reads) open a :meth:`Sentinel.sanctioned`
  window; anything else is recorded as a violation (or raised, under
  ``strict=True``).

  CPU-backend caveat (documented, deliberate): buffer-protocol reads
  (``np.asarray`` on a committed array) are zero-copy host loads on
  CPU and bypass ``_value``; on TPU/GPU they do go through the guarded
  transfer path.  The scalar-coercion class — the way accidental syncs
  are actually written — is caught on every backend.

* **Recompiles after warmup.**  Replans, table commits, elastic
  kill/rejoin and chunked-prefill buckets must all hit the jit cache.
  Entry points register with :meth:`register_entry`; after
  :meth:`mark_warm` every additional compilation (tracked via the
  jitted function's ``_cache_size``) is a violation.  A deliberate
  re-jit (the capacity-resize band) is declared with
  :meth:`note_rebuild` and reported separately.

The null object :data:`NULL_SENTINEL` follows the repo's tracer/
profiler discipline: ``enabled`` is False, every context manager is a
shared no-op, and an unsentineled engine is bitwise identical to one
predating this module.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

import jax

__all__ = ["Sentinel", "NULL_SENTINEL", "SyncViolation"]


@dataclasses.dataclass
class SyncViolation:
    where: str          # python source "file:line (function)"
    context: str        # engine phase label if known
    kind: str = "host_sync"


class _HostPullGuard:
    """Class-level hook on ``jax.Array``'s host materialisation.

    Patches ``ArrayImpl._value`` (the cached numpy view every scalar
    coercion — ``__float__`` / ``__int__`` / ``__bool__`` /
    ``.tolist()`` / ``jax.device_get`` — funnels through) with a
    thread-local armed/suspended flag.  Installed once per armed
    sentinel; always uninstalled on exit.
    """

    def __init__(self, on_violation: Callable[[], None]):
        self._on_violation = on_violation
        self._tls = threading.local()
        self._orig = None
        self._installed = False

    # thread-local depth counters: hot > 0 and sanctioned == 0 -> guarded
    def _depth(self, name: str) -> int:
        return getattr(self._tls, name, 0)

    def _bump(self, name: str, d: int) -> None:
        setattr(self._tls, name, self._depth(name) + d)

    def install(self) -> None:
        if self._installed:
            return
        from jax._src import array as _jarray
        impl = _jarray.ArrayImpl
        self._orig = impl.__dict__["_value"]
        orig_get = self._orig.fget if isinstance(self._orig, property) \
            else self._orig
        guard = self

        def guarded(self_arr):
            if guard._depth("hot") > 0 and guard._depth("sanctioned") == 0:
                guard._on_violation()
            return orig_get(self_arr)

        impl._value = property(guarded)
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        from jax._src import array as _jarray
        _jarray.ArrayImpl._value = self._orig
        self._installed = False

    @contextlib.contextmanager
    def hot(self):
        self._bump("hot", 1)
        try:
            yield
        finally:
            self._bump("hot", -1)

    @contextlib.contextmanager
    def sanctioned(self):
        self._bump("sanctioned", 1)
        try:
            yield
        finally:
            self._bump("sanctioned", -1)


def _caller_site(skip_prefixes=("repro/analysis", "jax/_src",
                                "site-packages/jax")) -> str:
    import traceback
    for frame in reversed(traceback.extract_stack(limit=24)[:-2]):
        fn = frame.filename.replace("\\", "/")
        if not any(p in fn for p in skip_prefixes):
            return f"{fn}:{frame.lineno} ({frame.name})"
    return "<unknown>"


class Sentinel:
    """Arms the transfer/recompile invariants around a serving run."""

    enabled = True

    def __init__(self, strict: bool = False):
        #: strict: raise on the first unsanctioned host pull instead of
        #: recording it (tests want the traceback; reports want totals)
        self.strict = strict
        self.violations: List[SyncViolation] = []
        self.sanctioned_pulls: Dict[str, int] = {}
        self.rebuilds: List[str] = []
        self._entries: Dict[str, List[Any]] = {}
        self._warm: Optional[Dict[str, int]] = None
        self._armed = False
        self._phase = ""
        self._guard = _HostPullGuard(self._record_violation)

    # -- arming ----------------------------------------------------------
    def __enter__(self):
        self.arm()
        return self

    def __exit__(self, *exc):
        self.disarm()
        return False

    def arm(self) -> None:
        if not self._armed:
            self._guard.install()
            self._armed = True

    def disarm(self) -> None:
        if self._armed:
            self._guard.uninstall()
            self._armed = False

    def _record_violation(self) -> None:
        v = SyncViolation(where=_caller_site(), context=self._phase)
        self.violations.append(v)
        if self.strict:
            raise RuntimeError(
                f"unsanctioned device->host sync inside the serving hot "
                f"loop at {v.where} (phase {v.context or '?'}): wrap a "
                "legitimate pull site in sentinel.sanctioned(label)")

    # -- transfer windows ------------------------------------------------
    @contextlib.contextmanager
    def hot(self, phase: str = "iter"):
        """The guarded window: one serving iteration's compute+dispatch.
        Also arms jax's own transfer guard — a no-op on CPU (host==device
        memory) but authoritative on real accelerators."""
        prev = self._phase
        self._phase = phase
        try:
            with jax.transfer_guard_device_to_host("disallow"):
                with self._guard.hot():
                    yield
        finally:
            self._phase = prev

    @contextlib.contextmanager
    def sanctioned(self, label: str):
        """A whitelisted pull site inside the hot window (sampling,
        telemetry reads, timing)."""
        self.sanctioned_pulls[label] = self.sanctioned_pulls.get(label, 0) + 1
        with jax.transfer_guard_device_to_host("allow"):
            with self._guard.sanctioned():
                yield

    # -- recompile accounting --------------------------------------------
    def register_entry(self, name: str, jitted: Any) -> None:
        """Track a jit entry point.  Re-registering the same name (an
        engine rebuild) keeps the old generation's compile counts — the
        total is cumulative across generations, so a rebuild's fresh
        compilations are visible post-warmup."""
        gens = self._entries.setdefault(name, [])
        if not any(g is jitted for g in gens):
            gens.append(jitted)

    def note_rebuild(self, reason: str) -> None:
        """A deliberate re-jit (e.g. the capacity-resize band)."""
        self.rebuilds.append(reason)

    def _compiles(self, name: str) -> int:
        total = 0
        for fn in self._entries.get(name, []):
            try:
                total += int(fn._cache_size())
            except Exception:
                pass
        return total

    def compile_counts(self) -> Dict[str, int]:
        return {n: self._compiles(n) for n in sorted(self._entries)}

    def mark_warm(self) -> Dict[str, int]:
        """End of warmup: snapshot per-entry compile counts.  Every
        compilation after this point is a recompile violation."""
        self._warm = self.compile_counts()
        return dict(self._warm)

    def post_warm_recompiles(self) -> Dict[str, int]:
        if self._warm is None:
            return {}
        now = self.compile_counts()
        return {n: now[n] - self._warm.get(n, 0) for n in now
                if now[n] - self._warm.get(n, 0) > 0}

    # -- report ----------------------------------------------------------
    @property
    def ok(self) -> bool:
        return not self.violations and not self.post_warm_recompiles()

    def report(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "violations": [dataclasses.asdict(v) for v in self.violations],
            "sanctioned_pulls": dict(sorted(self.sanctioned_pulls.items())),
            "compile_counts": self.compile_counts(),
            "warm_counts": dict(self._warm) if self._warm else None,
            "post_warm_recompiles": self.post_warm_recompiles(),
            "rebuilds": list(self.rebuilds),
        }


class _NullSentinel:
    """Shared no-op: an unsentineled engine pays nothing."""

    enabled = False
    strict = False
    violations: List[SyncViolation] = []
    rebuilds: List[str] = []

    _NULL_CTX = contextlib.nullcontext()

    def hot(self, phase: str = "iter"):
        return self._NULL_CTX

    def sanctioned(self, label: str):
        return self._NULL_CTX

    def register_entry(self, name: str, jitted: Any) -> None:
        pass

    def note_rebuild(self, reason: str) -> None:
        pass

    def mark_warm(self) -> Dict[str, int]:
        return {}

    def post_warm_recompiles(self) -> Dict[str, int]:
        return {}

    def compile_counts(self) -> Dict[str, int]:
        return {}

    @property
    def ok(self) -> bool:
        return True

    def report(self) -> Dict[str, Any]:
        return {"ok": True, "violations": [], "sanctioned_pulls": {},
                "compile_counts": {}, "warm_counts": None,
                "post_warm_recompiles": {}, "rebuilds": []}


NULL_SENTINEL = _NullSentinel()
