"""repro.analysis — hot-loop invariant checkers.

Three layers, one goal: ReaLB's "zero scheduling overhead" claim only
holds while the serving hot loop stays free of silent regressions — a
stray host sync, an f64 upcast, an extra collective, or a shape-driven
recompile after a replan would erase the fused-kernel win without any
functional test failing.  This package machine-checks those properties:

* :mod:`repro.analysis.lint` — AST lint over ``src/`` (RPL001–RPL007):
  repo-specific rules for traced-value coercion, hardware-constant
  single-sourcing, null-object hot-loop guards, staged-commit table
  discipline, integral byte accounting and clock hygiene.
* :mod:`repro.analysis.jaxpr_audit` — trace-time audit of the fused
  step's jaxpr: no callbacks on the hot path, no f64, widening
  ``convert_element_type`` on the FP4 path only via an allowlist, and a
  collective census (count + bytes of psum/all_to_all/ppermute per
  layer) that reconciles with the compiled-HLO census
  (:func:`repro.launch.hlo_analysis.collective_census`) and the
  :class:`repro.obs.ledger.FlopByteLedger` graph-level prediction.
* :mod:`repro.analysis.sentinel` — runtime sentinel the engine and
  ``serve_bench`` opt into: guards implicit device→host syncs inside
  iterations (sanctioned pull sites whitelisted) and counts jit cache
  misses per entry point, asserting zero recompiles after warmup.

``benchmarks/analysis_report.py`` runs all three on the FP4-active
profiled arm and emits a JSON invariant report (non-zero exit on any
violation); CI uploads it as the ``analysis`` job artifact.
"""
from repro.analysis.lint import Finding, lint_paths, lint_source

__all__ = [
    "AuditViolation", "audit_jaxpr", "collective_census_jaxpr",
    "Finding", "lint_paths", "lint_source",
    "Sentinel", "NULL_SENTINEL",
]

_LAZY = {
    "AuditViolation": "jaxpr_audit", "audit_jaxpr": "jaxpr_audit",
    "collective_census_jaxpr": "jaxpr_audit",
    "Sentinel": "sentinel", "NULL_SENTINEL": "sentinel",
}


def __getattr__(name):
    # jaxpr_audit/sentinel pull in jax; the lint CLI must not
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.analysis.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(name)
