"""Trace-time audit of the serving hot loop's jaxpr.

:func:`audit_jaxpr` walks a closed jaxpr (recursing through scan /
cond / while / pjit / shard_map sub-jaxprs) and enforces the invariants
ReaLB's "zero scheduling overhead" claim rests on:

* **no host callbacks** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` on the hot path would serialize every iteration on
  a device→host round trip;
* **no f64** — a stray Python float promoted to float64 doubles the
  bandwidth of whatever it touches and kicks the MXU off the fast path;
* **widening discipline** — every ``convert_element_type`` that widens
  a float (bf16→f32, anything→f64) inside the FP4 dispatch/expert
  phases must match an explicit allowlist (softmax, accumulators,
  norms, sub-byte dequant): an unlisted widening is usually a silently
  reintroduced BF16 round-trip the fused kernel PR removed.

:func:`collective_census_jaxpr` counts collective primitives
(``psum`` / ``all_to_all`` / ``ppermute`` / ``all_gather`` /
``reduce_scatter``) with per-participant payload bytes, multiplying
through ``scan`` trip counts.  The same census runs post-XLA over the
compiled HLO (:func:`repro.launch.hlo_analysis.collective_census`) and
both reconcile against the
:meth:`repro.obs.ledger.FlopByteLedger.predict_graph_census`
prediction — three independent derivations of the hot loop's ICI
traffic that must agree.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import core as jcore

#: primitive-name fragments that mean a host round trip
_CALLBACK_RE = re.compile(r"callback")

#: collective primitive names (jaxpr level)
COLLECTIVE_PRIMS = ("psum", "all_to_all", "ppermute", "all_gather",
                    "reduce_scatter", "pmax", "pmin", "axis_index")
_CENSUS_PRIMS = ("psum", "all_to_all", "ppermute", "all_gather",
                 "reduce_scatter")

#: default name-stack allowlist for widening converts: phases where a
#: float widening is the algorithm (f32 softmax/logits in `route`, f32
#: gate accumulation in `combine`, f32 norm statistics, attention
#: softmax, aux losses).  Matched against the eqn's full name stack.
DEFAULT_WIDEN_ALLOWLIST: Tuple[str, ...] = (
    "route", "combine", "norm", "attention", "aux", "softmax", "rope",
    "embed", "logits",
)


@dataclasses.dataclass
class AuditViolation:
    kind: str            # callback | f64 | widening
    primitive: str
    where: str           # name-stack / context
    detail: str

    def format(self) -> str:
        return f"[{self.kind}] {self.primitive} @ {self.where}: {self.detail}"


@dataclasses.dataclass
class AuditReport:
    violations: List[AuditViolation]
    n_eqns: int
    widenings: List[Dict[str, Any]]     # every float widening seen
    census: Dict[str, Dict[str, int]]   # collective census (count/bytes)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "n_eqns": self.n_eqns,
            "violations": [dataclasses.asdict(v) for v in self.violations],
            "widenings": self.widenings,
            "census": self.census,
        }


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    return n * np.dtype(dtype).itemsize


def _is_f64(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and np.dtype(dtype) in (np.float64,
                                                     np.complex128)


def _float_bits(dtype) -> Optional[int]:
    dt = np.dtype(dtype)
    # jax extended float types (bfloat16, f8/f4 variants) are ml_dtypes
    # customs with kind 'V': np.finfo rejects them, jnp.finfo does not
    if not jax.numpy.issubdtype(dt, jax.numpy.floating):
        return None
    try:
        return int(jax.numpy.finfo(dt).bits)
    except Exception:
        return dt.itemsize * 8


def _name_stack(eqn) -> str:
    try:
        return str(eqn.source_info.name_stack)
    except Exception:
        return ""


def _sub_jaxprs(eqn) -> List[Tuple[jcore.Jaxpr, int]]:
    """(sub_jaxpr, multiplier) pairs below one eqn."""
    out: List[Tuple[jcore.Jaxpr, int]] = []
    params = eqn.params
    mult = 1
    if eqn.primitive.name == "scan":
        mult = int(params.get("length", 1))
    for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                "fun_jaxpr"):
        sub = params.get(key)
        if sub is None:
            continue
        j = sub.jaxpr if isinstance(sub, jcore.ClosedJaxpr) else sub
        out.append((j, mult))
    for branch in params.get("branches", ()):  # lax.cond / switch
        j = branch.jaxpr if isinstance(branch, jcore.ClosedJaxpr) \
            else branch
        out.append((j, 1))
    return out


def _walk(jaxpr: jcore.Jaxpr, visit: Callable[[Any, int], None],
          mult: int = 1) -> None:
    """Depth-first over eqns; ``visit(eqn, mult)`` sees the product of
    enclosing scan trip counts."""
    for eqn in jaxpr.eqns:
        visit(eqn, mult)
        for sub, m in _sub_jaxprs(eqn):
            _walk(sub, visit, mult * m)


def audit_jaxpr(closed: jcore.ClosedJaxpr,
                widen_allowlist: Sequence[str] = DEFAULT_WIDEN_ALLOWLIST,
                widen_scopes: Sequence[str] = ("dispatch", "expert_gemm",
                                               "quantize_fp4"),
                allow_f64: bool = False) -> AuditReport:
    """Audit one traced step.

    ``widen_scopes``: name-stack fragments marking the FP4
    dispatch/expert path — float widenings there must match
    ``widen_allowlist`` (sub-byte → wider dequants are always legal:
    that *is* the FP4 mechanism).  Widenings to f64 are never legal.
    """
    violations: List[AuditViolation] = []
    widenings: List[Dict[str, Any]] = []
    census: Dict[str, Dict[str, int]] = {}
    n_eqns = 0

    def visit(eqn, mult: int):
        nonlocal n_eqns
        n_eqns += 1
        name = eqn.primitive.name
        stack = _name_stack(eqn)
        if _CALLBACK_RE.search(name):
            violations.append(AuditViolation(
                "callback", name, stack,
                "host callback on the hot path serializes every "
                "iteration on a device-host round trip"))
        if not allow_f64:
            for v in list(eqn.outvars) + list(eqn.invars):
                aval = getattr(v, "aval", None)
                if aval is not None and _is_f64(aval):
                    violations.append(AuditViolation(
                        "f64", name, stack,
                        f"float64 value of shape "
                        f"{getattr(aval, 'shape', ())}"))
                    break
        if name == "convert_element_type":
            self_bits = _convert_bits(eqn)
            if self_bits is not None:
                src_bits, dst_bits, src_dt, dst_dt = self_bits
                if dst_bits > src_bits:
                    entry = {"src": str(src_dt), "dst": str(dst_dt),
                             "where": stack}
                    widenings.append(entry)
                    on_fp4_path = any(s in stack for s in widen_scopes)
                    allowed = (
                        src_bits <= 8       # sub-byte/f8 dequant widen
                        or any(a in stack for a in widen_allowlist))
                    if on_fp4_path and not allowed:
                        violations.append(AuditViolation(
                            "widening", name, stack,
                            f"{src_dt} -> {dst_dt} widening on the FP4 "
                            "dispatch/expert path is not on the "
                            "allowlist"))
        if name in _CENSUS_PRIMS or any(
                name.startswith(p + "_") for p in _CENSUS_PRIMS):
            kind = next((p for p in _CENSUS_PRIMS
                         if name == p or name.startswith(p + "_")), name)
            out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            in_b = sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
            b = max(out_b, in_b)
            ent = census.setdefault(kind, {"count": 0, "bytes": 0})
            ent["count"] += mult
            ent["bytes"] += b * mult

    _walk(closed.jaxpr, visit)
    return AuditReport(violations=violations, n_eqns=n_eqns,
                       widenings=widenings, census=census)


def _convert_bits(eqn):
    """(src_bits, dst_bits, src_dtype, dst_dtype) of a float->float
    convert_element_type, else None."""
    if not eqn.invars:
        return None
    src_aval = getattr(eqn.invars[0], "aval", None)
    if src_aval is None:
        return None
    src_dt = getattr(src_aval, "dtype", None)
    dst_dt = eqn.params.get("new_dtype")
    if src_dt is None or dst_dt is None:
        return None
    sb, db = _float_bits(src_dt), _float_bits(dst_dt)
    if sb is None or db is None:
        return None
    return sb, db, src_dt, dst_dt


def collective_census_jaxpr(closed: jcore.ClosedJaxpr
                            ) -> Dict[str, Dict[str, int]]:
    """Collective census alone: {prim: {count, bytes}} with per-
    participant payload bytes, scan trip counts multiplied through."""
    return audit_jaxpr(closed, allow_f64=True).census
