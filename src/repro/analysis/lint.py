"""Repo-specific AST lint — the static third of the invariant checker.

Rules (each guards an invariant the serving hot loop depends on):

RPL001  No ``float()`` / ``int()`` / ``bool()`` / ``np.*`` coercion of
        traced (``jnp``/``jax``-rooted) values in ``core/`` / ``models/``
        / ``kernels/``: a host coercion inside traced code either fails
        at trace time or, worse, silently bakes a Python constant into
        the jaxpr.  (Dynamic complement: the runtime sentinel.)
RPL002  No Python ``if``/``while`` on ``jnp`` values in the same
        directories — data-dependent Python control flow forces a trace
        break; use ``lax.cond``/``jnp.where`` or a static argument.
RPL003  Hardware constants are single-sourced in ``repro.configs.hw`` /
        ``repro.configs.base``: a numeric literal ≥ 1e9 (bandwidth /
        flops magnitude) anywhere else is a drift-prone fork of the
        roofline the cost gates price migrations with.
RPL004  Null-object hot-loop guard: tracer/profiler annotation calls
        (``.instant`` / ``.complete`` / ``.observe_iter``) must sit
        under an ``enabled`` check — the null objects make unguarded
        *span* construction free, but annotation argument packing is
        per-iteration Python work the guard elides.
RPL005  Routable tables mutate only through the staged-commit API
        (``commit`` / ``commit_layers``): direct assignment to
        ``.tables`` / ``.rsets`` outside the managers desynchronizes
        serving from the migration protocol.
RPL006  Byte accounting stays integral: migration budgets, slab sizes
        and transfer counters are exact ``int`` end-to-end; a float
        creeping in (literal, true division, ``float()``) rounds a
        commit boundary.  Analytic roofline estimates are exempt
        (``obs/ledger.py``) — sub-byte FP4 weights price at 4.25
        bits/weight by design.
RPL007  ``time.time()`` only in clock/bandwidth modules: interval
        measurements elsewhere must use the injected engine clock or
        ``time.perf_counter()`` — wall clock is not monotonic and
        breaks the virtual-clock determinism CI relies on.

Escape hatch: append ``# repro-lint: disable=RPL00x`` (comma-separated
for several rules) to the offending line; suppressed findings are still
collected and reported separately.  Suppressions are expected to carry a
justification in the surrounding comment.

CLI: ``python -m repro.analysis.lint <paths> [--json] [--show-suppressed]``
exits non-zero iff unsuppressed findings remain.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

RULES: Dict[str, str] = {
    "RPL001": "host coercion of a traced value in hot-path code",
    "RPL002": "Python control flow on a traced (jnp) value",
    "RPL003": "hardware-magnitude literal outside repro.configs",
    "RPL004": "tracer/profiler annotation without an `enabled` guard",
    "RPL005": "routable-table mutation outside the staged-commit API",
    "RPL006": "non-integral byte accounting",
    "RPL007": "time.time() outside clock/bandwidth modules",
}

#: path substrings (posix, relative) scoping each rule.  ``only``: rule
#: fires only under these; ``skip``: rule never fires under these.
_HOT_DIRS = ("core/", "models/", "kernels/")
_RULE_ONLY: Dict[str, Tuple[str, ...]] = {
    "RPL001": _HOT_DIRS,
    "RPL002": _HOT_DIRS,
}
_RULE_SKIP: Dict[str, Tuple[str, ...]] = {
    # the single-source-of-truth modules themselves
    "RPL003": ("configs/hw.py", "configs/base.py"),
    # the null-object definitions (and their tests of themselves)
    "RPL004": ("obs/trace.py", "obs/profiler.py"),
    # the staged-commit API implementations
    "RPL005": ("placement/manager.py", "replication/manager.py"),
    # analytic roofline accounting prices FP4 at 4.25 bits/weight
    "RPL006": ("obs/ledger.py",),
    # the virtual/wall clock seam and the bandwidth EWMA wall-timer
    "RPL007": ("obs/trace.py", "placement/migrate.py"),
}

_DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+)")
_BYTEISH_RE = re.compile(r"(^|_)n?bytes?($|_)")
_PROFILERISH_RE = re.compile(r"prof|trac|trc|telemetry", re.I)
# hardware magnitudes (bandwidths, flop rates) live in [1e9, 1e15);
# larger literals are numeric sentinels (1e30 attention masks), smaller
# ones are ordinary sizes.  These two define the rule's band, not a
# hardware constant:
_HW_LITERAL_MIN = 1e9   # repro-lint: disable=RPL003
_HW_LITERAL_MAX = 1e15  # repro-lint: disable=RPL003

#: host-side jax API — returns Python values, never tracers
_HOST_JAX_API = frozenset({
    "jax.default_backend", "jax.devices", "jax.local_devices",
    "jax.device_count", "jax.local_device_count",
    "jax.process_index", "jax.process_count",
})


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str
    suppressed: bool = False

    def format(self) -> str:
        mark = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}{mark}")

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def _name_chain(node: ast.AST) -> str:
    """Dotted source-ish text of a Name/Attribute chain ('' otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _mentions_traced(node: ast.AST) -> bool:
    """True if the subtree references the jnp/jax namespaces (excluding
    the host-side jax API — backend/device queries return Python)."""
    excluded: set = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and _name_chain(n) in _HOST_JAX_API:
            excluded.update(id(sub) for sub in ast.walk(n))
    for n in ast.walk(node):
        if id(n) in excluded:
            continue
        if isinstance(n, ast.Name) and n.id in ("jnp", "jax"):
            return True
    return False


def _test_mentions_enabled(test: ast.AST) -> bool:
    for n in ast.walk(test):
        if isinstance(n, ast.Attribute) and n.attr == "enabled":
            return True
        if isinstance(n, ast.Name) and n.id == "enabled":
            return True
    return False


def _target_names(node: ast.AST) -> Iterable[str]:
    if isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, ast.Attribute):
        yield node.attr
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            yield from _target_names(elt)


def _value_is_floaty(node: ast.AST) -> Optional[str]:
    """Why a value expression breaks integral byte accounting (or None)."""
    for n in ast.walk(node):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Div):
            return "true division (use // for byte counts)"
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                and n.func.id == "float":
            return "float() coercion"
        if isinstance(n, ast.Constant) and isinstance(n.value, float):
            return f"float literal {n.value!r}"
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, rules: Sequence[str], findings: List[Finding],
                 path: str):
        self.rules = set(rules)
        self.findings = findings
        self.path = path
        self._if_stack: List[ast.AST] = []
        # traced values only exist inside functions; module-level
        # jnp expressions run eagerly at import (RPL001/002 exempt)
        self._fn_depth = 0

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._fn_depth += 1
        self.generic_visit(node)
        self._fn_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def _emit(self, rule: str, node: ast.AST, message: str):
        if rule in self.rules:
            self.findings.append(Finding(
                self.path, getattr(node, "lineno", 0),
                getattr(node, "col_offset", 0), rule, message))

    # -- guard-context tracking (RPL004) --------------------------------
    def visit_If(self, node: ast.If):
        self._check_test(node, node.test, "if")
        self._if_stack.append(node.test)
        for child in node.body:
            self.visit(child)
        self._if_stack.pop()
        for child in node.orelse:
            self.visit(child)

    def _under_enabled_guard(self) -> bool:
        return any(_test_mentions_enabled(t) for t in self._if_stack)

    # -- RPL002: control flow on traced values --------------------------
    def _check_test(self, node: ast.AST, test: ast.AST, kind: str):
        if self._fn_depth > 0 and _mentions_traced(test):
            self._emit("RPL002", node,
                       f"{RULES['RPL002']}: `{kind}` test calls into "
                       "jnp/jax — use lax.cond/jnp.where or hoist to a "
                       "static argument")

    def visit_While(self, node: ast.While):
        self._check_test(node, node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        self._check_test(node, node.test, "ternary")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert):
        # assertions on traced values sync at trace time; same rule
        self._check_test(node, node.test, "assert")
        self.generic_visit(node)

    # -- assignments (RPL005, RPL006) -----------------------------------
    def _check_assign(self, node: ast.AST, targets: Sequence[ast.AST],
                      value: Optional[ast.AST]):
        for tgt in targets:
            if isinstance(tgt, ast.Attribute) \
                    and tgt.attr in ("tables", "rsets", "table", "rset"):
                self._emit("RPL005", node,
                           f"{RULES['RPL005']}: assign to `.{tgt.attr}` — "
                           "route mutations through manager.commit/"
                           "commit_layers")
            if value is not None:
                for name in _target_names(tgt):
                    if _BYTEISH_RE.search(name):
                        why = _value_is_floaty(value)
                        if why:
                            self._emit("RPL006", node,
                                       f"{RULES['RPL006']}: `{name}` "
                                       f"assigned from {why}")

    def visit_Assign(self, node: ast.Assign):
        self._check_assign(node, node.targets, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self._check_assign(node, [node.target], node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_assign(node, [node.target], node.value)
        self.generic_visit(node)

    # -- calls (RPL001, RPL004, RPL007) ---------------------------------
    def visit_Call(self, node: ast.Call):
        func = node.func
        # RPL001: host coercion of traced values
        if isinstance(func, ast.Name) and func.id in ("float", "int",
                                                      "bool"):
            if self._fn_depth > 0 \
                    and any(_mentions_traced(a) for a in node.args):
                self._emit("RPL001", node,
                           f"{RULES['RPL001']}: `{func.id}()` of a "
                           "jnp/jax expression forces a host sync at "
                           "trace time")
        if isinstance(func, ast.Attribute):
            chain = _name_chain(func)
            root = chain.split(".")[0] if chain else ""
            if root in ("np", "numpy") and func.attr in (
                    "asarray", "array", "float32", "float64", "int32",
                    "int64", "argmax", "argsort"):
                if self._fn_depth > 0 \
                        and any(_mentions_traced(a) for a in node.args):
                    self._emit("RPL001", node,
                               f"{RULES['RPL001']}: `{chain}()` of a "
                               "jnp/jax expression materialises on host")
            # RPL007: wall clock
            if chain == "time.time":
                self._emit("RPL007", node,
                           f"{RULES['RPL007']}: use the injected engine "
                           "clock or time.perf_counter()")
            # RPL004: unguarded annotation work
            annot = func.attr in ("instant", "complete") or (
                func.attr == "observe_iter"
                and _PROFILERISH_RE.search(chain.rsplit(".", 1)[0]))
            if annot and not self._under_enabled_guard():
                self._emit("RPL004", node,
                           f"{RULES['RPL004']}: `{chain}()` runs "
                           "argument packing every iteration — wrap in "
                           "`if <tracer/profiler>.enabled:`")
        self.generic_visit(node)

    # -- RPL003: hardware literals --------------------------------------
    def visit_Constant(self, node: ast.Constant):
        if isinstance(node.value, (int, float)) \
                and not isinstance(node.value, bool) \
                and _HW_LITERAL_MIN <= abs(node.value) < _HW_LITERAL_MAX:
            self._emit("RPL003", node,
                       f"{RULES['RPL003']}: literal {node.value!r} — "
                       "import it from repro.configs.hw / configs.base")
        self.generic_visit(node)


def _relpath(path: str) -> str:
    """Path relative to the `repro` package root (posix), for scoping."""
    p = Path(path).as_posix()
    marker = "repro/"
    i = p.rfind(marker)
    return p[i + len(marker):] if i >= 0 else p


def _active_rules(path: str) -> List[str]:
    rel = _relpath(path)
    active = []
    for rule in RULES:
        only = _RULE_ONLY.get(rule)
        if only is not None and not any(rel.startswith(d) or f"/{d}" in rel
                                        for d in only):
            continue
        if any(rel.endswith(s) for s in _RULE_SKIP.get(rule, ())):
            continue
        active.append(rule)
    return active


def _apply_suppressions(findings: List[Finding], source: str) -> None:
    lines = source.splitlines()
    for f in findings:
        if 1 <= f.line <= len(lines):
            m = _DISABLE_RE.search(lines[f.line - 1])
            if m and f.rule in {r.strip() for r in m.group(1).split(",")}:
                f.suppressed = True


def lint_source(source: str, path: str = "<string>",
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one module's source; ``rules`` overrides path-based scoping."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        findings.append(Finding(path, e.lineno or 0, e.offset or 0,
                                "RPL000", f"syntax error: {e.msg}"))
        return findings
    active = list(rules) if rules is not None else _active_rules(path)
    _Visitor(active, findings, path).visit(tree)
    _apply_suppressions(findings, source)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """Lint files and directory trees (``*.py``, recursively)."""
    findings: List[Finding] = []
    for p in paths:
        path = Path(p)
        files = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in files:
            findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def summarize(findings: List[Finding]) -> Dict:
    """JSON-ready summary (the shape embedded in the invariant report)."""
    unsup = [f for f in findings if not f.suppressed]
    sup = [f for f in findings if f.suppressed]
    by_rule: Dict[str, int] = {}
    for f in unsup:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "files_ok": not unsup,
        "n_findings": len(unsup),
        "n_suppressed": len(sup),
        "by_rule": dict(sorted(by_rule.items())),
        "findings": [f.to_json() for f in unsup],
        "suppressed": [f.to_json() for f in sup],
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-specific AST lint (rules RPL001-RPL007)")
    ap.add_argument("paths", nargs="+", help="files or directories")
    ap.add_argument("--json", action="store_true",
                    help="emit the JSON summary instead of text lines")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings")
    args = ap.parse_args(argv)

    findings = lint_paths(args.paths)
    unsup = [f for f in findings if not f.suppressed]
    if args.json:
        print(json.dumps(summarize(findings), indent=2))
    else:
        shown = findings if args.show_suppressed else unsup
        for f in shown:
            print(f.format())
        n_sup = len(findings) - len(unsup)
        print(f"{len(unsup)} finding(s), {n_sup} suppressed")
    return 1 if unsup else 0


if __name__ == "__main__":
    sys.exit(main())
