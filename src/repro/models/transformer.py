"""Full model assembly for every assigned architecture.

One generic decoder stack covers all 10 architectures through a *block
layout*: the per-layer (token-mixer, ffn) kinds repeat with a fixed period
(1 for uniform stacks, 8 for jamba's 1-attn:7-mamba, 5 for llama-vision's
4-self:1-cross), so the depth dimension is a single ``lax.scan`` over
stacked block parameters — HLO size is O(1) in depth, which keeps 512-way
SPMD compiles tractable.

Three entry points (all pure):

* ``train_forward``   — logits + MoE aux losses (no cache).
* ``prefill_forward`` — logits for the last position + a length-``cache_len``
  KV/SSM cache.
* ``decode_forward``  — one-token step against the cache.

The AIMD ``m_state`` of ReaLB threads through the layer scan (each MoE
layer applies one synchronous control update) and across serve steps.

Expert placement/replication tables enter here too: a *shared* table is
closed over by the scan body (every block routes identically), while
*per-layer* tables — stacked along a leading ``[n_blocks]`` axis — ride
the scan ``xs`` alongside the block params, so each block consumes its
own slice (see :func:`split_placement`).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ReaLBConfig, SSMConfig
from repro.core import ep_moe
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (P, abstract_params, init_params,
                                 logical_constraint, rms_norm)

Tree = Any

AUX_KEYS = ep_moe.AUX_SCALARS  # ("lb_loss", "z_loss", "drop_frac", ...)


# --------------------------------------------------------------------------
# block layout
# --------------------------------------------------------------------------
def block_structure(cfg: ModelConfig) -> Tuple[Tuple[Tuple[str, str], ...],
                                               int, int]:
    """(block_layout, n_blocks, n_prefix). Layout entries: (mix, ffn)."""
    mixes = list(cfg.layer_kinds())
    ffns = list(cfg.ffn_kinds())
    if cfg.is_encdec:
        mixes = ["dec"] * cfg.n_layers
    kinds = [(m, "none" if (f == "dense" and cfg.d_ff == 0) else f)
             for m, f in zip(mixes, ffns)]
    n_prefix = cfg.n_dense_layers
    rest = kinds[n_prefix:]
    period = cfg.scan_period
    assert len(rest) % period == 0, (len(rest), period)
    layout = tuple(rest[:period])
    for i in range(0, len(rest), period):
        assert tuple(rest[i:i + period]) == layout, "non-periodic layer stack"
    return layout, len(rest) // period, n_prefix


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------
def layer_spec(cfg: ModelConfig, mix: str, ffn: str) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {"norm1": P((d,), ("embed",), init="zeros")}
    if mix in ("attn", "dec"):
        spec["attn"] = attn.attn_spec(cfg)
    elif mix == "ssm":
        spec["ssm"] = ssm_mod.ssm_spec(cfg)
    elif mix == "cross":
        spec["cross"] = attn.gqa_spec(cfg, cross=True)
    if mix == "dec":
        spec["norm_cross"] = P((d,), ("embed",), init="zeros")
        spec["cross"] = attn.gqa_spec(cfg, cross=True)
    if ffn != "none":
        spec["norm2"] = P((d,), ("embed",), init="zeros")
    if ffn == "dense":
        dff = cfg.d_ff or (cfg.moe.d_ff if cfg.moe else 0)
        spec["ffn"] = ffn_mod.ffn_spec(d, dff, cfg.activation)
    elif ffn == "moe":
        spec["moe"] = ep_moe.moe_spec(cfg)
        if cfg.moe.n_shared_experts:
            spec["shared"] = ffn_mod.ffn_spec(
                d, cfg.moe.d_ff * cfg.moe.n_shared_experts, cfg.activation)
    return spec


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    layout, n_blocks, n_prefix = block_structure(cfg)
    d, v = cfg.d_model, cfg.vocab_size
    spec: Dict[str, Any] = {
        "embed": P((v, d), ("vocab", "embed"), init="embed", scale=0.02),
        "final_norm": P((d,), ("embed",), init="zeros"),
        "blocks": {f"layer{i}": layer_spec(cfg, m, f)
                   for i, (m, f) in enumerate(layout)},
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = P((d, v), ("embed", "vocab"))
    if n_prefix:
        spec["prefix"] = {str(i): layer_spec(cfg, cfg.layer_kinds()[i],
                                             "dense")
                          for i in range(n_prefix)}
    if cfg.is_encdec:
        spec["enc_blocks"] = {"layer0": layer_spec(cfg, "attn", "dense")}
        spec["enc_norm"] = P((d,), ("embed",), init="zeros")
    return spec


def init_model(cfg: ModelConfig, key: jax.Array) -> Tree:
    spec = model_spec(cfg)
    _, n_blocks, _ = block_structure(cfg)
    keys = jax.random.split(key, 4)
    params = {
        k: init_params(v, keys[0], cfg.param_dtype)
        for k, v in spec.items() if k not in ("blocks", "enc_blocks")
    }
    params["blocks"] = init_params(spec["blocks"], keys[1], cfg.param_dtype,
                                   stack=n_blocks)
    if cfg.is_encdec:
        params["enc_blocks"] = init_params(
            spec["enc_blocks"], keys[2], cfg.param_dtype,
            stack=cfg.n_enc_layers)
    return params


def abstract_model(cfg: ModelConfig) -> Tree:
    spec = model_spec(cfg)
    _, n_blocks, _ = block_structure(cfg)
    out = {k: abstract_params(v, cfg.param_dtype)
           for k, v in spec.items() if k not in ("blocks", "enc_blocks")}
    out["blocks"] = abstract_params(spec["blocks"], cfg.param_dtype,
                                    stack=n_blocks)
    if cfg.is_encdec:
        out["enc_blocks"] = abstract_params(spec["enc_blocks"],
                                            cfg.param_dtype,
                                            stack=cfg.n_enc_layers)
    return out


# --------------------------------------------------------------------------
# cache specs
# --------------------------------------------------------------------------
def _entry_spec(cfg: ModelConfig, mix: str, ffn: str, b: int, l: int,
                mem_len: int, dtype: str) -> Dict[str, P]:
    s_cfg = cfg.ssm or SSMConfig()
    d_in = s_cfg.expand * cfg.d_model
    out: Dict[str, P] = {}
    if mix in ("attn", "dec"):
        if cfg.mla is not None:
            out["latent"] = P((b, l, cfg.mla.kv_lora_rank),
                              ("batch", "kv_seq", "rank"), init="zeros",
                              dtype=dtype)
            out["k_rope"] = P((b, l, cfg.mla.qk_rope_head_dim),
                              ("batch", "kv_seq", None), init="zeros",
                              dtype=dtype)
        else:
            kv = P((b, l, cfg.n_kv_heads, cfg.head_dim),
                   ("batch", "kv_seq", "kv_heads", None), init="zeros",
                   dtype=dtype)
            out["k"], out["v"] = kv, kv
    elif mix == "ssm":
        out["conv"] = P((b, s_cfg.d_conv - 1, d_in),
                        ("batch", None, "d_inner"), init="zeros", dtype=dtype)
        out["ssm"] = P((b, d_in, s_cfg.d_state),
                       ("batch", "d_inner", None), init="zeros",
                       dtype="float32")
    if mix in ("cross", "dec"):
        xkv = P((b, mem_len, cfg.n_kv_heads, cfg.head_dim),
                ("batch", None, "kv_heads", None), init="zeros", dtype=dtype)
        out["xk"], out["xv"] = xkv, xkv
    return out


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    layout, n_blocks, n_prefix = block_structure(cfg)
    mem_len = cfg.enc_seq_len if cfg.is_encdec else cfg.n_vision_tokens
    dtype = cfg.param_dtype
    spec: Dict[str, Any] = {
        "blocks": {f"layer{i}": _entry_spec(cfg, m, f, batch, cache_len,
                                            mem_len, dtype)
                   for i, (m, f) in enumerate(layout)},
    }
    if n_prefix:
        spec["prefix"] = {str(i): _entry_spec(cfg, cfg.layer_kinds()[i],
                                              "dense", batch, cache_len,
                                              mem_len, dtype)
                          for i in range(n_prefix)}
    return spec


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Tree:
    spec = cache_spec(cfg, batch, cache_len)
    _, n_blocks, _ = block_structure(cfg)
    key = jax.random.PRNGKey(0)  # zeros init: key unused
    out = {"blocks": init_params(spec["blocks"], key, cfg.param_dtype,
                                 stack=n_blocks)}
    if "prefix" in spec:
        out["prefix"] = init_params(spec["prefix"], key, cfg.param_dtype)
    return out


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Tree:
    spec = cache_spec(cfg, batch, cache_len)
    _, n_blocks, _ = block_structure(cfg)
    out = {"blocks": abstract_params(spec["blocks"], cfg.param_dtype,
                                     stack=n_blocks)}
    if "prefix" in spec:
        out["prefix"] = abstract_params(spec["prefix"], cfg.param_dtype)
    return out


# --------------------------------------------------------------------------
# single layer application
# --------------------------------------------------------------------------
def _pad_kv(arr: jax.Array, cache_len: int) -> jax.Array:
    """Pad a prefill KV [B,S,...] out to [B,cache_len,...]."""
    s = arr.shape[1]
    if s == cache_len:
        return arr
    pad = [(0, 0), (0, cache_len - s)] + [(0, 0)] * (arr.ndim - 2)
    return jnp.pad(arr, pad)


def n_physical_slots(cfg: ModelConfig, placement=None) -> int:
    """Physical expert-slot count S of the MoE weight arrays: the logical
    expert count for bijective tables, the replica-slot count (>= E) when
    a :class:`~repro.core.ep_moe.Replication` set is threaded through.
    Per-layer (stacked ``[n_blocks, ...]``) tables share S across layers,
    so the trailing axis is authoritative either way."""
    n_e = cfg.moe.num_experts if cfg.moe is not None else 1
    # slot_owner [S] is entry 2 of both the 3-tuple Replication view and
    # the 4-tuple weighted-split view (entry 3 is the split schedule)
    if placement is not None and len(tuple(placement)) >= 3:
        return int(tuple(placement)[2].shape[-1])
    return n_e


def split_placement(placement, n_blocks: int):
    """(shared, stacked) view of a placement/replication argument.

    A *shared* table — ``(e2r [E], local_slot [E])`` or ``(rep_pos
    [E, R], n_rep [E], slot_owner [S])`` — serves every scanned block and
    is closed over by the scan body (the PR 3 path, and the ``n_blocks=1``
    degenerate case of per-layer planning).  A *per-layer* table carries a
    leading ``[n_blocks]`` axis on every entry and is threaded through the
    scan ``xs`` alongside the block params, so each block consumes its own
    slice — ``repro.core.ep_moe`` sees per-layer and shared tables
    identically.  Exactly one of the returned values is non-None (both
    None when ``placement`` is None)."""
    if placement is None:
        return None, None
    entries = tuple(placement)
    base_ndim = 1 if len(entries) == 2 else 2   # e2r [E] / rep_pos [E, R]
    if entries[0].ndim == base_ndim:
        return entries, None
    assert entries[0].ndim == base_ndim + 1, \
        f"placement entry ndim {entries[0].ndim}, want {base_ndim} " \
        f"(shared) or {base_ndim + 1} (per-layer)"
    for a in entries:
        assert int(a.shape[0]) == n_blocks, \
            (tuple(int(s) for s in a.shape), n_blocks)
    return None, entries


def apply_layer(lp: Tree, x: jax.Array, cfg: ModelConfig, rcfg: ReaLBConfig,
                mix: str, ffn: str, *, mode: str, positions, pos,
                memory, cache_in, m_state, modality, cache_len: int,
                fsdp: bool, chunk_len=None, valid=None, placement=None):
    """Returns (x, cache_out, m_state, aux_scalars, stats, estats,
    sstats)."""
    n_e = cfg.moe.num_experts if cfg.moe is not None else 1
    n_slot = n_physical_slots(cfg, placement)
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    stats = jnp.zeros((2,) + m_state.shape, jnp.float32)
    estats = jnp.zeros((2, n_e), jnp.float32)
    sstats = jnp.zeros((2, n_slot), jnp.float32)
    cache_out: Dict[str, jax.Array] = {}
    decode = mode == "decode"
    with_cache = mode in ("prefill", "decode", "chunk")

    # ---- token mixer ----
    # named_scope = profiler phase vocabulary (metadata only, no data
    # deps): "attention" covers every mixer flavor, "moe"/"ffn" the block
    # below — the xprof timeline groups ops accordingly
    with jax.named_scope("attention"):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        if mix in ("attn", "dec"):
            if mode == "chunk":
                # cached multi-token prefill continuation (plain GQA/MQA
                # only; callers gate on cfg — see chunk_forward)
                o, kv = attn.gqa_chunk(lp["attn"], h,
                                       {"k": cache_in["k"],
                                        "v": cache_in["v"]},
                                       cfg, positions=positions,
                                       chunk_len=chunk_len)
            elif cfg.mla is not None:
                if decode:
                    o, kv = attn.mla_decode(lp["attn"], h, cache_in, cfg,
                                            pos=pos)
                else:
                    o, kv = attn.mla_forward(lp["attn"], h, cfg,
                                             positions=positions)
                    if mode == "prefill":
                        kv = {k: _pad_kv(v, cache_len)
                              for k, v in kv.items()}
            else:
                if decode:
                    o, kv = attn.gqa_decode(lp["attn"], h,
                                            {"k": cache_in["k"],
                                             "v": cache_in["v"]}, cfg,
                                            pos=pos)
                else:
                    causal = not (cfg.is_encdec and mode == "encode")
                    o, kv = attn.gqa_forward(lp["attn"], h, cfg,
                                             positions=positions,
                                             causal=causal)
                    if mode == "prefill":
                        kv = {k: _pad_kv(v, cache_len)
                              for k, v in kv.items()}
            if with_cache and mix in ("attn", "dec"):
                cache_out.update(kv)
            if mode == "train":
                o = jax.ad_checkpoint.checkpoint_name(o, "attn_out")
            x = x + o
        elif mix == "ssm":
            if decode:
                o, st = ssm_mod.ssm_decode(lp["ssm"], h,
                                           {"conv": cache_in["conv"],
                                            "ssm": cache_in["ssm"]}, cfg)
            else:
                o, st = ssm_mod.ssm_forward(lp["ssm"], h, cfg)
            if mode in ("prefill", "decode"):
                cache_out.update(st)
            x = x + o
        if mix in ("cross", "dec"):
            key = "cross"
            hn = rms_norm(x, lp.get("norm_cross", lp["norm1"]),
                          cfg.norm_eps)
            if decode:
                o, xkv = attn.cross_decode(lp[key], hn,
                                           {"k": cache_in["xk"],
                                            "v": cache_in["xv"]}, cfg)
                xkv = {"xk": xkv["k"], "xv": xkv["v"]}
            else:
                o, kv2 = attn.cross_forward(lp[key], hn, memory, cfg)
                xkv = {"xk": kv2["k"], "xv": kv2["v"]}
            if mode in ("prefill", "decode"):
                cache_out.update(xkv)
            x = x + o

    # ---- ffn / moe ----
    if ffn == "dense" and "ffn" in lp:
        with jax.named_scope("ffn"):
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            x = x + ffn_mod.ffn_forward(lp["ffn"], h2, cfg)
    elif ffn == "moe":
        with jax.named_scope("moe"):
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            y, m_state, moe_aux = ep_moe.ep_moe_forward(
                lp["moe"], h2, cfg, rcfg, m_state, modality,
                mode="broadcast" if decode else "dispatch",
                train=(mode == "train"), fsdp=fsdp, valid=valid,
                placement=placement)
            if "shared" in lp:
                y = y + ffn_mod.ffn_forward(lp["shared"], h2, cfg)
            x = x + y
        aux = {k: moe_aux[k].astype(jnp.float32) for k in AUX_KEYS}
        stats = jnp.stack([
            jnp.broadcast_to(moe_aux["load_d"].reshape(-1),
                             (m_state.size,)).reshape(m_state.shape),
            jnp.broadcast_to(moe_aux["vis_d"].reshape(-1),
                             (m_state.size,)).reshape(m_state.shape)])
        # per-logical-expert routed loads (summed over EP group rows):
        # the placement predictor's observation stream
        estats = jnp.stack([
            moe_aux["expert_load"].reshape(-1, n_e).sum(0),
            moe_aux["expert_vis"].reshape(-1, n_e).sum(0)]
        ).astype(jnp.float32)
        # per-physical-slot post-split loads: the replica manager's
        # utilization stream (== estats under a bijective table)
        sstats = jnp.stack([
            moe_aux["slot_load"].reshape(-1, n_slot).sum(0),
            moe_aux["slot_vis"].reshape(-1, n_slot).sum(0)]
        ).astype(jnp.float32)
    return x, cache_out, m_state, aux, stats, estats, sstats


# --------------------------------------------------------------------------
# full forward passes
# --------------------------------------------------------------------------
class ForwardResult(NamedTuple):
    logits: jax.Array
    cache: Optional[Tree]
    m_state: jax.Array
    aux: Dict[str, jax.Array]


def _embed(params, cfg: ModelConfig, tokens: jax.Array,
           vision_embeds: Optional[jax.Array], mode: str) -> jax.Array:
    dtype = jnp.dtype(cfg.param_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale_sqrt_d:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if (cfg.family == "vlm" and vision_embeds is not None
            and mode != "decode"):
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(dtype), (0, 0, 0))
    axes = ("batch", None, None) if mode == "decode" \
        else ("batch", "seq", None)
    return logical_constraint(x, axes)


def _unembed(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["unembed"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _encode(params, cfg: ModelConfig, enc_embeds: jax.Array,
            rcfg: ReaLBConfig, m_state) -> jax.Array:
    """Whisper-style encoder: non-causal attention blocks over frames."""
    dtype = jnp.dtype(cfg.param_dtype)
    x = enc_embeds.astype(dtype)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, bp):
        h, m = carry
        h, _, m, _, _, _, _ = apply_layer(
            bp["layer0"], h, cfg, rcfg, "attn", "dense", mode="encode",
            positions=positions, pos=None, memory=None, cache_in=None,
            m_state=m, modality=None, cache_len=0, fsdp=False)
        return (h, m), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    (x, _), _ = jax.lax.scan(body, (x, m_state), params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _run_stack(params, cfg, rcfg, x, *, mode, positions, pos, memory,
               cache, m_state, modality, cache_len, fsdp, chunk_len=None,
               valid=None, placement=None):
    layout, n_blocks, n_prefix = block_structure(cfg)
    n_e = cfg.moe.num_experts if cfg.moe is not None else 1
    n_slot = n_physical_slots(cfg, placement)
    place_shared, place_stacked = split_placement(placement, n_blocks)
    new_cache: Dict[str, Any] = {}
    aux_acc = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    with_cache = mode in ("prefill", "decode", "chunk")

    # unrolled prefix layers (e.g. moonshot's leading dense layer)
    if n_prefix:
        new_cache["prefix"] = {}
        for i in range(n_prefix):
            ci = cache["prefix"][str(i)] if (cache and "prefix" in cache) \
                else None
            x, co, m_state, aux, _, _, _ = apply_layer(
                params["prefix"][str(i)], x, cfg, rcfg,
                cfg.layer_kinds()[i], "dense", mode=mode,
                positions=positions, pos=pos, memory=memory, cache_in=ci,
                m_state=m_state, modality=modality, cache_len=cache_len,
                fsdp=fsdp, chunk_len=chunk_len, valid=valid)
            if with_cache:
                new_cache["prefix"][str(i)] = co
            aux_acc = {k: aux_acc[k] + aux[k] for k in AUX_KEYS}

    def body(carry, xs):
        h, m = carry
        bp, cache_in, place_b = xs
        if place_b is None:      # shared table (or none): same every block
            place_b = place_shared
        block_cache = {}
        aux_b = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
        stats_b = jnp.zeros((2,) + m.shape, jnp.float32)
        estats_b = jnp.zeros((2, n_e), jnp.float32)
        sstats_b = jnp.zeros((2, n_slot), jnp.float32)
        for i, (mix, f) in enumerate(layout):
            ci = cache_in[f"layer{i}"] if cache_in is not None else None
            h, co, m, aux, stats, estats, sstats = apply_layer(
                bp[f"layer{i}"], h, cfg, rcfg, mix, f, mode=mode,
                positions=positions, pos=pos, memory=memory, cache_in=ci,
                m_state=m, modality=modality, cache_len=cache_len,
                fsdp=fsdp, chunk_len=chunk_len, valid=valid,
                placement=place_b)
            if with_cache:
                block_cache[f"layer{i}"] = co
            aux_b = {k: aux_b[k] + aux[k] for k in AUX_KEYS}
            stats_b = stats_b + stats
            estats_b = estats_b + estats
            sstats_b = sstats_b + sstats
        outs = (block_cache, aux_b, stats_b, estats_b, sstats_b) \
            if with_cache else (aux_b, stats_b, estats_b, sstats_b)
        return (h, m), outs

    if mode == "train" and cfg.remat == "full":
        body = jax.checkpoint(body)
    elif mode == "train" and cfg.remat == "attn_out":
        # rematerialise everything except the attention outputs: the
        # online-softmax KV scan is the most recompute-expensive part of
        # the block, and its output is only [B,S,D] bf16 per layer
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out"))

    xs = (params["blocks"], cache["blocks"] if with_cache and cache else None,
          place_stacked)
    (x, m_state), ys = jax.lax.scan(body, (x, m_state), xs)
    if with_cache:
        (new_cache["blocks"], aux_blocks, stats_blocks, estats_blocks,
         sstats_blocks) = ys
    else:
        aux_blocks, stats_blocks, estats_blocks, sstats_blocks = ys
    aux_total = {k: aux_acc[k] + aux_blocks[k].sum() for k in AUX_KEYS}
    aux_total["moe_stats"] = stats_blocks          # [n_blocks, 2, groups, ep]
    aux_total["expert_stats"] = estats_blocks      # [n_blocks, 2, E]
    aux_total["slot_stats"] = sstats_blocks        # [n_blocks, 2, S]
    return x, (new_cache if with_cache else None), m_state, aux_total


def _prepare_inputs(cfg, batch, mode):
    tokens = batch["tokens"]
    modality = batch.get("modality")
    if modality is None:
        b, s = tokens.shape
        if cfg.family == "vlm" and mode != "decode":
            modality = (jnp.arange(s)[None, :] < cfg.n_vision_tokens)
            modality = jnp.broadcast_to(modality, (b, s))
        else:
            modality = jnp.zeros((b, s), jnp.bool_)
    return tokens, modality


def train_forward(params, cfg: ModelConfig, rcfg: ReaLBConfig, batch,
                  m_state, placement=None) -> ForwardResult:
    tokens, modality = _prepare_inputs(cfg, batch, "train")
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    memory = None
    if cfg.is_encdec:
        memory = _encode(params, cfg, batch["enc_embeds"], rcfg, m_state)
    elif cfg.family == "vlm":
        memory = batch["vision_embeds"]
    x = _embed(params, cfg, tokens, batch.get("vision_embeds"), "train")
    x, _, m_state, aux = _run_stack(
        params, cfg, rcfg, x, mode="train", positions=positions, pos=None,
        memory=memory, cache=None, m_state=m_state, modality=modality,
        cache_len=0, fsdp=True, placement=placement)
    logits = _unembed(params, cfg, x)
    return ForwardResult(logits, None, m_state, aux)


def prefill_forward(params, cfg: ModelConfig, rcfg: ReaLBConfig, batch,
                    m_state, cache_len: int = 0,
                    placement=None) -> ForwardResult:
    tokens, modality = _prepare_inputs(cfg, batch, "prefill")
    b, s = tokens.shape
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    memory = None
    if cfg.is_encdec:
        memory = _encode(params, cfg, batch["enc_embeds"], rcfg, m_state)
    elif cfg.family == "vlm":
        memory = batch["vision_embeds"]
    x = _embed(params, cfg, tokens, batch.get("vision_embeds"), "prefill")
    x, cache, m_state, aux = _run_stack(
        params, cfg, rcfg, x, mode="prefill", positions=positions, pos=None,
        memory=memory, cache=None, m_state=m_state, modality=modality,
        cache_len=cache_len, fsdp=False, placement=placement)
    logits = _unembed(params, cfg, x[:, -1:, :])
    return ForwardResult(logits[:, 0], cache, m_state, aux)


def chunk_forward(params, cfg: ModelConfig, rcfg: ReaLBConfig, batch,
                  cache, m_state, placement=None) -> ForwardResult:
    """Chunked-prefill continuation step against a partially-filled cache.

    batch: tokens [B,S] (one prompt chunk per row), start [B] (absolute
    position of each row's first chunk token), chunk_len [B] (valid tokens
    per row; 0 = idle row), modality [B,S].  Each row writes its chunk's KV
    into the cache at [start, start+chunk_len) and attends causally to its
    own prefix; padding columns and idle rows never touch the cache.
    Returns logits at every row's last *valid* chunk position (only rows
    that just finished their prompt should be sampled from).

    Only uniform GQA/MQA decoder stacks support chunk continuation (no MLA
    latent re-expansion, no SSM state threading, no enc-dec memory).
    """
    assert (cfg.mla is None and cfg.ssm is None and not cfg.is_encdec
            and cfg.layer_pattern == "attn"), \
        "chunked prefill supports plain-attention stacks only"
    tokens = batch["tokens"]
    start = batch["start"]
    chunk_len = batch["chunk_len"]
    b, s = tokens.shape
    modality = batch.get("modality")
    if modality is None:
        modality = jnp.zeros((b, s), jnp.bool_)
    positions = start[:, None] + jnp.arange(s)[None, :]
    valid = jnp.arange(s)[None, :] < chunk_len[:, None]
    x = _embed(params, cfg, tokens, None, "chunk")
    x, cache, m_state, aux = _run_stack(
        params, cfg, rcfg, x, mode="chunk", positions=positions, pos=start,
        memory=None, cache=cache, m_state=m_state, modality=modality,
        cache_len=0, fsdp=False, chunk_len=chunk_len, valid=valid,
        placement=placement)
    last = jnp.clip(chunk_len - 1, 0, s - 1)
    x_last = x[jnp.arange(b), last][:, None, :]
    logits = _unembed(params, cfg, x_last)
    return ForwardResult(logits[:, 0], cache, m_state, aux)


def decode_forward(params, cfg: ModelConfig, rcfg: ReaLBConfig, batch,
                   cache, m_state, placement=None) -> ForwardResult:
    """batch: tokens [B,1], pos [B], modality [B,1] (vision flag of the
    *new* token; usually False during generation), valid [B,1] (False =
    dummy slot excluded from routing stats)."""
    tokens = batch["tokens"]
    pos = batch["pos"]
    modality = batch.get("modality")
    if modality is None:
        modality = jnp.zeros(tokens.shape, jnp.bool_)
    x = _embed(params, cfg, tokens, None, "decode")
    x, cache, m_state, aux = _run_stack(
        params, cfg, rcfg, x, mode="decode", positions=None, pos=pos,
        memory=None, cache=cache, m_state=m_state, modality=modality,
        cache_len=0, fsdp=False, valid=batch.get("valid"),
        placement=placement)
    logits = _unembed(params, cfg, x)
    return ForwardResult(logits[:, 0], cache, m_state, aux)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE. logits [B,S,V] f32, labels [B,S] int32 (-1 = pad)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def train_loss(params, cfg: ModelConfig, rcfg: ReaLBConfig, batch,
               m_state) -> Tuple[jax.Array, Tuple[jax.Array, Dict]]:
    res = train_forward(params, cfg, rcfg, batch, m_state)
    ce = cross_entropy(res.logits, batch["labels"])
    loss = ce
    if cfg.moe is not None:
        loss = (loss + cfg.moe.aux_loss_coef * res.aux["lb_loss"]
                + cfg.moe.router_z_coef * res.aux["z_loss"])
    metrics = {"ce": ce, **{k: res.aux[k] for k in AUX_KEYS}}
    return loss, (res.m_state, metrics)
