"""Dense feed-forward blocks: SwiGLU / GeGLU / GELU-MLP (Megatron-SP sharded)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import P, activation_fn, logical_constraint

Params = Dict[str, jax.Array]


def ffn_spec(d_model: int, d_ff: int, activation: str) -> Dict[str, P]:
    spec = {
        "w_up": P((d_model, d_ff), ("embed", "ffn")),
        "w_down": P((d_ff, d_model), ("ffn", "embed")),
    }
    if activation in ("swiglu", "geglu"):
        spec["w_gate"] = P((d_model, d_ff), ("embed", "ffn"))
    return spec


def ffn_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                d_ff: int = 0) -> jax.Array:
    """x: [B, S, D] (seq-sharded in) -> [B, S, D] (seq-sharded out)."""
    act = activation_fn(cfg.activation)
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = act(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = act(h.astype(jnp.float32)).astype(x.dtype)
    h = logical_constraint(h, ("batch", None, "ffn"))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return logical_constraint(out, ("batch", "seq", None))
