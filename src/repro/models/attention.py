"""Attention variants: GQA/MQA, MLA (latent), cross-attention; full + cached decode.

All functions are pure; parameters are declared via :class:`repro.models.common.P`
and applied functionally.  Sharding is guided by ``logical_constraint`` —
heads over "model" during attention, sequence over "model" in the residual
stream (Megatron-style SP↔TP transitions inserted by GSPMD).

Long sequences use an online-softmax KV-chunked attention (flash-attention
recurrence expressed with ``lax.scan``) so score matrices never exceed
``[B,H,S,chunk]``; the dense path is kept for short sequences where XLA
fuses it best.

Decode uses a sequence-sharded KV cache ``[B, S, K, D]`` (logical axes
batch/kv_seq/kv_heads/None): each shard computes partial attention over its
sequence slice and GSPMD combines the softmax reductions across shards
(flash-decode style).
"""
from __future__ import annotations

import os
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import P, apply_rope, logical_constraint, rms_norm

Params = Dict[str, jax.Array]

_DENSE_MAX_KV = 2048      # kv length above which the chunked path is used
_KV_CHUNK = 1024

# §Perf A/B switch: REPRO_ATTN_BASELINE=1 restores the paper-faithful-but-
# naive baseline (f32 attention math, whole-cache select updates, no layout
# pinning) so before/after roofline terms are measured on one codebase.
_BASELINE = os.environ.get("REPRO_ATTN_BASELINE") == "1"


# --------------------------------------------------------------------------
# parameter declarations
# --------------------------------------------------------------------------
def gqa_spec(cfg: ModelConfig, cross: bool = False) -> Dict[str, P]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": P((d, h, hd), ("embed", "heads", None)),
        "wk": P((d, k, hd), ("embed", "kv_heads", None)),
        "wv": P((d, k, hd), ("embed", "kv_heads", None)),
        "wo": P((h, hd, d), ("heads", None, "embed"),
                scale=1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((h, hd), ("heads", None), init="zeros")
        spec["bk"] = P((k, hd), ("kv_heads", None), init="zeros")
        spec["bv"] = P((k, hd), ("kv_heads", None), init="zeros")
    return spec


def mla_spec(cfg: ModelConfig) -> Dict[str, P]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": P((d, m.q_lora_rank), ("embed", "rank")),
        "q_norm": P((m.q_lora_rank,), ("rank",), init="zeros"),
        "wq_b": P((m.q_lora_rank, h, qk), ("rank", "heads", None)),
        "wkv_a": P((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "rank")),
        "kv_norm": P((m.kv_lora_rank,), ("rank",), init="zeros"),
        "wk_b": P((m.kv_lora_rank, h, m.qk_nope_head_dim), ("rank", "heads", None)),
        "wv_b": P((m.kv_lora_rank, h, m.v_head_dim), ("rank", "heads", None)),
        "wo": P((h, m.v_head_dim, d), ("heads", None, "embed"),
                scale=1.0 / (2 * max(cfg.n_layers, 1)) ** 0.5),
    }


def attn_spec(cfg: ModelConfig, kind: str = "attn") -> Dict[str, P]:
    if cfg.mla is not None and kind == "attn":
        return mla_spec(cfg)
    return gqa_spec(cfg, cross=(kind == "cross"))


# --------------------------------------------------------------------------
# core attention math
# --------------------------------------------------------------------------
def _dense_attention(q, k, v, scale, *, causal, q_offset, kv_valid):
    """Short-KV attention (train/prefill ≤2k KV, cross-attention).

    Repeated-KV MHA layout (same rationale as the chunked path): one
    `heads` dim that shards over "model" (fallback: sequence parallelism),
    bf16 dots with f32 accumulation.
    """
    if _BASELINE:
        return _dense_attention_v0(q, k, v, scale, causal=causal,
                                   q_offset=q_offset, kv_valid=kv_valid)
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    odt = q.dtype
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qh = q.transpose(0, 2, 1, 3)                           # [B,H,S,D]
    scores = jnp.einsum("bhsd,bthd->bhst", qh, k,
                        preferred_element_type=jnp.float32) * scale
    scores = logical_constraint(scores, ("batch", "heads", "seq", None))
    mask = None
    if causal:
        q_pos = jnp.arange(s)[:, None] + q_offset
        mask = (jnp.arange(t)[None, :] <= q_pos)[None, None]   # [1,1,S,T]
    if kv_valid is not None:
        vm = (jnp.arange(t)[None, :] < kv_valid[:, None])      # [B,T]
        vm = vm[:, None, None, :]
        mask = vm if mask is None else (mask & vm)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bhsd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = logical_constraint(out, ("batch", "heads", "seq", None))
    return out.transpose(0, 2, 1, 3).astype(odt)


def _decode_flash(q, k, v, scale, *, kv_valid):
    """One-token decode against a long sequence-sharded cache: grouped
    [kh,g] scores stay sharded on the cache's kv_seq axis; GSPMD inserts
    the tiny per-shard max/sum LSE all-reduces (flash-decode)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qf = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k,
                        preferred_element_type=jnp.float32) * scale
    scores = logical_constraint(scores,
                                ("batch", None, None, None, "kv_seq"))
    if kv_valid is not None:
        vm = (jnp.arange(t)[None, :] < kv_valid[:, None])
        scores = jnp.where(vm[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def _dense_attention_v0(q, k, v, scale, *, causal, q_offset, kv_valid):
    """Baseline (pre-§Perf) dense attention: f32 math, grouped layout."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    odt = q.dtype
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    qf = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k,
                        preferred_element_type=jnp.float32) * scale
    mask = None
    if causal:
        q_pos = jnp.arange(s)[:, None] + q_offset
        mask = (jnp.arange(t)[None, :] <= q_pos)[None, None, None]
    if kv_valid is not None:
        vm = (jnp.arange(t)[None, :] < kv_valid[:, None])
        vm = vm[:, None, None, None, :]
        mask = vm if mask is None else (mask & vm)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, v.shape[-1]).astype(odt)


def _chunked_attention(q, k, v, scale, *, causal, q_offset, kv_valid,
                       chunk=_KV_CHUNK):
    """Online-softmax attention scanning KV chunks.

    MHA formulation: KV is broadcast to the full head count (cheap at
    train/prefill sizes) so every per-step tensor carries a single `heads`
    dim that shards cleanly over "model" for all head counts divisible by
    the axis; the logical resolver falls back to sequence parallelism for
    the 20/40-head archs.  One stable layout end-to-end — no GSPMD
    "involuntary full rematerialization" resharding inside the scan.

    Two scan phases: chunks entirely below the causal diagonal run a
    mask-free step (no score-sized select), only the diagonal/ragged tail
    pays for masking; the softmax scale is folded into Q once (q-sized)
    instead of scaling every score chunk.
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    odt = q.dtype
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    n_chunks = -(-t // chunk)
    t_pad = n_chunks * chunk
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    _axes = ("batch", "heads", "seq")
    _lc = logical_constraint
    k = _lc(k, ("batch", None, "heads", None))
    v = _lc(v, ("batch", None, "heads", None))
    kc = k.reshape(b, n_chunks, chunk, h, -1).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_chunks, chunk, h, -1).transpose(1, 0, 3, 2, 4)
    # fold the softmax scale into q: one [B,H,S,D] multiply instead of a
    # score-sized multiply per chunk
    qh = (q.transpose(0, 2, 1, 3).astype(jnp.float32)
          * jnp.float32(scale)).astype(q.dtype)
    q_pos = jnp.arange(s)[:, None] + q_offset            # [S,1]

    def make_step(masked: bool):
        def step(carry, inp):
            m_prev, l_prev, acc = carry
            ci, k_i, v_i = inp                            # [B,H,C,D]
            scores = jnp.einsum("bhsd,bhcd->bhsc", qh, k_i,
                                preferred_element_type=jnp.float32)
            scores = _lc(scores, _axes + (None,))
            if masked:
                kv_pos = ci * chunk + jnp.arange(chunk)   # [C]
                mask = (kv_pos[None, :] < t)              # [1,C] padding
                if causal:
                    mask = mask & (kv_pos[None, :] <= q_pos)
                mask = jnp.broadcast_to(mask[None, None],
                                        scores.shape[:2] + mask.shape[-2:])
                if kv_valid is not None:
                    vm = (kv_pos[None, :] < kv_valid[:, None])
                    mask = mask & vm[:, None, None, :]
                scores = jnp.where(mask, scores, -1e30)
            m_cur = jnp.max(scores, axis=-1)              # [B,H,S]
            m_new = jnp.maximum(m_prev, m_cur)
            alpha = jnp.exp(m_prev - m_new)
            # masked lanes sit at -1e30: exp underflows to exactly 0, so no
            # second mask select is needed (one fewer score-sized pass)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhsc,bhcd->bhsd", p.astype(v_i.dtype), v_i,
                            preferred_element_type=jnp.float32)
            acc_new = _lc(acc * alpha[..., None] + pv, _axes + (None,))
            return (m_new, l_new, acc_new), None
        return step

    dv = v.shape[-1]
    m0 = _lc(jnp.full((b, h, s), -jnp.inf, jnp.float32), _axes)
    l0 = _lc(jnp.zeros((b, h, s), jnp.float32), _axes)
    a0 = _lc(jnp.zeros((b, h, s, dv), jnp.float32), _axes + (None,))
    carry = (m0, l0, a0)
    # phase 1: chunks entirely below the causal diagonal — mask-free
    n_free = 0
    if causal and kv_valid is None and t_pad == t:
        n_free = min(int(q_offset) // chunk, n_chunks)
    if n_free:
        carry, _ = jax.lax.scan(
            make_step(False), carry,
            (jnp.arange(n_free), kc[:n_free], vc[:n_free]))
    if n_free < n_chunks:
        carry, _ = jax.lax.scan(
            make_step(True), carry,
            (jnp.arange(n_free, n_chunks), kc[n_free:], vc[n_free:]))
    m_f, l_f, acc = carry
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(odt)          # [B,S,H,D]


def _chunked_attention_v0(q, k, v, scale, *, causal, q_offset, kv_valid,
                          chunk=_KV_CHUNK):
    """Baseline (pre-§Perf) chunked attention: f32 math, grouped [kh,g]
    score layout, no layout pinning. Kept for the A/B measurements."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    odt = q.dtype
    q, k, v = (x.astype(jnp.float32) for x in (q, k, v))
    n_chunks = -(-t // chunk)
    t_pad = n_chunks * chunk
    if t_pad != t:
        pad = [(0, 0), (0, t_pad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(b, n_chunks, chunk, kh, -1).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kh, -1).transpose(1, 0, 2, 3, 4)
    qf = q.reshape(b, s, kh, g, d)
    q_pos = jnp.arange(s)[:, None] + q_offset

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ci, k_i, v_i = inp
        t0 = ci * chunk
        scores = jnp.einsum("bskgd,btkd->bkgst", qf, k_i,
                            preferred_element_type=jnp.float32) * scale
        kv_pos = t0 + jnp.arange(chunk)
        mask = (kv_pos[None, :] < t)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos)
        mask = jnp.broadcast_to(mask[None, None, None],
                                scores.shape[:3] + mask.shape[-2:])
        if kv_valid is not None:
            vm = (kv_pos[None, :] < kv_valid[:, None])
            mask = mask & vm[:, None, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgsc,bckd->bkgsd", p, v_i,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    dv = v.shape[-1]
    m0 = jnp.full((b, kh, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kh, g, s, dv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dv).astype(odt)


_Q_BLOCK = 4096


def scaled_attention(q, k, v, scale, *, causal=True, q_offset=0,
                     kv_valid=None):
    """Dispatch: one-token decode → flash-decode against the seq-sharded
    cache; short KV → dense; long causal sequences → q-block truncation
    (each q block attends only its own KV prefix — block-level causal
    skipping, ~2× less score traffic/flops at 32k) over the chunked
    online-softmax inner loop."""
    if q.shape[1] <= 8 and k.shape[1] > _DENSE_MAX_KV and not _BASELINE:
        return _decode_flash(q, k, v, scale, kv_valid=kv_valid)
    if k.shape[1] <= _DENSE_MAX_KV:
        return _dense_attention(q, k, v, scale, causal=causal,
                                q_offset=q_offset, kv_valid=kv_valid)
    if _BASELINE:
        return _chunked_attention_v0(q, k, v, scale, causal=causal,
                                     q_offset=q_offset, kv_valid=kv_valid)
    s = q.shape[1]
    qb = _Q_BLOCK if s % _Q_BLOCK == 0 else (
        s // 2 if s % 2 == 0 and s > _DENSE_MAX_KV else 0)
    if causal and s == k.shape[1] and q_offset == 0 and qb and s > qb:
        outs = []
        for j in range(s // qb):
            q_j = q[:, j * qb:(j + 1) * qb]
            kv_end = (j + 1) * qb
            if kv_end <= _DENSE_MAX_KV:
                outs.append(_dense_attention(
                    q_j, k[:, :kv_end], v[:, :kv_end], scale, causal=True,
                    q_offset=j * qb, kv_valid=kv_valid))
            else:
                outs.append(_chunked_attention(
                    q_j, k[:, :kv_end], v[:, :kv_end], scale, causal=True,
                    q_offset=j * qb, kv_valid=kv_valid))
        return jnp.concatenate(outs, axis=1)
    return _chunked_attention(q, k, v, scale, causal=causal,
                              q_offset=q_offset, kv_valid=kv_valid)


def _project_qkv(p: Params, x: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dke->btke", xkv, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dke->btke", xkv, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        # explicit (1, 1, h, e) broadcast: rank promotion raises
        q = q + p["bq"].astype(x.dtype)[None, None]
        k = k + p["bk"].astype(x.dtype)[None, None]
        v = v + p["bv"].astype(x.dtype)[None, None]
    q = logical_constraint(q, ("batch", None, "heads", None))
    k = logical_constraint(k, ("batch", None, "kv_heads", None))
    v = logical_constraint(v, ("batch", None, "kv_heads", None))
    return q, k, v


# --------------------------------------------------------------------------
# GQA self-attention
# --------------------------------------------------------------------------
def gqa_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array, causal: bool = True,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full (train/prefill) self-attention. Returns (out, kv)."""
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = scaled_attention(q, k, v, cfg.head_dim ** -0.5, causal=causal)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return logical_constraint(out, ("batch", "seq", None)), {"k": k, "v": v}


def gqa_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
               cfg: ModelConfig, *, pos: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x:[B,1,D]; cache k/v:[B,S,K,D] seq-sharded; pos:[B]."""
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    k_cache = _scatter_kv(cache["k"], k_new, pos)
    v_cache = _scatter_kv(cache["v"], v_new, pos)
    out = scaled_attention(q, k_cache, v_cache, cfg.head_dim ** -0.5,
                           causal=False, kv_valid=pos + 1)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    out = logical_constraint(out, ("batch", None, None))
    return out, {"k": k_cache, "v": v_cache}


def _scatter_kv(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write new:[B,1,K,D] into cache:[B,S,K,D] at per-example pos:[B].

    Scatter (not a whole-cache select): with the cache argument donated,
    XLA updates the B affected rows in place — O(B·K·D) traffic instead of
    O(B·S·K·D) per layer per step.
    """
    if _BASELINE:
        sel = (jnp.arange(cache.shape[1])[None, :]
               == pos[:, None])[:, :, None, None]
        out = jnp.where(sel, new.astype(cache.dtype), cache)
    else:
        out = cache.at[jnp.arange(cache.shape[0]), pos].set(
            new[:, 0].astype(cache.dtype))
    return logical_constraint(out, ("batch", "kv_seq", "kv_heads", None))


def _chunk_attention(q, k, v, scale, q_pos):
    """Chunk-prefill attention: queries [B,S,H,D] at absolute positions
    ``q_pos`` [B,S] against a full cache k/v [B,L,K,D].  Per-row causal mask
    ``kv_pos <= q_pos`` — everything at or before a query's position was
    written by this request's own chunks, so stale KV from a previous slot
    occupant (only ever at later positions) is masked out structurally.
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    odt = q.dtype
    k = k.astype(q.dtype)
    v = v.astype(q.dtype)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qh = q.transpose(0, 2, 1, 3)                           # [B,H,S,D]
    scores = jnp.einsum("bhsd,bthd->bhst", qh, k,
                        preferred_element_type=jnp.float32) * scale
    scores = logical_constraint(scores, ("batch", "heads", "seq", None))
    mask = jnp.arange(t)[None, None, None, :] <= q_pos[:, None, :, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bhsd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 2, 1, 3).astype(odt)


def gqa_chunk(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
              cfg: ModelConfig, *, positions: jax.Array,
              chunk_len: jax.Array
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Cached multi-token prefill continuation (chunked prefill).

    x: [B,S,D] one prompt chunk per row; cache k/v: [B,L,K,D];
    positions: [B,S] absolute position of every chunk column;
    chunk_len: [B] valid tokens per row (0 = idle row: nothing is written
    and the row's output is garbage the caller discards).
    """
    b, s, _ = x.shape
    t = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    # scatter valid chunk KV into the cache; padding columns and idle rows
    # get an out-of-bounds index, which scatter drops
    idx = jnp.where(jnp.arange(s)[None, :] < chunk_len[:, None],
                    positions, t)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s))
    k_cache = cache["k"].at[bidx, idx].set(
        k_new.astype(cache["k"].dtype), mode="drop")
    v_cache = cache["v"].at[bidx, idx].set(
        v_new.astype(cache["v"].dtype), mode="drop")
    k_cache = logical_constraint(k_cache,
                                 ("batch", "kv_seq", "kv_heads", None))
    v_cache = logical_constraint(v_cache,
                                 ("batch", "kv_seq", "kv_heads", None))
    out = _chunk_attention(q, k_cache, v_cache, cfg.head_dim ** -0.5,
                           positions)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return (logical_constraint(out, ("batch", "seq", None)),
            {"k": k_cache, "v": v_cache})


# --------------------------------------------------------------------------
# cross-attention (VLM / enc-dec): kv from a fixed memory
# --------------------------------------------------------------------------
def cross_forward(p: Params, x: jax.Array, memory: jax.Array,
                  cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    q, k, v = _project_qkv(p, x, memory.astype(x.dtype), cfg)  # no rope
    out = scaled_attention(q, k, v, cfg.head_dim ** -0.5, causal=False)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return logical_constraint(out, ("batch", "seq", None)), {"k": k, "v": v}


def cross_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                 cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode-time cross-attention against prefill-cached memory KV."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)[None, None]
    q = logical_constraint(q, ("batch", None, "heads", None))
    out = scaled_attention(q, cache["k"].astype(x.dtype),
                           cache["v"].astype(x.dtype),
                           cfg.head_dim ** -0.5, causal=False)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return logical_constraint(out, ("batch", None, None)), cache


# --------------------------------------------------------------------------
# MLA (multi-head latent attention)
# --------------------------------------------------------------------------
def _mla_qkv(p: Params, x: jax.Array, latent: jax.Array, k_rope: jax.Array,
             cfg: ModelConfig, q_positions: jax.Array):
    """Project q from x and expand k/v from (latent, k_rope)."""
    m = cfg.mla
    dtype = x.dtype
    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dtype)),
                  p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", qa, p["wq_b"].astype(dtype))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, q_positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = logical_constraint(q, ("batch", None, "heads", None))

    k_nope = jnp.einsum("btr,rhe->bthe", latent, p["wk_b"].astype(dtype))
    v = jnp.einsum("btr,rhe->bthe", latent, p["wv_b"].astype(dtype))
    kr = jnp.broadcast_to(k_rope[:, :, None, :].astype(k_nope.dtype),
                          (*k_nope.shape[:3], m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, kr], axis=-1)
    k = logical_constraint(k, ("batch", None, "heads", None))
    v = logical_constraint(v, ("batch", None, "heads", None))
    return q, k, v


def mla_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.mla
    kv = jnp.einsum("btd,dr->btr", x, p["wkv_a"].astype(x.dtype))
    latent, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    latent = rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    q, k, v = _mla_qkv(p, x, latent, k_rope, cfg, positions)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = scaled_attention(q, k, v, scale, causal=True)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return (logical_constraint(out, ("batch", "seq", None)),
            {"latent": latent, "k_rope": k_rope})


def mla_decode(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
               cfg: ModelConfig, *, pos: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Latent-cache decode. cache: latent [B,S,r], k_rope [B,S,dr].

    Default path uses **absorbed matmuls** (§Perf bonus iteration): instead
    of re-expanding K/V = latent·W_kb / latent·W_vb over the whole cache
    every step (O(S·h·(d_n+d_v)) traffic), the query is absorbed into the
    latent space (q·W_kb once, O(h·r)) and attention runs directly against
    the compressed cache — O(S·r) reads, a ~17× traffic cut for MiniCPM3.
    ``REPRO_ATTN_BASELINE=1`` restores the naive expand-then-attend form.
    """
    m = cfg.mla
    kv = jnp.einsum("btd,dr->btr", x, p["wkv_a"].astype(x.dtype))
    lat_new, kr_new = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    lat_new = rms_norm(lat_new, p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kr_new[:, :, None, :], pos[:, None],
                        cfg.rope_theta)[:, :, 0, :]

    b = cache["latent"].shape[0]
    s = cache["latent"].shape[1]
    ar = jnp.arange(b)
    latent = cache["latent"].at[ar, pos].set(
        lat_new[:, 0].astype(cache["latent"].dtype))
    k_rope = cache["k_rope"].at[ar, pos].set(
        kr_new[:, 0].astype(cache["k_rope"].dtype))
    latent = logical_constraint(latent, ("batch", "kv_seq", "rank"))
    k_rope = logical_constraint(k_rope, ("batch", "kv_seq", None))
    new_cache = {"latent": latent, "k_rope": k_rope}
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    if _BASELINE:
        q, k, v = _mla_qkv(p, x, latent.astype(x.dtype),
                           k_rope.astype(x.dtype), cfg, pos[:, None])
        out = scaled_attention(q, k, v, scale, causal=False,
                               kv_valid=pos + 1)
        out = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
        return logical_constraint(out, ("batch", None, None)), new_cache

    # absorbed path
    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
                  p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", qa, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope,
                       p["wk_b"].astype(x.dtype))          # [B,1,H,r]
    latf = latent.astype(x.dtype)
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, latf,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope,
                           k_rope.astype(x.dtype),
                           preferred_element_type=jnp.float32)) * scale
    scores = logical_constraint(scores, ("batch", "heads", None, "kv_seq"))
    valid = (jnp.arange(s)[None, :] <= pos[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)                # [B,H,1,S]
    ctx = jnp.einsum("bhst,btr->bshr", probs.astype(latf.dtype), latf,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    vh = jnp.einsum("bshr,rhe->bshe", ctx, p["wv_b"].astype(x.dtype))
    out = jnp.einsum("bshe,hed->bsd", vh, p["wo"].astype(x.dtype))
    return logical_constraint(out, ("batch", None, None)), new_cache
