"""Shared model machinery: parameter specs, logical-axis sharding, norms, RoPE.

Parameter system
----------------
Models declare parameters as trees of :class:`P` leaves (shape + logical
axis names + init).  From one declaration we derive:

* concrete initialisation (``init_params``),
* abstract ``ShapeDtypeStruct`` trees for ``jax.eval_shape``/dry-run
  (``abstract_params``),
* ``NamedSharding`` trees via logical→mesh rules (``tree_shardings``).

Logical→mesh resolution is *shape aware*: a mesh axis is only used if it
divides the dimension, and never twice within one array (left-to-right
priority), which automatically resolves e.g. expert(model) vs ffn(model)
conflicts on expert weights.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# jax moved shard_map out of jax.experimental in 0.4.38+ / 0.5; support both
# spellings so the manual-SPMD layers run on every toolchain we ship against.
try:
    shard_map = jax.shard_map
except AttributeError:                                 # jax <= 0.4.37
    from jax.experimental.shard_map import shard_map

Tree = Any

# --------------------------------------------------------------------------
# logical axis rules
# --------------------------------------------------------------------------
# logical name -> mesh axes to try, in order; tuples try the full product
# first, then prefixes.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),           # sequence parallelism of activations
    "kv_seq": ("data", "model"),  # decode KV cache sequence dim
    "vocab": ("model",),
    "embed": ("data",),           # FSDP on d_model dims of weights
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "expert": ("model",),
    "d_inner": ("model",),        # mamba inner dim
    "layers": (),                 # stacked scan dim: never sharded
    "rank": (),                   # MLA low-rank dims: replicated
}


@dataclasses.dataclass(frozen=True)
class P:
    """Declaration of one parameter."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    scale: float = 1.0            # stddev multiplier for normal/scaled
    dtype: Optional[str] = None   # override the model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


class _MeshCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _MeshCtx()


class use_mesh:
    """Context manager activating a mesh (+ optional rule overrides)."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict] = None):
        self.mesh, self.rules = mesh, rules
        self._saved: Tuple = ()

    def __enter__(self):
        self._saved = (_CTX.mesh, _CTX.rules)
        _CTX.mesh = self.mesh
        if self.rules is not None:
            _CTX.rules = {**DEFAULT_RULES, **self.rules}
        return self.mesh

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._saved
        return False


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> Dict[str, Tuple[str, ...]]:
    return _CTX.rules


# --------------------------------------------------------------------------
# logical -> PartitionSpec resolution
# --------------------------------------------------------------------------
def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(shape: Sequence[int],
                 axes: Sequence[Optional[str]],
                 mesh: Mesh,
                 rules: Optional[Dict] = None) -> PartitionSpec:
    """Shape-aware logical→mesh PartitionSpec with conflict resolution."""
    rules = rules if rules is not None else current_rules()
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        if name is None or name not in rules:
            entries.append(None)
            continue
        cand = [a for a in rules[name] if a in sizes and a not in used]
        # longest prefix of candidate axes whose product divides dim
        chosen: Tuple[str, ...] = ()
        prod = 1
        for a in cand:
            if dim % (prod * sizes[a]) == 0:
                prod *= sizes[a]
                chosen = chosen + (a,)
            else:
                break
        if chosen:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def named_sharding(shape, axes, mesh=None, rules=None) -> Optional[NamedSharding]:
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(shape, axes, mesh, rules))


def logical_constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` under the active mesh; no-op without one."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# parameter trees
# --------------------------------------------------------------------------
def _is_leaf(x) -> bool:
    return isinstance(x, P)


def _leaf_dtype(p: P, default_dtype: str) -> jnp.dtype:
    return jnp.dtype(p.dtype or default_dtype)


def _init_leaf(p: P, key, default_dtype: str, stack: int = 0) -> jax.Array:
    shape = (stack, *p.shape) if stack else p.shape
    dt = _leaf_dtype(p, default_dtype)
    if p.init == "zeros":
        return jnp.zeros(shape, dt)
    if p.init == "ones":
        return jnp.ones(shape, dt)
    if p.init == "embed":
        std = p.scale
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)
    # normal / scaled: fan-in scaled init on the second-to-last dim
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)


def init_params(tree: Tree, key: jax.Array, default_dtype: str = "float32",
                stack: int = 0) -> Tree:
    """Initialise a tree of :class:`P`; ``stack`` adds a leading scan dim."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(p, k, default_dtype, stack) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree: Tree, default_dtype: str = "float32",
                    stack: int = 0) -> Tree:
    """ShapeDtypeStruct tree (with shardings if a mesh is active)."""
    mesh = current_mesh()

    def mk(p: P):
        shape = (stack, *p.shape) if stack else p.shape
        axes = (("layers",) + tuple(p.axes)) if stack else tuple(p.axes)
        sh = named_sharding(shape, axes, mesh) if mesh is not None else None
        return jax.ShapeDtypeStruct(shape, _leaf_dtype(p, default_dtype),
                                    sharding=sh)

    return jax.tree.map(mk, tree, is_leaf=_is_leaf)


def tree_shardings(tree: Tree, mesh: Optional[Mesh] = None, stack: int = 0,
                   rules: Optional[Dict] = None) -> Tree:
    """NamedSharding tree matching a P-tree."""
    mesh = mesh if mesh is not None else current_mesh()

    def mk(p: P):
        shape = (stack, *p.shape) if stack else p.shape
        axes = (("layers",) + tuple(p.axes)) if stack else tuple(p.axes)
        return NamedSharding(mesh, resolve_spec(shape, axes, mesh, rules))

    return jax.tree.map(mk, tree, is_leaf=_is_leaf)


def tree_bytes(tree: Tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)).reshape(
        (1,) * (x.ndim - 1) + (-1,))    # explicit: rank promotion raises
    return (y * w).astype(x.dtype)


def activation_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu,
            "gelu": jax.nn.gelu}[name]


# RoPE ---------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D] (or D rotary slice); positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    freqs = freqs.reshape((1,) * positions.ndim + (-1,))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    # insert head axis
    angles = angles[..., None, :]                      # [..., S, 1, d/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, q_offset: Union[int, jax.Array] = 0):
    """Boolean [q_len, kv_len] mask, True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def length_mask(kv_len: int, valid: jax.Array):
    """[..., kv_len] mask from per-example valid lengths."""
    return jnp.arange(kv_len)[None, :] < valid[..., None]
