"""Mamba-1 selective SSM block (falcon-mamba / jamba mamba layers).

The selective scan ``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t`` is computed
with ``jax.lax.associative_scan`` over the sequence axis — parallel depth
O(log S), TPU friendly — with the inner dimension sharded over "model"
(the scan axis is elementwise in d_inner/d_state so the sharding is free).
Decode keeps O(1) state: (conv window, ssm state) per layer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import P, logical_constraint

Params = Dict[str, jax.Array]


def ssm_spec(cfg: ModelConfig) -> Dict[str, P]:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in = s.expand * d
    dtr = s.resolved_dt_rank(d)
    return {
        "w_in": P((d, 2 * d_in), ("embed", "d_inner")),
        "conv_w": P((s.d_conv, d_in), (None, "d_inner")),
        "conv_b": P((d_in,), ("d_inner",), init="zeros"),
        "w_x": P((d_in, dtr + 2 * s.d_state), ("d_inner", None)),
        "w_dt": P((dtr, d_in), (None, "d_inner")),
        "b_dt": P((d_in,), ("d_inner",), init="ones", dtype="float32"),
        "a_log": P((d_in, s.d_state), ("d_inner", None), init="ones",
                   dtype="float32"),
        "d_skip": P((d_in,), ("d_inner",), init="ones", dtype="float32"),
        "w_out": P((d_in, d), ("d_inner", "embed")),
    }


def _ssm_core(p: Params, xz: jax.Array, conv_state: jax.Array,
              ssm_state: jax.Array, cfg: ModelConfig, seq_mode: bool
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared selective-SSM math.

    xz: [B, S, 2*d_in]; conv_state [B, d_conv-1, d_in] (history);
    ssm_state [B, d_in, N].  Returns (y [B,S,d_in->d after out proj later],
    new conv_state, new ssm_state).
    """
    s_cfg = cfg.ssm or SSMConfig()
    n = s_cfg.d_state
    d_in = xz.shape[-1] // 2
    x, z = jnp.split(xz, 2, axis=-1)                      # [B,S,d_in]

    # depthwise causal conv1d over seq with carried history
    hist = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    dc = s_cfg.d_conv
    x_conv = sum(hist[:, i:i + x.shape[1], :]
                 * p["conv_w"][i].astype(x.dtype)[None, None]
                 for i in range(dc))
    x_conv = x_conv + p["conv_b"].astype(x.dtype)[None, None]
    x_conv = jax.nn.silu(x_conv.astype(jnp.float32))      # [B,S,d_in] f32
    new_conv_state = hist[:, -(dc - 1):, :] if dc > 1 else hist[:, :0, :]

    # input-dependent Δ, B, C
    dtr = p["w_dt"].shape[0]
    proj = jnp.einsum("bsd,de->bse", x_conv.astype(x.dtype),
                      p["w_x"].astype(x.dtype)).astype(jnp.float32)
    dt, b_mat, c_mat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt.astype(x.dtype),
                    p["w_dt"].astype(x.dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["b_dt"][None, None])      # [B,S,d_in]
    a = -jnp.exp(p["a_log"])                              # [d_in,N]

    da = jnp.exp(dt[..., None] * a[None, None])           # [B,S,d_in,N]
    dbx = dt[..., None] * b_mat[:, :, None, :] * x_conv[..., None]

    if seq_mode:
        # prepend carried state as step 0: h_0 absorbed via first element
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        # include initial state by adding da_0 * ssm_state to b_0
        dbx = dbx.at[:, 0].add(da[:, 0] * ssm_state[:, None, :, :][:, 0])
        _, h = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        new_ssm_state = h[:, -1]                          # [B,d_in,N]
    else:
        h = (da[:, 0] * ssm_state + dbx[:, 0])[:, None]   # [B,1,d_in,N]
        new_ssm_state = h[:, 0]

    y = jnp.einsum("bsdn,bsn->bsd", h, c_mat)             # [B,S,d_in]
    y = y + x_conv * p["d_skip"][None, None]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(xz.dtype), new_conv_state.astype(xz.dtype), new_ssm_state


def ssm_forward(p: Params, x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence mamba block. x: [B,S,D] -> (out, final states)."""
    s_cfg = cfg.ssm or SSMConfig()
    d_in = s_cfg.expand * cfg.d_model
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    xz = logical_constraint(xz, ("batch", None, "d_inner"))
    b = x.shape[0]
    conv0 = jnp.zeros((b, s_cfg.d_conv - 1, d_in), x.dtype)
    ssm0 = jnp.zeros((b, d_in, s_cfg.d_state), jnp.float32)
    y, conv_st, ssm_st = _ssm_core(p, xz, conv0, ssm0, cfg, seq_mode=True)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    out = logical_constraint(out, ("batch", "seq", None))
    return out, {"conv": logical_constraint(conv_st, ("batch", None, "d_inner")),
                 "ssm": logical_constraint(ssm_st, ("batch", "d_inner", None))}


def ssm_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array],
               cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step. x: [B,1,D]; state: conv [B,dc-1,d_in], ssm [B,d_in,N]."""
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    xz = logical_constraint(xz, ("batch", None, "d_inner"))
    y, conv_st, ssm_st = _ssm_core(p, xz, state["conv"],
                                   state["ssm"], cfg, seq_mode=False)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    out = logical_constraint(out, ("batch", None, None))
    return out, {"conv": logical_constraint(conv_st, ("batch", None, "d_inner")),
                 "ssm": logical_constraint(ssm_st, ("batch", "d_inner", None))}
