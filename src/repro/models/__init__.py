"""Model zoo. Import submodules explicitly, e.g.
``from repro.models import transformer`` — the package init stays empty to
avoid import cycles with :mod:`repro.core` (whose EP MoE is a layer inside
the transformer stack).
"""
