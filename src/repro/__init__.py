"""repro: ReaLB (real-time load balancing for multimodal MoE inference) on TPU/JAX."""
__version__ = "0.1.0"
