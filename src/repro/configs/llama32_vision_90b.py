"""llama-3.2-vision-90b — cross-attn image layers (4 self : 1 cross per 5).

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  Vision frontend is a
stub: ``input_specs()`` provides precomputed patch embeddings
``[B, n_vision_tokens, d_model]``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    layer_pattern="cross5",       # every 5th layer cross-attends to vision
    activation="swiglu",
    rope_theta=500000.0,
    n_vision_tokens=1601,         # one 560x560 tile + cls, llama-vision style
)
