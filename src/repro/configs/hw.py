"""Single source of truth for TPU v5e hardware constants.

Every module that prices compute against the hardware — the launch-time
roofline (`repro.launch.roofline`), the analytic serving cost model
(`benchmarks/costmodel.py`), and the runtime FLOP/byte ledger
(`repro.obs.ledger`) — imports these numbers from here so a calibration
change lands everywhere at once.

Per-chip figures:

- ``PEAK_BF16``: 197 TFLOP/s dense bf16 MXU rate.
- ``PEAK_INT8``: 394 TFLOP/s int8 MXU rate (2x bf16) — the rate FP4
  experts run at after dequant-to-int8-scale inside the grouped GEMM.
- ``HBM_BW``: 819 GB/s HBM bandwidth.
- ``PEAK_FLOPS``: legacy alias for ``PEAK_BF16`` kept for the roofline
  module's public name.

Inter-chip (ICI) bandwidth is *not* defined here: the serving stack
single-sources it as ``repro.configs.base.MIGRATION_BW_DEFAULT`` (50
GB/s/link) because the measured-bandwidth EWMA can override it at run
time; static consumers import that constant directly.
"""
from __future__ import annotations

PEAK_BF16 = 197e12           # FLOP/s / chip, dense bf16
PEAK_INT8 = 394e12           # FLOP/s / chip, int8 MXU rate (2x bf16)
PEAK_FLOPS = PEAK_BF16       # legacy roofline name
HBM_BW = 819e9               # B/s / chip

__all__ = ["PEAK_BF16", "PEAK_INT8", "PEAK_FLOPS", "HBM_BW"]
