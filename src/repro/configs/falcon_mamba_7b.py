"""falcon-mamba-7b — attention-free Mamba-1. [arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,                   # mamba block subsumes the FFN
    vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    layer_pattern="ssm",
    tie_embeddings=True,
)
