"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  This is the LLM backbone of the
paper's primary model (Kimi-VL-A3B = MoonViT frontend + this backbone),
so it is the main ReaLB evaluation architecture.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11264,              # dense FFN for the leading dense layer
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, n_shared_experts=2, capacity_factor=1.25),
    n_dense_layers=1,        # deepseek-v3-style leading dense layer
    layer_pattern="attn",
    activation="swiglu",
    rope_theta=50000.0,
)
