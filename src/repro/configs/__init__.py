"""Architecture registry: ``get_config(arch_id)`` + shape/mesh exports."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    MeshConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    MULTI_POD_MESH,
    PlacementConfig,
    PREFILL_32K,
    ReaLBConfig,
    ReplicationConfig,
    ShapeConfig,
    SINGLE_POD_MESH,
    SSMConfig,
    TRAIN_4K,
    TrainConfig,
    reduced,
)

_ARCH_MODULES: Dict[str, str] = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-large-v3": "whisper_large_v3",
    "gemma-7b": "gemma_7b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen1.5-0.5b": "qwen15_05b",
    "command-r-35b": "command_r_35b",
    "jamba-1.5-large-398b": "jamba_15_large_398b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and cfg.full_attention_only:
        return False, ("skip: long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention")
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """(arch, shape, supported, reason) for all 40 assigned cells."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            ok, why = shape_supported(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out
