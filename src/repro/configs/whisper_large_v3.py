"""whisper-large-v3 — enc-dec, conv frontend stubbed. [arXiv:2212.04356; unverified]

The assigned "32L" is interpreted as the published 32-encoder +
32-decoder-layer stack; the conv/mel frontend is a stub supplying 1500
frame embeddings ``[B, 1500, 1280]``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,              # decoder layers
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    is_encdec=True,
    enc_seq_len=1500,
    layer_pattern="attn",
    activation="gelu",
    qkv_bias=True,
)
