"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes
as ``ShapeConfig``; distribution as ``MeshConfig``.  Configs are frozen
dataclasses so they are hashable (usable as jit static args).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts layer config (routed experts)."""

    num_experts: int
    top_k: int
    d_ff: int                      # per-expert ffn hidden size
    n_shared_experts: int = 0      # deepseek-style always-on experts
    capacity_factor: float = 2.0   # dispatch buffer provisioning (× ideal)
    router_dtype: str = "float32"
    moe_every: int = 1             # apply MoE on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    aux_loss_coef: float = 0.01    # load-balancing loss (training only)
    router_z_coef: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek/MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM config."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclass(frozen=True)
class ModelConfig:
    """One architecture from the assigned pool."""

    name: str
    family: str                 # moe | dense | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                   # dense-ffn hidden size (0 for attn-free)
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # layer pattern: "attn" (all attention), "ssm" (all mamba),
    # "jamba" (1 attn : 7 mamba per 8-block), "cross5" (4 self + 1 cross per 5-block)
    layer_pattern: str = "attn"
    n_dense_layers: int = 0     # leading layers that use dense FFN even in MoE models

    activation: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    embed_scale_sqrt_d: bool = False   # gemma-style sqrt(d) embedding scale

    # encoder-decoder (whisper)
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 0        # fixed encoder length (whisper: 1500 frames)

    # vlm: number of vision tokens supplied by the (stubbed) frontend
    n_vision_tokens: int = 0

    param_dtype: str = "bfloat16"
    remat: str = "full"         # none | full  (activation checkpointing)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived -----------------------------------------------------
    @property
    def uses_attention(self) -> bool:
        return self.layer_pattern != "ssm"

    @property
    def full_attention_only(self) -> bool:
        """True if every token-mixing layer is quadratic attention."""
        return self.layer_pattern in ("attn", "cross5") or self.is_encdec

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer token-mixer kind for the decoder stack."""
        kinds = []
        for i in range(self.n_layers):
            if self.layer_pattern == "attn":
                kinds.append("attn")
            elif self.layer_pattern == "ssm":
                kinds.append("ssm")
            elif self.layer_pattern == "jamba":
                kinds.append("attn" if i % 8 == 0 else "ssm")
            elif self.layer_pattern == "cross5":
                kinds.append("cross" if i % 5 == 4 else "attn")
            else:
                raise ValueError(self.layer_pattern)
        return tuple(kinds)

    def ffn_kinds(self) -> Tuple[str, ...]:
        kinds = []
        for i in range(self.n_layers):
            if self.moe is not None and i >= self.n_dense_layers \
                    and (i - self.n_dense_layers) % self.moe.moe_every == self.moe.moe_offset:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    @property
    def scan_period(self) -> int:
        """Layers per scanned block of the decoder stack (the repeating
        unit of the ``lax.scan`` block layout) — the single source both
        ``repro.models.transformer.block_structure`` and the host-side
        planners derive block counts from."""
        return {"jamba": 8, "cross5": 5}.get(self.layer_pattern, 1)

    def moe_block_structure(self) -> Tuple[int, int]:
        """(n_scan_blocks, n_moe_layers_per_block) of the scanned decoder
        stack — the granularity of per-layer placement/replication tables
        (one table per scan block; the stacked ``[n_blocks, ...]`` tables
        ride the layer scan alongside the block params).  Matches
        ``repro.models.transformer.block_structure`` without importing the
        model stack, so host-side planners stay jax-free."""
        period = self.scan_period
        rest = self.ffn_kinds()[self.n_dense_layers:]
        assert len(rest) % period == 0, (len(rest), period)
        return len(rest) // period, sum(1 for f in rest[:period]
                                        if f == "moe")

    # parameter counting ------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (embedding + decoder [+ encoder])."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self._stack_params(self.layer_kinds(), self.ffn_kinds())
        if self.is_encdec:
            n += self.enc_seq_len * 0  # stub frontend holds no params here
            n += self._stack_params(("attn",) * self.n_enc_layers,
                                    ("dense",) * self.n_enc_layers)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared only)."""
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        n += self._stack_params(self.layer_kinds(), self.ffn_kinds(), active=True)
        if self.is_encdec:
            n += self._stack_params(("attn",) * self.n_enc_layers,
                                    ("dense",) * self.n_enc_layers, active=True)
        return n

    def _stack_params(self, layer_kinds, ffn_kinds, active: bool = False) -> int:
        d = self.d_model
        total = 0
        for mix, ffn in zip(layer_kinds, ffn_kinds):
            # token mixer
            if mix in ("attn", "cross"):
                if self.mla is not None:
                    m = self.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * self.head_dim          # q
                    total += 2 * d * self.n_kv_heads * self.head_dim   # k,v
                    total += self.n_heads * self.head_dim * d          # o
                if mix == "cross":  # extra kv proj for cross-attn path
                    total += 2 * d * self.n_kv_heads * self.head_dim
            elif mix == "ssm":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                dtr = s.resolved_dt_rank(d)
                total += d * 2 * d_in                  # in_proj
                total += d_in * s.d_conv               # conv
                total += d_in * (dtr + 2 * s.d_state)  # x_proj
                total += dtr * d_in + d_in             # dt_proj
                total += d_in * s.d_state + d_in       # A_log, D
                total += d_in * d                      # out_proj
            # ffn
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            if ffn == "moe":
                e = self.moe
                per = mult * d * e.d_ff
                n_e = (e.top_k if active else e.num_experts)
                total += n_e * per + e.n_shared_experts * per
                total += d * e.num_experts             # router
            else:
                dff = self.d_ff if self.d_ff else (self.moe.d_ff if self.moe else 0)
                if dff:
                    total += mult * d * dff
            total += 2 * d  # two rmsnorm scales
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


# Nominal expert-slab migration bandwidth (bytes/s, ICI-class link).  The
# single source shared by PlacementConfig / ReplicationConfig defaults,
# the analytic cost model's ICI constant (benchmarks.costmodel.ICI_BW)
# and the measured-bandwidth EWMA's prior
# (repro.placement.migrate.MigrationBandwidth) — so sims, replan gates
# and engine accounting price the same bytes at the same rate until a
# measured value replaces it.
MIGRATION_BW_DEFAULT = 50e9


@dataclass(frozen=True)
class ReaLBConfig:
    """Paper hyper-parameters (§4.2, §5.1)."""

    enabled: bool = True
    capacity_c: float = 1.0       # hotspot threshold C on IB_d
    tau: float = 1.5              # AIMD congestion threshold on IB_global
    md_init: float = 0.9          # initial modality threshold
    md_add: float = 0.1           # additive increase
    md_mult: float = 0.5          # multiplicative decrease
    md_min: float = 0.0
    gate_gamma: int = 2048        # Γ: global token threshold for LB gate
    adaptive: bool = True         # False -> ReaLB-m* fixed-threshold variants
    overlap: bool = True          # False -> ReaLB-seq
    group_size: int = 16          # NVFP4 quant group
    wq_bits: int = 4


@dataclass(frozen=True)
class PlacementConfig:
    """Predictive expert→rank placement & live migration (repro.placement).

    The placement loop is the slow-timescale complement to ReaLB: a
    per-layer EWMA predictor of expert loads feeds a planner that remaps
    experts across EP ranks every ``replan_every`` engine iterations;
    ReaLB's FP4 compression absorbs whatever fast-timescale burst the plan
    could not anticipate.
    """

    enabled: bool = True
    planner: str = "least_loaded"  # identity | least_loaded | modality_aware
    replan_every: int = 32         # engine iterations between replans
    warmup_iters: int = 4          # observations required before planning
    ewma_alpha: float = 0.25       # predictor smoothing (1 = last iter only)
    min_gain: float = 0.02         # skip migration below this predicted
    #                                relative reduction of the max rank load
    vis_tol: float = 0.25          # modality_aware: max |r_v| difference for
    #                                a load-balancing swap
    max_swaps: int = 64            # modality_aware: refinement swap budget
    migration_bw: float = MIGRATION_BW_DEFAULT
    #                              # bytes/s charged for moved expert slabs
    #                                in virtual-time serving runs (ICI-class);
    #                                the prior of the measured-bandwidth EWMA
    per_layer: bool = False        # one table per scanned MoE block instead
    #                                of one shared table; migration becomes
    #                                a layer-diff (changed layers only)
    decode_halflife: float = 0.0   # decode-window EWMA half-life in decode
    #                                iterations (0 = single shared window)
    decode_replan_every: int = 0   # decode iterations between decode-regime
    #                                replans (0 = prefill cadence only)
    max_changed_layers: int = 0    # per-replan churn budget: cap on changed
    #                                layers per per-layer replan, filled in
    #                                predicted-gain order; recovery layers
    #                                are exempt (0 = unlimited)


@dataclass(frozen=True)
class ReplicationConfig:
    """Redundant experts with token-split dispatch (repro.replication).

    The third arm of the comparison: instead of *moving* hot experts
    (placement) or *compressing* them (ReaLB), duplicate them — each rank
    provisions ``spare_per_rank`` extra weight slots beyond its bijective
    ``E // n_ranks`` slab, and an EPLB-style planner fills the spares with
    replicas of the predictor's hottest (vision-weighted) experts.  Routed
    tokens are split deterministically round-robin across an expert's
    replicas, so the post-split physical loads — which the ReaLB policy
    and the capacity packing observe — are flattened.
    """

    enabled: bool = True
    spare_per_rank: int = 1        # replica slots per rank beyond E // R
    max_replicas: int = 2          # replica cap per logical expert (<= ep)
    vis_weight: float = 1.0        # hotness = load + vis_weight * vis
    replan_every: int = 32         # engine iterations between replans
    warmup_iters: int = 4          # observations required before planning
    ewma_alpha: float = 0.25       # predictor smoothing (shared w/ placement)
    min_gain: float = 0.02         # skip re-replication below this predicted
    #                                relative reduction of the max rank load
    migration_bw: float = MIGRATION_BW_DEFAULT
    #                              # bytes/s charged for copied replica slabs
    per_layer: bool = False        # one replica set per scanned MoE block;
    #                                replica adds/drops diff per layer
    decode_halflife: float = 0.0   # decode-window EWMA half-life in decode
    #                                iterations (0 = single shared window)
    decode_replan_every: int = 0   # decode iterations between decode-regime
    #                                replans (0 = prefill cadence only)
    max_changed_layers: int = 0    # per-replan churn budget: cap on changed
    #                                layers per per-layer replan, filled in
    #                                predicted-gain order; recovery layers
    #                                are exempt (0 = unlimited)
    weighted_split: bool = False   # split routed tokens across replicas
    #                                proportionally to host-rank residual
    #                                capacity (deficit round-robin schedule)
    #                                instead of equal-share round-robin


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    opt_state_dtype: str = "float32"
    grad_accum: int = 1
    grad_compression: bool = False   # int8 all-reduce w/ error feedback
    checkpoint_every: int = 100
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh + axis mapping rules."""

    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def model_axis_size(self) -> int:
        return dict(zip(self.axis_names, self.shape)).get("model", 1)

    @property
    def data_axis_size(self) -> int:
        return dict(zip(self.axis_names, self.shape)).get("data", 1)


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.layer_pattern == "attn" else 8),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
        enc_seq_len=16 if cfg.is_encdec else 0,
        n_enc_layers=2 if cfg.is_encdec else 0,
        n_vision_tokens=8 if cfg.n_vision_tokens else 0,
        param_dtype="float32",
        remat="none",
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff=64,
            capacity_factor=2.0)
    if cfg.mla is not None:
        small["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                 qk_nope_head_dim=16, qk_rope_head_dim=8,
                                 v_head_dim=16)
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2)
    if cfg.layer_pattern == "jamba":
        small["n_layers"] = 8
    if cfg.layer_pattern == "cross5":
        small["n_layers"] = 5
        small["n_vision_tokens"] = 8
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
