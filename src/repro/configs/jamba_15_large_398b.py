"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]  72 layers in 9 blocks of 8 (1 attention + 7 mamba);
MoE (16 experts, top-2) on every 2nd layer, dense FFN otherwise.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, moe_every=2, moe_offset=1, capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    layer_pattern="jamba",
    activation="swiglu",
)
