"""Serving telemetry: rolling-window aggregation of engine iterations and
request latencies into SLO-style percentiles.

The engine emits one :class:`~repro.serving.engine.IterStats` per forward
batch — prefill chunks included, which is where ReaLB's LB gate opens —
and one finished :class:`~repro.serving.scheduler.Request` per completion.
The collector keeps bounded deques (``window`` iterations / requests) so a
long-running server reports *recent* percentiles, and exposes the headline
quantities of the paper's serving evaluation: TTFT / TPOT percentiles,
``ib_global`` distribution, and LB-gate / FP4 duty cycles split by phase.

Percentiles use the linear-interpolation definition (numpy's default) but
are implemented locally so the math is unit-testable without an engine.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy 'linear' method).

    q in [0, 100].  Defined locally (not np.percentile) so the telemetry
    math is dependency-light and directly unit-tested.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q out of range: {q}")
    xs = sorted(xs)
    if not xs:
        raise ValueError("percentile of empty sequence")
    if len(xs) == 1:
        return float(xs[0])
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


def summarize(xs: Sequence[float], qs=(50, 90, 99)) -> Dict[str, float]:
    """{"p50": ..., "p90": ..., ...} plus mean; empty input -> {}."""
    xs = list(xs)
    if not xs:
        return {}
    out = {f"p{int(q)}": percentile(xs, q) for q in qs}
    out["mean"] = sum(xs) / len(xs)
    return out


@dataclasses.dataclass
class RequestLatency:
    uid: int
    ttft: float                  # arrival -> first token
    tpot: Optional[float]        # per-token after the first (None if 1 tok)
    prompt_len: int
    n_generated: int
    is_vision: bool


class Telemetry:
    """Rolling-window collector; feed it from the engine, read summaries."""

    def __init__(self, window: int = 512):
        self.window = window
        self.iters: Deque = deque(maxlen=window)        # IterStats
        self.requests: Deque[RequestLatency] = deque(maxlen=window)
        self.n_iters = 0
        self.n_requests = 0
        # migration accounting is cumulative (not windowed): the question
        # the paper's comparison asks is "how many bytes did placement move
        # over the whole run, vs. ReaLB's zero".  Bytes stay integral
        # end-to-end (plans count whole weight bytes, never fractions);
        # seconds are split into serving *stall* (migration_s_total) and
        # transfer time *hidden* under the forward by async overlap.
        self.migration_bytes_total = 0
        self.migration_s_total = 0.0
        self.migration_hidden_s_total = 0.0
        self.n_migrations = 0
        # elastic-serving availability accounting (cumulative, like the
        # migration counters): an iteration is *degraded* when >= 1
        # expert was unroutable (a rank died and took the only replica);
        # each completed recovery stamps its wall seconds
        self.degraded_iters = 0
        self.lost_tokens_total = 0.0
        self.recoveries: List[float] = []

    # -- feeds ------------------------------------------------------------
    def record_iter(self, stat) -> None:
        self.iters.append(stat)
        self.n_iters += 1
        if getattr(stat, "n_unroutable", 0) > 0:
            self.degraded_iters += 1
            self.lost_tokens_total += float(
                getattr(stat, "lost_tokens", 0.0))
        mig = getattr(stat, "migration_bytes", 0)
        mig_s = getattr(stat, "migration_s", 0.0)
        mig_h = getattr(stat, "migration_hidden_s", 0.0)
        # zero-byte migration work still carries real seconds (e.g. a
        # drained replica batch of same-rank copies priced at 0 bytes
        # under a wall clock) — never drop measured time on the floor
        if mig > 0 or mig_s > 0 or mig_h > 0:
            self.migration_bytes_total += int(mig)
            self.migration_s_total += mig_s
            self.migration_hidden_s_total += mig_h
            # NOTE: one count per iteration that carried migration
            # traffic — under async draining that is one per chunk
            # batch, not per plan; the manager's n_migrations counts
            # committed plans
            self.n_migrations += 1

    def record_recovery(self, seconds: float) -> None:
        """One completed elastic recovery (rank loss -> every expert
        routable again), in wall/virtual seconds."""
        self.recoveries.append(float(seconds))

    def record_request(self, req) -> None:
        if req.ttft is None:
            return
        self.requests.append(RequestLatency(
            uid=req.uid, ttft=req.ttft, tpot=req.tpot,
            prompt_len=req.prompt_len, n_generated=len(req.generated),
            is_vision=req.is_vision))
        self.n_requests += 1

    # -- summaries --------------------------------------------------------
    def _phase(self, phase: Optional[str]) -> List:
        return [s for s in self.iters
                if phase is None or s.phase == phase]

    def gate_duty(self, phase: Optional[str] = "prefill") -> float:
        """Fraction of (phase-filtered) iterations with the LB gate open."""
        it = self._phase(phase)
        if not it:
            return 0.0
        return sum(1.0 for s in it if s.gate_open > 0) / len(it)

    def fp4_duty(self, phase: Optional[str] = None) -> float:
        """Fraction of iterations on which >=1 rank ran its experts in FP4."""
        it = self._phase(phase)
        if not it:
            return 0.0
        return sum(1.0 for s in it if s.fp4_ranks > 0) / len(it)

    def split_duty(self, phase: Optional[str] = None) -> float:
        """Fraction of iterations on which a non-primary replica served
        routed tokens (always 0 under a bijective table)."""
        it = self._phase(phase)
        if not it:
            return 0.0
        return sum(1.0 for s in it
                   if getattr(s, "split_frac", 0.0) > 0) / len(it)

    def split_summary(self, phase: Optional[str] = None) -> Dict[str, float]:
        """Rolling-window token-split fraction percentiles."""
        return summarize([getattr(s, "split_frac", 0.0)
                          for s in self._phase(phase)])

    def ib_summary(self, phase: Optional[str] = None) -> Dict[str, float]:
        return summarize([s.ib_global for s in self._phase(phase)])

    def drop_summary(self, phase: Optional[str] = None) -> Dict[str, float]:
        """Rolling-window capacity-drop fraction percentiles."""
        return summarize([getattr(s, "drop_frac", 0.0)
                          for s in self._phase(phase)])

    @property
    def availability(self) -> float:
        """Fraction of iterations with every expert routable (1.0 when
        no iteration ever ran degraded)."""
        if self.n_iters == 0:
            return 1.0
        return 1.0 - self.degraded_iters / self.n_iters

    def ttft_summary(self) -> Dict[str, float]:
        return summarize([r.ttft for r in self.requests])

    def tpot_summary(self) -> Dict[str, float]:
        return summarize([r.tpot for r in self.requests
                          if r.tpot is not None])

    def summary(self) -> Dict[str, object]:
        """One flat report dict (benchmark / log-line friendly)."""
        by_mod = {
            "vision": [r.ttft for r in self.requests if r.is_vision],
            "text": [r.ttft for r in self.requests if not r.is_vision],
        }
        return {
            "n_iters": self.n_iters,
            "n_requests": self.n_requests,
            "ttft": self.ttft_summary(),
            "ttft_vision": summarize(by_mod["vision"]),
            "ttft_text": summarize(by_mod["text"]),
            "tpot": self.tpot_summary(),
            "ib_global": self.ib_summary(),
            "ib_global_prefill": self.ib_summary("prefill"),
            "gate_duty_prefill": self.gate_duty("prefill"),
            "gate_duty_decode": self.gate_duty("decode"),
            "fp4_duty": self.fp4_duty(),
            "fp4_duty_prefill": self.fp4_duty("prefill"),
            "drop_frac": self.drop_summary(),
            "drop_frac_prefill": self.drop_summary("prefill"),
            "split_duty": self.split_duty(),
            "split_frac": self.split_summary(),
            "migration_bytes_total": self.migration_bytes_total,
            "migration_s_total": self.migration_s_total,
            # explicit stall/hidden split: migration_s IS the stall; the
            # hidden share is the transfer time async overlap absorbed
            "migration_stall_s": self.migration_s_total,
            "migration_hidden_s": self.migration_hidden_s_total,
            "n_migrations": self.n_migrations,
            # elastic serving: availability + recovery time
            "availability": self.availability,
            "degraded_iters": self.degraded_iters,
            "lost_tokens_total": self.lost_tokens_total,
            "n_recoveries": len(self.recoveries),
            "recovery_s": max(self.recoveries) if self.recoveries
            else None,
        }
