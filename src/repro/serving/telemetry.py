"""Serving telemetry: rolling-window aggregation of engine iterations and
request latencies into SLO-style percentiles.

The engine emits one :class:`~repro.serving.engine.IterStats` per forward
batch — prefill chunks included, which is where ReaLB's LB gate opens —
and one finished :class:`~repro.serving.scheduler.Request` per completion.
The collector keeps bounded deques (``window`` iterations / requests) so a
long-running server reports *recent* percentiles, and exposes the headline
quantities of the paper's serving evaluation: TTFT / TPOT percentiles,
``ib_global`` distribution, and LB-gate / FP4 duty cycles split by phase.

Cumulative quantities (migration bytes/seconds, plan commits, elastic
availability, recoveries) live on a typed
:class:`~repro.obs.metrics.MetricsRegistry` — the seed's ad-hoc instance
attributes survive as property shims so existing readers keep working —
and two :mod:`repro.obs.metrics` recorders ride along: the per-layer
per-rank expert-load heatmap and the predicted-vs-realized peak-rank-load
accuracy tracker (opened per committed replan window).

Percentiles use the linear-interpolation definition (numpy's default);
the math lives in :mod:`repro.obs.metrics` and is re-exported here.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.obs.metrics import (HeatmapRecorder, MetricsRegistry,
                               PredictionTracker, percentile, summarize)

__all__ = ["percentile", "summarize", "RequestLatency", "Telemetry"]


@dataclasses.dataclass
class RequestLatency:
    uid: int
    ttft: float                  # arrival -> first token
    tpot: Optional[float]        # per-token after the first (None if 1 tok)
    prompt_len: int
    n_generated: int
    is_vision: bool


class Telemetry:
    """Rolling-window collector; feed it from the engine, read summaries."""

    def __init__(self, window: int = 512,
                 registry: Optional[MetricsRegistry] = None):
        self.window = window
        self.iters: Deque = deque(maxlen=window)        # IterStats
        self.requests: Deque[RequestLatency] = deque(maxlen=window)
        self.n_iters = 0
        self.n_requests = 0
        self.registry = registry if registry is not None else MetricsRegistry()
        reg = self.registry
        # migration accounting is cumulative (not windowed): the question
        # the paper's comparison asks is "how many bytes did placement move
        # over the whole run, vs. ReaLB's zero".  Bytes stay integral
        # end-to-end (plans count whole weight bytes, never fractions);
        # seconds are split into serving *stall* (migration_s_total) and
        # transfer time *hidden* under the forward by async overlap.
        self._mig_bytes = reg.counter(
            "migration_bytes", "weight bytes moved by replans")
        self._mig_s = reg.counter(
            "migration_stall_s", "serving seconds stalled on migration")
        self._mig_hidden_s = reg.counter(
            "migration_hidden_s",
            "migration transfer seconds hidden under the forward")
        # one count per iteration that carried migration traffic — under
        # async draining that is one per chunk batch, not per plan; plan
        # commits are counted separately (record_plan_commit)
        self._mig_iters = reg.counter(
            "migration_iters", "iterations carrying migration traffic")
        self._plan_commits = reg.counter(
            "plans_committed", "replan plans fully committed")
        # elastic-serving availability accounting (cumulative, like the
        # migration counters): an iteration is *degraded* when >= 1
        # expert was unroutable (a rank died and took the only replica);
        # each completed recovery stamps its wall seconds
        self._degraded = reg.counter(
            "degraded_iters", "iterations with >=1 unroutable expert")
        self._lost_tokens = reg.counter(
            "lost_tokens", "expected tokens lost to unroutable experts")
        self._recovery_hist = reg.histogram(
            "recovery_s", "seconds from rank loss to full routability")
        self.heatmap = HeatmapRecorder()
        self.prediction = PredictionTracker()

    # -- seed-compat shims: cumulative attrs now live on the registry -----
    @property
    def migration_bytes_total(self) -> int:
        return int(self._mig_bytes.value())

    @property
    def migration_s_total(self) -> float:
        return float(self._mig_s.value())

    @property
    def migration_hidden_s_total(self) -> float:
        return float(self._mig_hidden_s.value())

    @property
    def n_migrations(self) -> int:
        return int(self._mig_iters.value())

    @property
    def n_plans_committed(self) -> int:
        return int(self._plan_commits.value())

    @property
    def degraded_iters(self) -> int:
        return int(self._degraded.value())

    @property
    def lost_tokens_total(self) -> float:
        return float(self._lost_tokens.value())

    @property
    def recoveries(self) -> List[float]:
        return self._recovery_hist.values()

    # -- feeds ------------------------------------------------------------
    def record_iter(self, stat) -> None:
        self.iters.append(stat)
        self.n_iters += 1
        if getattr(stat, "n_unroutable", 0) > 0:
            self._degraded.inc()
            self._lost_tokens.inc(float(getattr(stat, "lost_tokens", 0.0)))
        mig = getattr(stat, "migration_bytes", 0)
        mig_s = getattr(stat, "migration_s", 0.0)
        mig_h = getattr(stat, "migration_hidden_s", 0.0)
        # zero-byte migration work still carries real seconds (e.g. a
        # drained replica batch of same-rank copies priced at 0 bytes
        # under a wall clock) — never drop measured time on the floor
        if mig > 0 or mig_s > 0 or mig_h > 0:
            self._mig_bytes.inc(int(mig))
            self._mig_s.inc(mig_s)
            self._mig_hidden_s.inc(mig_h)
            self._mig_iters.inc()

    def record_plan_commit(self) -> None:
        """One replan plan fully committed (sync apply, or the last
        layer of an async drain landing)."""
        self._plan_commits.inc()

    def record_rank_heatmap(self, heatmap) -> None:
        """Per-iteration ``[L, R]`` rank loads from the live tables;
        feeds the expert-load heatmap and the open prediction window."""
        if heatmap is None:
            return
        self.heatmap.record(heatmap)
        self.prediction.record(heatmap)

    def open_prediction_window(self, it: int, predicted) -> None:
        """Stamp the predictor's per-layer rank loads at a plan commit;
        closes the previous window (see PredictionTracker)."""
        self.prediction.open(it, predicted)

    def record_recovery(self, seconds: float) -> None:
        """One completed elastic recovery (rank loss -> every expert
        routable again), in wall/virtual seconds."""
        self._recovery_hist.observe(float(seconds))

    def record_request(self, req) -> None:
        if req.ttft is None:
            return
        self.requests.append(RequestLatency(
            uid=req.uid, ttft=req.ttft, tpot=req.tpot,
            prompt_len=req.prompt_len, n_generated=len(req.generated),
            is_vision=req.is_vision))
        self.n_requests += 1

    # -- summaries --------------------------------------------------------
    def _phase(self, phase: Optional[str]) -> List:
        return [s for s in self.iters
                if phase is None or s.phase == phase]

    def gate_duty(self, phase: Optional[str] = "prefill") -> float:
        """Fraction of (phase-filtered) iterations with the LB gate open."""
        it = self._phase(phase)
        if not it:
            return 0.0
        return sum(1.0 for s in it if s.gate_open > 0) / len(it)

    def fp4_duty(self, phase: Optional[str] = None) -> float:
        """Fraction of iterations on which >=1 rank ran its experts in FP4."""
        it = self._phase(phase)
        if not it:
            return 0.0
        return sum(1.0 for s in it if s.fp4_ranks > 0) / len(it)

    def split_duty(self, phase: Optional[str] = None) -> float:
        """Fraction of iterations on which a non-primary replica served
        routed tokens (always 0 under a bijective table)."""
        it = self._phase(phase)
        if not it:
            return 0.0
        return sum(1.0 for s in it
                   if getattr(s, "split_frac", 0.0) > 0) / len(it)

    def split_summary(self, phase: Optional[str] = None) -> Dict[str, float]:
        """Rolling-window token-split fraction percentiles."""
        return summarize([getattr(s, "split_frac", 0.0)
                          for s in self._phase(phase)])

    def ib_summary(self, phase: Optional[str] = None) -> Dict[str, float]:
        return summarize([s.ib_global for s in self._phase(phase)])

    def drop_summary(self, phase: Optional[str] = None) -> Dict[str, float]:
        """Rolling-window capacity-drop fraction percentiles."""
        return summarize([getattr(s, "drop_frac", 0.0)
                          for s in self._phase(phase)])

    @property
    def availability(self) -> float:
        """Fraction of iterations with every expert routable (1.0 when
        no iteration ever ran degraded)."""
        if self.n_iters == 0:
            return 1.0
        return 1.0 - self.degraded_iters / self.n_iters

    def ttft_summary(self) -> Dict[str, float]:
        return summarize([r.ttft for r in self.requests])

    def tpot_summary(self) -> Dict[str, float]:
        return summarize([r.tpot for r in self.requests
                          if r.tpot is not None])

    def summary(self) -> Dict[str, object]:
        """One flat report dict (benchmark / log-line friendly)."""
        by_mod = {
            "vision": [r.ttft for r in self.requests if r.is_vision],
            "text": [r.ttft for r in self.requests if not r.is_vision],
        }
        recoveries = self.recoveries
        return {
            "n_iters": self.n_iters,
            "n_requests": self.n_requests,
            "ttft": self.ttft_summary(),
            "ttft_vision": summarize(by_mod["vision"]),
            "ttft_text": summarize(by_mod["text"]),
            "tpot": self.tpot_summary(),
            "ib_global": self.ib_summary(),
            "ib_global_prefill": self.ib_summary("prefill"),
            "gate_duty_prefill": self.gate_duty("prefill"),
            "gate_duty_decode": self.gate_duty("decode"),
            "fp4_duty": self.fp4_duty(),
            "fp4_duty_prefill": self.fp4_duty("prefill"),
            "drop_frac": self.drop_summary(),
            "drop_frac_prefill": self.drop_summary("prefill"),
            "split_duty": self.split_duty(),
            "split_frac": self.split_summary(),
            "migration_bytes_total": self.migration_bytes_total,
            "migration_s_total": self.migration_s_total,
            # explicit stall/hidden split: migration_s IS the stall; the
            # hidden share is the transfer time async overlap absorbed
            "migration_stall_s": self.migration_s_total,
            "migration_hidden_s": self.migration_hidden_s_total,
            # "n_migrations" kept for old readers; it counts *iterations*
            # that carried migration traffic (one per async chunk batch),
            # NOT committed plans — the two unambiguous names:
            "n_migrations": self.n_migrations,
            "n_migration_iters": self.n_migrations,
            "n_plans_committed": self.n_plans_committed,
            # elastic serving: availability + recovery time
            "availability": self.availability,
            "degraded_iters": self.degraded_iters,
            "lost_tokens_total": self.lost_tokens_total,
            "n_recoveries": len(recoveries),
            # recovery_s stays the max (worst recovery) for old readers;
            # "recovery" carries the full percentile summary
            "recovery_s": max(recoveries) if recoveries else None,
            "recovery": summarize(recoveries),
            "expert_load_heatmap": self.heatmap.summary(),
            "prediction_accuracy": self.prediction.summary(),
            **self._profiler_summary(),
        }

    def _profiler_summary(self) -> Dict[str, object]:
        """Profiler-fed registry metrics, when a Profiler shares this
        registry (empty otherwise — legacy readers see no new keys on
        unprofiled runs, and the keys above never change meaning)."""
        reg = self.registry
        mfu = reg.get("mfu")
        if mfu is None or mfu.value() is None:
            return {}
        out: Dict[str, object] = {"mfu": float(mfu.value())}
        roof = reg.get("roofline_fraction")
        if roof is not None and roof.value() is not None:
            out["roofline_fraction"] = float(roof.value())
        scale = reg.get("costmodel_time_scale")
        if scale is not None and scale.value() is not None:
            out["costmodel_time_scale"] = float(scale.value())
        flops = reg.get("model_flops")
        if flops is not None:
            out["model_flops_total"] = float(flops.total())
        for name in ("phase_seconds", "phase_seconds_pred"):
            ctr = reg.get(name)
            if ctr is not None:
                out[name] = {k[0]: float(ctr.value(phase=k[0]))
                             for k in ctr.labelsets()}
        return out
