"""Asynchronous overlapped migration: per-layer slab streaming with
measured-bandwidth budgeting (HarMoEny-style layer-wise rebalancing).

The synchronous migration path applies a staged plan's entire slab
permutation between two serving iterations — a hard stall proportional
to the whole transfer.  This module turns a staged (layer-diff) plan
into a queue of per-layer :class:`SlabChunk` s and drains a
*byte-budgeted* batch of chunks per serving iteration instead:

- **chunking** — each changed layer of a
  :class:`~repro.placement.migrate.LayerMigrationPlan` /
  :class:`~repro.replication.migrate.LayerReplicaMigrationPlan` is one
  chunk (a shared plan degenerates to a single whole-plan chunk);
- **budgeting** — the per-iteration byte budget is either explicit
  (``bytes_per_iter``) or derived from the manager's *measured*
  bytes/s EWMA (:class:`~repro.placement.migrate.MigrationBandwidth`)
  times the engine's recent iteration seconds: the bytes that fit under
  one iteration's compute, i.e. the transfer the overlap can hide;
- **calibration** — every drained batch's ``apply_to_params`` wall
  clock is timed (device-synchronized) and fed back into the bandwidth
  EWMA, which also prices ``manager.migration_seconds`` and the
  :class:`benchmarks.costmodel.CalibratedReplanCostGate` — closing the
  ROADMAP migration-bandwidth-calibration loop;
- **per-layer commit** — as each chunk lands, exactly that layer's
  table is committed (``manager.commit_layers``), so serving keeps
  routing through the *old* table for layers whose slab has not landed
  and through the *new* table for layers that have.  The consistency
  rule is preserved per layer: a layer's new table becomes routable
  only after its slab landed.

The executor is deliberately host-side and engine-agnostic: the engine
owns the clock accounting (stall vs. hidden seconds) and the decision
of when to drain; the executor owns the queue, the subset applies, the
timing and the per-layer commits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.placement import migrate as pmigrate

# bytes the first drain may assume fit under one iteration when the
# engine has no iteration-seconds estimate yet (~2 ms of transfer)
DEFAULT_OVERLAP_S = 2e-3


@dataclasses.dataclass(frozen=True)
class SlabChunk:
    """One unit of overlap: a single layer's slab gather of a staged
    plan (layer 0 = the whole plan for shared, non-layer plans)."""
    layer: int
    nbytes: int


@dataclasses.dataclass(frozen=True)
class DrainReport:
    """What one per-iteration drain did (engine accounting input)."""
    layers: List[int]          # chunk layers landed this iteration
    nbytes: int                # logical transfer bytes of those chunks
    budget_bytes: int          # the budget the batch was packed against
    wall_s: float              # measured wall clock of the subset apply
    done: bool                 # queue empty: the plan has fully landed

    @property
    def excess_bytes(self) -> int:
        """Bytes past the budget (a single chunk larger than the budget
        is transferred whole for progress; the excess is *stall*)."""
        return max(0, self.nbytes - self.budget_bytes)


class MigrationExecutor:
    """Drains one staged plan as a queue of byte-budgeted slab chunks.

    Built by the engine when a manager stages a plan in async mode;
    ``drain`` is called once per serving iteration until ``draining`` is
    False.  Chunks are ordered by plan layer index — deeper layers land
    later, which matches the scan order but is otherwise arbitrary (the
    per-layer consistency rule makes any order safe).

    ``priority_layers`` (elastic recovery) moves those layers to the
    queue front: recovery chunks re-materializing unroutable experts
    drain before optimization chunks, under the same byte budget.
    ``patch_fn(params, plan, layers)`` is applied after each batch's
    slab gather and before its commit — the coordinator's hook that
    overwrites checkpoint-sourced rows for experts whose source slab
    died with its rank."""

    def __init__(self, manager, plan,
                 bytes_per_iter: Optional[int] = None,
                 priority_layers=None, patch_fn=None):
        self.manager = manager
        self.plan = plan
        self.patch_fn = patch_fn
        # explicit budget wins; otherwise measured bandwidth x overlap
        self.bytes_per_iter = None if not bytes_per_iter \
            else int(bytes_per_iter)
        self.queue: List[SlabChunk] = [
            SlabChunk(layer=l, nbytes=int(manager.layer_bytes(plan, l)))
            for l in manager.plan_layers(plan)]
        if priority_layers:
            prio = {int(l) for l in priority_layers}
            # stable: recovery chunks first, layer order preserved within
            # each class
            self.queue.sort(key=lambda c: c.layer not in prio)
        self.total_bytes = sum(c.nbytes for c in self.queue)
        self.drained_bytes = 0
        self.n_drains = 0

    @property
    def draining(self) -> bool:
        return bool(self.queue)

    def cancel(self) -> None:
        """Drop the remaining chunks and abort the staged plan (already
        committed layers stay routable — their slabs landed)."""
        self.queue.clear()
        self.manager.abort()

    def budget_bytes(self, iter_s: Optional[float] = None) -> int:
        """This iteration's byte budget: the explicit knob, or the bytes
        the measured bandwidth moves in one iteration's compute."""
        if self.bytes_per_iter is not None:
            return self.bytes_per_iter
        overlap = iter_s if iter_s and iter_s > 0 else DEFAULT_OVERLAP_S
        return max(int(self.manager.bandwidth.bytes_per_s * overlap), 1)

    def _pack(self, budget: int) -> List[SlabChunk]:
        """Pop a batch of chunks fitting the budget — always at least
        one, so an over-budget chunk still makes progress (its excess is
        charged as stall by the engine)."""
        batch = [self.queue.pop(0)]
        spent = batch[0].nbytes
        while self.queue and spent + self.queue[0].nbytes <= budget:
            batch.append(self.queue.pop(0))
            spent += batch[-1].nbytes
        return batch

    def drain(self, params: Dict[str, Any],
              iter_s: Optional[float] = None):
        """Apply one budgeted batch of chunks to ``params``; time the
        apply, feed the bandwidth EWMA, commit exactly the landed
        layers.  Returns ``(new_params, DrainReport)``.

        On an apply failure the staged plan is aborted (already-landed
        layers stay routable — their slabs did land; the old tables
        remain consistent for the rest) and the error is re-raised."""
        assert self.queue, "drain of a fully-landed plan"
        budget = self.budget_bytes(iter_s)
        batch = self._pack(budget)
        layers = [c.layer for c in batch]
        nbytes = sum(c.nbytes for c in batch)
        t0 = time.perf_counter()
        try:
            new_params = pmigrate.apply_layers_to_params(
                params, self.plan, layers)
            _block_until_ready(new_params)
        except BaseException:
            self.queue.clear()
            self.manager.abort()
            raise
        wall = time.perf_counter() - t0
        self.manager.bandwidth.observe(nbytes, wall)
        trc = getattr(self.manager, "tracer", None)
        if trc is not None and trc.enabled:
            # the measured wall seconds of this batch's subset apply,
            # stamped at the current engine-clock position (the engine's
            # migration.drain spans carry the stall/hidden attribution)
            trc.complete("migration.apply", trc.clock(), wall,
                         cat="migration",
                         args={"layers": len(layers), "bytes": int(nbytes),
                               "budget_bytes": int(budget),
                               "wall_s": wall,
                               "remaining": len(self.queue)})
        if self.patch_fn is not None:
            # recovery patch: checkpoint-sourced rows for experts whose
            # source slab died with its rank (outside the timed window —
            # checkpoint reads would pollute the fabric-bandwidth EWMA)
            try:
                new_params = self.patch_fn(new_params, self.plan, layers)
                _block_until_ready(new_params)
            except BaseException:
                self.queue.clear()
                self.manager.abort()
                raise
        self.manager.commit_layers(self.plan, layers)
        self.drained_bytes += nbytes
        self.n_drains += 1
        return new_params, DrainReport(layers=layers, nbytes=nbytes,
                                       budget_bytes=budget, wall_s=wall,
                                       done=not self.queue)


def _block_until_ready(tree) -> None:
    """Synchronize so the timed window covers the real transfer; numpy
    trees (host-side tests) pass through untouched."""
    try:
        import jax
        jax.block_until_ready(tree)
    except ImportError:                      # pure-numpy environments
        pass
