"""Batched serving engine: prefill + decode with ReaLB active.

The engine holds one device-resident cache of ``max_slots`` sequences and
drives the scheduler loop: admit → per-request prefill into the slot →
batched decode step across all active slots.  The AIMD ``m_state`` of
ReaLB persists across iterations, exactly like the controller in the
paper's serving deployment; per-iteration routing/imbalance stats are
recorded for the benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ReaLBConfig
from repro.core import ep_moe
from repro.models import transformer as tf
from repro.models.common import current_mesh
from repro.serving.scheduler import Request, Scheduler


@dataclasses.dataclass
class IterStats:
    """Per-iteration routing/balance diagnostics (benchmark input)."""
    n_active: int
    tokens: int
    ib_global: float
    fp4_ranks: float
    gate_open: float


class Engine:
    def __init__(self, cfg: ModelConfig, params, rcfg: ReaLBConfig,
                 max_slots: int = 8, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg, self.params, self.rcfg = cfg, params, rcfg
        self.max_slots, self.max_len = max_slots, max_len
        self.temperature = temperature
        self.scheduler = Scheduler(max_slots)
        self.cache = tf.init_cache(cfg, max_slots, max_len)
        groups, ep = ep_moe.moe_state_shape(current_mesh(), max_slots)
        self.m_state = jnp.full((groups, ep), rcfg.md_init, jnp.float32)
        self.pos = np.zeros(max_slots, np.int32)      # next write position
        self.last_tok = np.zeros(max_slots, np.int32)
        self.active_mask = np.zeros(max_slots, bool)
        self.stats: List[IterStats] = []
        self.key = jax.random.PRNGKey(seed)
        self._build()

    # -- jitted steps -------------------------------------------------------
    def _build(self):
        cfg, rcfg = self.cfg, self.rcfg

        @jax.jit
        def prefill_one(params, m_state, batch):
            res = tf.prefill_forward(params, cfg, rcfg, batch, m_state,
                                     cache_len=self.max_len)
            return res.logits, res.cache, res.m_state

        @jax.jit
        def decode(params, cache, m_state, tokens, pos, modality):
            batch = {"tokens": tokens, "pos": pos, "modality": modality}
            res = tf.decode_forward(params, cfg, rcfg, batch, cache, m_state)
            return res.logits, res.cache, res.m_state, res.aux

        self._prefill_one = prefill_one
        self._decode = decode

    # -- cache slot insertion ----------------------------------------------
    def _insert_cache(self, slot: int, new_cache):
        """Copy a batch-1 prefill cache into slot `slot` of the engine cache.

        Stacked block entries are [n_blocks, B, ...] (batch axis 1); prefix
        entries are [B, ...] (axis 0).
        """
        def set_slot(axis):
            def f(dst, src):
                idx = [slice(None)] * dst.ndim
                idx[axis] = slice(slot, slot + 1)
                return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            return f

        self.cache["blocks"] = jax.tree.map(set_slot(1),
                                            self.cache["blocks"],
                                            new_cache["blocks"])
        if "prefix" in self.cache:
            self.cache["prefix"] = jax.tree.map(set_slot(0),
                                                self.cache["prefix"],
                                                new_cache["prefix"])

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        assert req.prompt_len + req.max_new_tokens <= self.max_len, \
            (req.prompt_len, req.max_new_tokens, self.max_len)
        self.scheduler.submit(req)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1), np.int32)

    def step(self) -> int:
        """One continuous-batching iteration. Returns #active sequences."""
        # 1) admit + prefill new requests (slot-local, batch of 1)
        for req in self.scheduler.admit():
            batch = {
                "tokens": jnp.asarray(req.tokens, jnp.int32)[None],
                "modality": jnp.asarray(req.modality, bool)[None],
            }
            if req.vision_embeds is not None:
                batch["vision_embeds"] = jnp.asarray(
                    req.vision_embeds, jnp.dtype(self.cfg.param_dtype))[None]
            if self.cfg.is_encdec:
                batch["enc_embeds"] = jnp.asarray(
                    req.vision_embeds if req.vision_embeds is not None
                    else np.zeros((self.cfg.enc_seq_len, self.cfg.d_model),
                                  np.float32),
                    jnp.dtype(self.cfg.param_dtype))[None]
            logits, new_cache, self.m_state = self._prefill_one(
                self.params, self.m_state, batch)
            self._insert_cache(req.slot, new_cache)
            tok = self._sample(logits)[0]
            req.generated.append(int(tok))
            self.pos[req.slot] = req.prompt_len
            self.last_tok[req.slot] = int(tok)
            self.active_mask[req.slot] = True

        self.scheduler.retire()
        for s in range(self.max_slots):
            self.active_mask[s] = s in self.scheduler.active

        if not self.scheduler.active:
            return 0

        # 2) batched decode over all slots (inactive slots run dummies)
        tokens = jnp.asarray(self.last_tok[:, None], jnp.int32)
        pos = jnp.asarray(np.where(self.active_mask, self.pos, 0), jnp.int32)
        modality = jnp.zeros((self.max_slots, 1), bool)
        logits, self.cache, self.m_state, aux = self._decode(
            self.params, self.cache, self.m_state, tokens, pos, modality)
        toks = self._sample(logits)
        n_active = 0
        for slot, req in list(self.scheduler.active.items()):
            if not req.done:
                req.generated.append(int(toks[slot]))
                self.last_tok[slot] = int(toks[slot])
                self.pos[slot] += 1
                n_active += 1
        self.stats.append(IterStats(
            n_active=n_active,
            tokens=n_active,
            ib_global=float(aux["ib_global"]),
            fp4_ranks=float(aux["fp4_ranks"]),
            gate_open=float(aux["gate_open"])))
        self.scheduler.retire()
        return n_active

    def run(self, max_iters: int = 10_000) -> List[Request]:
        it = 0
        while not self.scheduler.idle and it < max_iters:
            self.step()
            it += 1
        return self.scheduler.finished
