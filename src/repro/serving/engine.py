"""Batched serving engine v2: chunked token-budgeted prefill + decode with
ReaLB active.

The engine holds one device-resident cache of ``max_slots`` sequences and
drives the scheduler loop.  Prefill is *chunked and batched*: each
iteration packs up to ``prefill_budget`` prompt tokens across every slot
with pending prefill work into one rectangular forward (per-slot chunk
continuation state), so prefill batches reach the large-token regime where
ReaLB's LB gate opens — instead of the v1 per-request batch-1 loop that
never crossed ``gate_gamma``.  Decode remains one batched step across all
decode-ready slots.  The AIMD ``m_state`` of ReaLB persists across both
kinds of iteration, exactly like the controller in the paper's serving
deployment; per-iteration routing/imbalance stats — prefill iterations
included — are recorded for the benchmarks and streamed to an optional
:class:`~repro.serving.telemetry.Telemetry` collector.

Architectures whose caches cannot be continued mid-prompt (MLA latent,
SSM state, enc-dec memory, VLM embed injection) fall back to the v1
one-shot batch-1 prefill per request; everything downstream (timing,
telemetry, modality-aware decode) is shared.

Expert placement & live migration: when constructed with a
:class:`~repro.placement.PlacementManager`, the engine feeds it per-
iteration expert-load stats, and at the manager's replan cadence applies
the returned weight-slab permutation to ``self.params`` (gather-by-table;
KV caches, AIMD M-state and telemetry are untouched).  Migration bytes
and virtual-time seconds are charged to the clock and recorded in the
next :class:`IterStats`, so the zero-overhead property of ReaLB vs. the
migration cost of placement is directly measurable.  ``virtual_ep``
provisions the ReaLB policy statistics over a virtual EP topology on a
single device (see ``repro.core.ep_moe``).

Redundant experts: a :class:`~repro.replication.ReplicaManager` rides the
same loop, but its weight arrays hold ``S >= E`` physical slots (expand
the logical params with ``repro.replication.expand_moe_params`` before
construction) and its plans are *staged*: the engine gathers the slabs
first and only then calls ``manager.commit(plan)``, so a replica becomes
routable (visible to the traced dispatch table) strictly after its slab
landed in ``self.params`` — the consistency rule that keeps a crashed
apply from routing tokens into garbage weights.

Per-layer tables: managers constructed with ``per_layer=True`` return
stacked ``[n_blocks, ...]`` tables from ``device_tables()``; the model
threads the per-layer slice through its layer scan, and the manager's
plans are layer-diffs whose slab traffic covers changed layers only.
The engine code is identical either way — ``_place_args``/
``_maybe_migrate`` are shape-agnostic.

Async overlapped migration (``migrate_async=True``): instead of landing
a staged plan's whole slab permutation between two iterations, the
engine drains it through a :class:`~repro.serving.async_migrate.
MigrationExecutor` — one byte-budgeted batch of per-layer chunks per
iteration, each landed layer's table committed independently
(``manager.commit_layers``), so serving routes old tables for layers
still in flight and new tables for landed ones.  Transfer seconds that
fit the budget are *hidden* (overlapped with the iteration's forward —
not charged to a virtual clock), only the excess *stalls*; both are
split out in :class:`IterStats` (``migration_s`` = stall,
``migration_hidden_s``).  Every apply — sync or async — is wall-timed
and fed into the manager's measured-bandwidth EWMA, which prices
``migration_seconds``, the chunk budget and the calibrated replan gate.
While a plan is draining no new replan can fire, and checkpointing
refuses cleanly (the in-flight params/table mix is not a restorable
state).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sentinel import NULL_SENTINEL
from repro.configs.base import ModelConfig, ReaLBConfig
from repro.core import ep_moe
from repro.models import transformer as tf
from repro.models.common import current_mesh
from repro.obs.profiler import NULL_PROFILER
from repro.obs.trace import NULL_TRACER
from repro.serving.scheduler import Request, Scheduler
from repro.serving.telemetry import Telemetry


@dataclasses.dataclass
class IterStats:
    """Per-iteration routing/balance diagnostics (benchmark input)."""
    n_active: int
    tokens: int                  # real (non-padding) tokens this iteration
    ib_global: float
    fp4_ranks: float
    gate_open: float
    phase: str = "decode"        # "prefill" | "decode"
    t_wall: float = 0.0          # engine clock at record time
    batch_tokens: int = 0        # tokens the MoE actually saw (incl. pad)
    vis_frac: float = 0.0        # vision fraction of routed assignments
    drop_frac: float = 0.0       # capacity-dropped fraction of routed tokens
    migration_bytes: int = 0     # expert weight bytes moved before this
    #                              iter (integral end-to-end: plans count
    #                              whole weight bytes, never fractions)
    migration_s: float = 0.0     # migration seconds that STALLED serving
    #                              (charged to a virtual clock; measured
    #                              wall seconds under wall clocks)
    migration_hidden_s: float = 0.0  # transfer seconds hidden under the
    #                              iteration's forward (async overlap)
    split_frac: float = 0.0      # routed fraction served by a non-primary
    #                              replica (0 under a bijective table)
    n_unroutable: int = 0        # logical experts with no live replica
    #                              (elastic degraded mode; 0 when healthy)
    lost_tokens: float = 0.0     # tokens this iteration routed to an
    #                              unroutable expert (they landed on the
    #                              dead rank's zeroed slots)


def _bucket(n: int, lo: int = 8) -> int:
    """Round a chunk length up to a power of two (bounds jit recompiles)."""
    b = lo
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(self, cfg: ModelConfig, params, rcfg: ReaLBConfig,
                 max_slots: int = 8, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_budget: int = 256, text_reserve: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 telemetry: Optional[Telemetry] = None,
                 cost_model=None, placement=None,
                 virtual_ep: Optional[int] = None,
                 capacity_margin: Optional[float] = None,
                 migrate_async: bool = False,
                 migrate_bytes_per_iter: Optional[int] = None,
                 elastic=None, fault_injector=None, tracer=None,
                 profiler=None, sentinel=None):
        self.cfg, self.params, self.rcfg = cfg, params, rcfg
        # invariant sentinel (repro.analysis.sentinel.Sentinel); None ->
        # the shared no-op under the tracer/profiler null-object
        # discipline.  When armed it guards the iteration hot window
        # against unsanctioned device->host syncs and counts per-entry
        # jit compilations (zero recompiles after warmup).
        self.sentinel = NULL_SENTINEL if sentinel is None else sentinel
        # span tracer (repro.obs.trace.Tracer); None -> the shared no-op
        # singleton, whose calls record nothing and read no clock — an
        # untraced engine is bitwise identical to one predating the obs
        # layer.  When given, the tracer is shared with the manager and
        # the elastic coordinator so their spans land on the same
        # timeline.
        self.tracer = NULL_TRACER if tracer is None else tracer
        if tracer is not None:
            if placement is not None:
                placement.tracer = tracer
            if elastic is not None:
                elastic.tracer = tracer
        # hot-loop profiler (repro.obs.profiler.Profiler); None -> the
        # shared no-op singleton under the same discipline as the tracer:
        # no stats conversion, no clock math, bitwise-identical outputs.
        self.profiler = NULL_PROFILER if profiler is None else profiler
        if profiler is not None and placement is not None:
            # wire the measured/predicted drift EWMA into the replan cost
            # gate's savings side (same idiom as the manager's bandwidth
            # auto-wiring): replans are priced at how fast the hardware
            # actually runs the analytic model's seconds
            gate = getattr(placement, "cost_gate", None)
            if gate is not None \
                    and getattr(gate, "time_scale", False) is None:
                gate.time_scale = profiler.time_scale
        self.max_slots, self.max_len = max_slots, max_len
        self.temperature = temperature
        self.prefill_budget = prefill_budget
        # chunk continuation needs a pure GQA/MQA decoder stack
        self.chunked = (prefill_budget > 0 and cfg.mla is None
                        and cfg.ssm is None and not cfg.is_encdec
                        and cfg.layer_pattern == "attn"
                        and cfg.family != "vlm")
        self.scheduler = Scheduler(max_slots, text_reserve=text_reserve)
        self.clock = clock
        self.telemetry = telemetry
        # virtual-time mode: an object with .cost(batch_tokens) -> seconds,
        # paired with a clock exposing .advance(dt).  The clock is advanced
        # right after each forward, *before* first-token/finish timestamps
        # are stamped, so TTFT includes the iteration that produced the
        # token — not just queueing delay.
        self.cost_model = cost_model
        # expert placement: a repro.placement.PlacementManager (or None).
        # virtual_ep sizes the policy-statistics topology on meshless runs
        # (defaults to the manager's EP group when one is given).
        self._placement = placement
        mesh = current_mesh()
        if placement is not None and mesh is not None:
            mesh_ep = dict(zip(mesh.axis_names,
                               mesh.devices.shape)).get("model", 1)
            assert placement.ep == mesh_ep, \
                f"placement plans {placement.ep} ranks, mesh EP={mesh_ep}"
        if placement is not None and mesh is None \
                and virtual_ep is not None:
            # the table's slots are strided by E // placement.ep; a
            # different policy topology would break the pos bijection
            assert placement.ep == virtual_ep, \
                f"placement plans {placement.ep} ranks, virtual_ep={virtual_ep}"
        if virtual_ep is None and placement is not None and mesh is None:
            virtual_ep = placement.ep
        if placement is not None and cfg.moe is not None:
            # replica managers route over S >= E physical weight slots;
            # refuse a params tree that was not laid out for the manager
            # (forgotten expand_moe_params would silently misroute)
            from repro.placement.migrate import moe_param_paths
            tables = placement.device_tables()
            want = int(tables[2].shape[-1]) if len(tables) >= 3 \
                else cfg.moe.num_experts
            paths = moe_param_paths(params)
            if paths:
                g0, l0 = paths[0]
                got = params[g0][l0]["moe"]["w_gate"].shape[-3]
                assert got == want, \
                    (f"params hold {got} expert slots but the manager "
                     f"routes over {want}; lay the weights out with "
                     "repro.replication.expand_moe_params first")
        # replica-aware dispatch capacity: with a margin set, every
        # committed replica replan re-derives capacity_factor from the
        # post-split predicted peak rank load and re-jits the steps —
        # the dispatch buffer shrinks to the flattened topology
        self.capacity_margin = capacity_margin
        self._base_capacity = cfg.moe.capacity_factor if cfg.moe else 0.0
        # async overlapped migration: drain staged plans as byte-budgeted
        # per-layer chunks instead of one synchronous whole-plan apply
        self.migrate_async = migrate_async
        self.migrate_bytes_per_iter = migrate_bytes_per_iter
        self._mig = None                  # active MigrationExecutor
        self._iter_s: Optional[float] = None  # EWMA of iteration seconds
        # (bytes:int, stall_s, hidden_s) staged for the next IterStats
        self._pending_migration = (0, 0.0, 0.0)
        # cumulative engine-side accounting (survives telemetry windows
        # and tail drains — e.g. drain_migrations() after the last
        # request — that never reach a _record)
        self.migration_bytes_moved = 0
        self.migration_stall_s = 0.0
        self.migration_hidden_s = 0.0
        # elastic serving: an ElasticCoordinator over the same manager
        # turns rank loss/rejoin into between-iteration events; a
        # FaultInjector scripts them (polled once per step)
        self._elastic = elastic
        self._fault = fault_injector
        if elastic is not None:
            assert placement is not None and elastic.manager is placement, \
                "elastic coordinator must wrap this engine's manager"
        self._place_cache = None                  # device copy of the table
        self._it = 0
        self.cache = tf.init_cache(cfg, max_slots, max_len)
        groups, ep = ep_moe.moe_state_shape(current_mesh(), max_slots,
                                            virtual_ep=virtual_ep)
        self.m_state = jnp.full((groups, ep), rcfg.md_init, jnp.float32)
        self.pos = np.zeros(max_slots, np.int32)      # next write position
        self.last_tok = np.zeros(max_slots, np.int32)
        self.active_mask = np.zeros(max_slots, bool)
        self.decode_ready = np.zeros(max_slots, bool)
        self.mod_state = np.zeros(max_slots, bool)    # decode-token modality
        self._prefill_fifo: List[int] = []            # slots mid-prefill
        # aux scalars come back summed over the layer scan; normalize to
        # per-MoE-layer means so duty cycles / IB read as true fractions
        self._n_moe = max(sum(1 for f in cfg.ffn_kinds() if f == "moe"), 1)
        self.stats: List[IterStats] = []
        self.key = jax.random.PRNGKey(seed)
        self._build()

    # -- jitted steps -------------------------------------------------------
    def _build(self):
        cfg, rcfg = self.cfg, self.rcfg

        @jax.jit
        def prefill_one(params, m_state, batch, place):
            res = tf.prefill_forward(params, cfg, rcfg, batch, m_state,
                                     cache_len=self.max_len, placement=place)
            return res.logits, res.cache, res.m_state, res.aux

        @jax.jit
        def chunk_step(params, cache, m_state, tokens, start, chunk_len,
                       modality, place):
            batch = {"tokens": tokens, "start": start,
                     "chunk_len": chunk_len, "modality": modality}
            res = tf.chunk_forward(params, cfg, rcfg, batch, cache, m_state,
                                   placement=place)
            return res.logits, res.cache, res.m_state, res.aux

        @jax.jit
        def decode(params, cache, m_state, tokens, pos, modality, valid,
                   place):
            batch = {"tokens": tokens, "pos": pos, "modality": modality,
                     "valid": valid}
            res = tf.decode_forward(params, cfg, rcfg, batch, cache, m_state,
                                    placement=place)
            return res.logits, res.cache, res.m_state, res.aux

        self._prefill_one = prefill_one
        self._chunk = chunk_step
        self._decode = decode
        if self.sentinel.enabled:
            self.sentinel.register_entry("prefill", prefill_one)
            self.sentinel.register_entry("chunk", chunk_step)
            self.sentinel.register_entry("decode", decode)

    def _place_args(self):
        """The traced table of the current plan — (e2r, local_slot) for a
        bijective manager, (rep_pos, n_rep, slot_owner) for a replica
        manager, None = the identity mapping (bitwise-identical to a
        placement-free engine).  Cached on device; invalidated when a
        committed migration changes the routable table."""
        if self._placement is None:
            return None
        if self._place_cache is None:
            self._place_cache = tuple(
                jnp.asarray(a) for a in self._placement.device_tables())
        return self._place_cache

    # -- live migration ------------------------------------------------------
    def _maybe_migrate(self):
        """The per-iteration migration state machine.

        Draining: advance the in-flight chunk queue by one byte-budgeted
        batch (no new replan can fire — the manager guards it).  Idle:
        ask the manager for a staged plan; apply it synchronously, or
        start an async executor and drain its first batch."""
        if self._placement is None or self.cfg.moe is None:
            return
        if self._mig is not None:
            self._drain_migration()
            return
        plan = self._placement.maybe_replan(self._it)
        if plan is None:
            return
        if self.migrate_async:
            from repro.serving.async_migrate import MigrationExecutor
            prio = patch = None
            if self._elastic is not None:
                # recovery chunks (re-materializing unroutable experts)
                # drain ahead of optimization chunks; the patch drops
                # checkpoint rows into the landed slots pre-commit
                prio = self._elastic.recovery_layers(plan)
                patch = self._elastic.patch_params
            self._mig = MigrationExecutor(
                self._placement, plan,
                bytes_per_iter=self.migrate_bytes_per_iter,
                priority_layers=prio, patch_fn=patch)
            self._drain_migration()
            return
        # synchronous path: the whole slab permutation lands between two
        # iterations, wall-timed so the measured-bandwidth EWMA (and the
        # charged seconds under wall clocks) reflect the real transfer
        from repro.placement import migrate
        t0 = time.perf_counter()
        try:
            new_params = migrate.apply_to_params(self.params, plan)
            jax.block_until_ready(new_params)
        except BaseException:
            # drop the staged plan so the old set stays routable and
            # a later cadence point can replan, then surface the error
            self._placement.abort()
            raise
        wall = time.perf_counter() - t0
        self._placement.bandwidth.observe(plan.moved_bytes, wall)
        layers = self._placement.plan_layers(plan)
        if self._elastic is not None:
            # lost experts' slabs were gathered from the dead (zeroed)
            # slots; overwrite them with checkpoint rows BEFORE the new
            # tables flip routable (staged-commit rule) — outside the
            # timed window so ckpt reads don't pollute the bandwidth EWMA
            try:
                new_params = self._elastic.patch_params(new_params, plan,
                                                        layers)
                jax.block_until_ready(new_params)
            except BaseException:
                self._placement.abort()
                raise
        self.params = new_params
        # staged plans become routable only after the slab gather above
        # produced the new weights (consistency rule)
        self._placement.commit(plan)
        self._place_cache = None                  # table changed
        if hasattr(self.clock, "advance"):
            secs = self._placement.migration_seconds(plan.moved_bytes)
            self.clock.advance(secs)
        else:
            # wall clocks: the move is real work already on the wall —
            # record the measured seconds, not 0
            secs = wall
        self._charge_migration(int(plan.moved_bytes), secs, 0.0)
        trc = self.tracer
        if trc.enabled:
            # one migration.drain span per charge: summed durations
            # reconcile exactly with stall + hidden telemetry totals
            trc.complete("migration.drain", self.clock() - secs, secs,
                         cat="migration",
                         args={"mode": "sync",
                               "bytes": int(plan.moved_bytes),
                               "stall_s": secs, "hidden_s": 0.0,
                               "layers": len(layers)})
            trc.instant("table.commit", cat="migration",
                        args={"layers": len(layers), "done": True})
        self._notify_plan_committed()
        if self._elastic is not None:
            self._elastic.on_layers_landed(plan, layers)

    def _drain_migration(self):
        """One budgeted chunk batch of the in-flight plan: land the
        slabs, commit exactly those layers, split the transfer seconds
        into hidden (fits the budget — overlapped with this iteration's
        forward) and stall (the excess, charged to a virtual clock)."""
        plan = self._mig.plan
        try:
            self.params, rep = self._mig.drain(self.params, self._iter_s)
        except BaseException:
            # the executor aborted the staged remainder; landed layers
            # stay routable (their slabs did land)
            self._mig = None
            self._place_cache = None
            raise
        self._place_cache = None              # landed layers' tables flipped
        if hasattr(self.clock, "advance"):
            stall = self._placement.migration_seconds(rep.excess_bytes)
            hidden = self._placement.migration_seconds(
                rep.nbytes - rep.excess_bytes)
            self.clock.advance(stall)
        else:
            # single-threaded wall-clock serving cannot actually overlap
            # the host-side apply: the whole batch is an honest stall
            stall, hidden = rep.wall_s, 0.0
        if rep.done:
            self._mig = None
        self._charge_migration(rep.nbytes, stall, hidden)
        trc = self.tracer
        if trc.enabled:
            # span starts at the stall charge and extends through the
            # hidden (forward-overlapped) share; dur = stall + hidden so
            # summed drain spans reconcile with the telemetry totals
            trc.complete("migration.drain", self.clock() - stall,
                         stall + hidden, cat="migration",
                         args={"mode": "async", "bytes": int(rep.nbytes),
                               "stall_s": stall, "hidden_s": hidden,
                               "layers": len(rep.layers),
                               "done": bool(rep.done)})
            if rep.layers:
                trc.instant("table.commit", cat="migration",
                            args={"layers": len(rep.layers),
                                  "done": bool(rep.done)})
        if rep.done:
            self._notify_plan_committed()
        if self._elastic is not None and rep.layers:
            # landed layers' lost experts are re-materialized (the
            # executor's patch_fn ran pre-commit); clear them and stamp
            # recovery_s / warm-up completion
            self._elastic.on_layers_landed(plan, rep.layers)

    def _charge_migration(self, nbytes: int, stall_s: float,
                          hidden_s: float):
        b, s, h = self._pending_migration
        self._pending_migration = (b + int(nbytes), s + stall_s,
                                   h + hidden_s)
        self.migration_bytes_moved += int(nbytes)
        self.migration_stall_s += stall_s
        self.migration_hidden_s += hidden_s

    def _notify_plan_committed(self):
        """A staged plan fully landed: count the commit and open a fresh
        prediction-accuracy window stamped with the predictor's per-layer
        rank loads under the new tables (read-only — no engine state)."""
        if self.telemetry is None:
            return
        self.telemetry.record_plan_commit()
        if self._placement is not None \
                and hasattr(self._placement, "predicted_rank_loads"):
            self.telemetry.open_prediction_window(
                self._it, self._placement.predicted_rank_loads())

    @property
    def migration_draining(self) -> bool:
        """A staged plan's chunk queue is mid-flight."""
        return self._mig is not None and self._mig.draining

    def drain_migrations(self, max_iters: int = 10_000) -> None:
        """Finish any in-flight migration without serving (e.g. before a
        checkpoint): budget-sized batches keep landing until the queue
        is empty."""
        it = 0
        while self.migration_draining:
            it += 1
            assert it <= max_iters, "migration drain failed to converge"
            self._drain_migration()

    # -- elastic serving events ----------------------------------------------
    def _abort_migration(self) -> None:
        """Drop any in-flight or staged plan (a fault invalidates it: the
        plan was computed against the pre-fault rank set).  Landed layers
        stay routable — their slabs did land."""
        if self._mig is not None:
            self._mig.cancel()
            self._mig = None
        elif getattr(self._placement, "in_flight", None) is not None:
            self._placement.abort()
        self._place_cache = None

    def fail_rank(self, rank: int) -> None:
        """The fault-injection hook: simulate the loss of EP ``rank``
        between iterations.  The in-flight plan (if any) is aborted, the
        dead rank is masked out of the routable tables (experts with a
        surviving replica stay routable this same iteration), its weight
        slabs are zeroed, and the coordinator arms an event-triggered
        recovery replan."""
        assert self._elastic is not None, \
            "fail_rank requires an ElasticCoordinator"
        self._abort_migration()
        self.params = self._elastic.fail_rank(rank, self.params)
        self._place_cache = None                  # tables were masked

    def rejoin_rank(self, rank: int) -> None:
        """The returning rank becomes plannable; it turns routable layer
        by layer as the warm-up plan's slabs land (staged commit)."""
        assert self._elastic is not None, \
            "rejoin_rank requires an ElasticCoordinator"
        self._elastic.rejoin_rank(rank)

    def _maybe_resize_capacity(self):
        """Replica-aware capacity: shrink (or restore) the dispatch
        ``capacity_factor`` to the post-split predicted peak rank load.
        Re-checked every iteration — not only on committed migrations —
        so load drifting under an unchanged replica set (replan rejected
        or noop) re-grows the buffer before it overflows.  The factor is
        jit-static, so a change re-builds the step fns; the 5% band
        keeps drift from re-jitting every step."""
        if (self.capacity_margin is None or self.cfg.moe is None
                or not hasattr(self._placement, "capacity_factor")):
            return
        eff = min(self._placement.capacity_factor(self.capacity_margin),
                  self._base_capacity)
        cur = self.cfg.moe.capacity_factor
        if abs(eff - cur) / max(cur, 1e-9) < 0.05:
            return
        self.cfg = dataclasses.replace(
            self.cfg, moe=dataclasses.replace(self.cfg.moe,
                                              capacity_factor=eff))
        # a deliberate re-jit: declare it so the sentinel's recompile
        # report attributes the fresh compilations to the resize band
        self.sentinel.note_rebuild(
            f"capacity_factor {cur:.4f}->{eff:.4f}")
        self._build()

    # -- cache slot insertion ----------------------------------------------
    def _insert_cache(self, slot: int, new_cache):
        """Copy a batch-1 prefill cache into slot `slot` of the engine cache.

        Stacked block entries are [n_blocks, B, ...] (batch axis 1); prefix
        entries are [B, ...] (axis 0).
        """
        def set_slot(axis):
            def f(dst, src):
                idx = [slice(None)] * dst.ndim
                idx[axis] = slice(slot, slot + 1)
                return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            return f

        self.cache["blocks"] = jax.tree.map(set_slot(1),
                                            self.cache["blocks"],
                                            new_cache["blocks"])
        if "prefix" in self.cache:
            self.cache["prefix"] = jax.tree.map(set_slot(0),
                                                self.cache["prefix"],
                                                new_cache["prefix"])

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request):
        assert req.prompt_len + req.max_new_tokens <= self.max_len, \
            (req.prompt_len, req.max_new_tokens, self.max_len)
        if req.arrival_time is None:
            req.arrival_time = self.clock()
        self.scheduler.submit(req)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        # sampling is a sanctioned sync: the generated token must reach
        # the host to extend the sequence (the one pull serving requires)
        with self.sentinel.sanctioned("sample"):
            if self.temperature <= 0:
                return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            self.key, sub = jax.random.split(self.key)
            return np.asarray(jax.random.categorical(
                sub, logits / self.temperature, axis=-1), np.int32)

    def _tick(self, batch_tokens: int):
        """Advance a virtual clock by the modeled cost of one forward."""
        if self.cost_model is not None and hasattr(self.clock, "advance"):
            self.clock.advance(self.cost_model.cost(batch_tokens))

    def _record(self, *, phase: str, n_active: int, tokens: int,
                batch_tokens: int, aux: Dict[str, Any],
                fwd_s: float = 0.0):
        # the statistics pull is a sanctioned sync point: routing stats
        # must land on host between forwards — they feed the predictor,
        # the replan gates and the AIMD policy's observers
        with self.sentinel.sanctioned("telemetry"):
            self._record_stats(phase=phase, n_active=n_active,
                               tokens=tokens, batch_tokens=batch_tokens,
                               aux=aux, fwd_s=fwd_s)

    def _record_stats(self, *, phase: str, n_active: int, tokens: int,
                      batch_tokens: int, aux: Dict[str, Any],
                      fwd_s: float = 0.0):
        # moe_stats: [n_blocks, 2, groups, ep] stacked (load_d, vis_d) rows
        ms = np.asarray(aux["moe_stats"], np.float64)
        load_sum, vis_sum = float(ms[:, 0].sum()), float(ms[:, 1].sum())
        mig_bytes, mig_s, mig_hidden = self._pending_migration
        self._pending_migration = (0, 0.0, 0.0)
        stat = IterStats(
            n_active=n_active, tokens=tokens,
            ib_global=float(aux["ib_global"]) / self._n_moe,
            fp4_ranks=float(aux["fp4_ranks"]) / self._n_moe,
            gate_open=float(aux["gate_open"]) / self._n_moe,
            phase=phase, t_wall=self.clock(), batch_tokens=batch_tokens,
            vis_frac=vis_sum / max(load_sum, 1.0),
            drop_frac=float(aux["drop_frac"]) / self._n_moe,
            migration_bytes=mig_bytes, migration_s=mig_s,
            migration_hidden_s=mig_hidden,
            split_frac=float(aux.get("split_frac", 0.0)) / self._n_moe)
        if self._elastic is not None and self._elastic.recovering:
            stat.n_unroutable = int(self._elastic.lost_experts.size)
            if "expert_stats" in aux:
                stat.lost_tokens = self._elastic.lost_token_count(
                    np.asarray(aux["expert_stats"]))
        self.stats.append(stat)
        if self._placement is not None and "expert_stats" in aux:
            # [n_blocks, 2, E] per-MoE-layer expert loads -> predictor
            # (decode iterations feed the decode window when configured)
            self._placement.observe(np.asarray(aux["expert_stats"]),
                                    decode=(phase == "decode"))
            if hasattr(self._placement, "observe_slots") \
                    and "slot_stats" in aux:
                # [n_blocks, 2, S] post-split physical-slot loads ->
                # replica-utilization accounting
                self._placement.observe_slots(np.asarray(aux["slot_stats"]))
            gate = getattr(self._placement, "cost_gate", None)
            if gate is not None and hasattr(gate, "observe_iter"):
                # calibrated replan gate: measured routed tokens (and the
                # engine clock) replace the static roofline constant
                gate.observe_iter(tokens, stat.t_wall)
            if self.telemetry is not None \
                    and hasattr(self._placement, "rank_heatmap"):
                # realized [n_blocks, ep] rank loads under the routable
                # tables -> expert-load heatmap + prediction accuracy
                self.telemetry.record_rank_heatmap(
                    self._placement.rank_heatmap(
                        np.asarray(aux["expert_stats"]),
                        np.asarray(aux["slot_stats"])
                        if "slot_stats" in aux else None))
        if self.telemetry is not None:
            self.telemetry.record_iter(stat)
        if self.profiler.enabled:
            # FLOP/byte ledger + drift EWMA off the stats array already
            # in hand; fwd_s is this forward's engine-clock seconds
            # (virtual charge or wall time alike)
            self.profiler.observe_iter(
                moe_stats=ms, fp4_layers=stat.fp4_ranks, tokens=tokens,
                batch_tokens=batch_tokens, fwd_s=fwd_s, phase=phase)
        trc = self.tracer
        if trc.enabled:
            trc.instant("dispatch.policy", cat="policy",
                        args={"it": self._it, "phase": phase,
                              "tokens": tokens,
                              "ib_global": stat.ib_global,
                              "fp4_ranks": stat.fp4_ranks,
                              "gate_open": stat.gate_open,
                              "drop_frac": stat.drop_frac})

    def _finish(self, req: Request):
        req.finish_time = self.clock()
        if self.telemetry is not None:
            self.telemetry.record_request(req)

    # -- prefill paths -------------------------------------------------------
    def _first_token(self, req: Request, tok: int):
        req.generated.append(tok)
        req.first_token_time = self.clock()
        self.pos[req.slot] = req.prompt_len
        self.last_tok[req.slot] = tok
        self.decode_ready[req.slot] = True
        if req.done:
            self._finish(req)

    def _prefill_oneshot(self, req: Request):
        """v1 path: whole prompt, batch of 1, full-row cache insert."""
        batch = {
            "tokens": jnp.asarray(req.tokens, jnp.int32)[None],
            "modality": jnp.asarray(req.modality, bool)[None],
        }
        if req.vision_embeds is not None:
            batch["vision_embeds"] = jnp.asarray(
                req.vision_embeds, jnp.dtype(self.cfg.param_dtype))[None]
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.asarray(
                req.vision_embeds if req.vision_embeds is not None
                else np.zeros((self.cfg.enc_seq_len, self.cfg.d_model),
                              np.float32),
                jnp.dtype(self.cfg.param_dtype))[None]
        t_fwd = self.clock()
        with self.tracer.span("forward.prefill", cat="forward") as sp:
            logits, new_cache, self.m_state, aux = self._prefill_one(
                self.params, self.m_state, batch, self._place_args())
            self._tick(req.prompt_len)
            if self.tracer.enabled:
                sp.set(tokens=req.prompt_len)
        fwd_s = self.clock() - t_fwd
        self._insert_cache(req.slot, new_cache)
        req.prefill_pos = req.prompt_len
        self._first_token(req, int(self._sample(logits)[0]))
        self._record(phase="prefill", n_active=1, tokens=req.prompt_len,
                     batch_tokens=req.prompt_len, aux=aux, fwd_s=fwd_s)

    def _plan_chunks(self) -> List:
        """Allocate the token budget over slots with pending prefill work,
        oldest admission first; at most one partial chunk per iteration."""
        budget = self.prefill_budget
        plan = []
        for slot in self._prefill_fifo:
            if budget <= 0:
                break
            req = self.scheduler.active[slot]
            take = min(req.prompt_len - req.prefill_pos, budget)
            plan.append((slot, take))
            budget -= take
        return plan

    def _chunk_prefill_step(self) -> int:
        plan = self._plan_chunks()
        if not plan:
            return 0
        s_bucket = _bucket(max(take for _, take in plan))
        b = self.max_slots
        tokens = np.zeros((b, s_bucket), np.int32)
        modality = np.zeros((b, s_bucket), bool)
        start = np.zeros(b, np.int32)
        chunk_len = np.zeros(b, np.int32)
        for slot, take in plan:
            req = self.scheduler.active[slot]
            p0 = req.prefill_pos
            tokens[slot, :take] = req.tokens[p0:p0 + take]
            modality[slot, :take] = req.modality[p0:p0 + take]
            start[slot] = p0
            chunk_len[slot] = take
        t_fwd = self.clock()
        with self.tracer.span("forward.chunk", cat="forward") as sp:
            logits, self.cache, self.m_state, aux = self._chunk(
                self.params, self.cache, self.m_state, jnp.asarray(tokens),
                jnp.asarray(start), jnp.asarray(chunk_len),
                jnp.asarray(modality), self._place_args())
            self._tick(b * s_bucket)
            if self.tracer.enabled:
                sp.set(slots=len(plan), batch_tokens=b * s_bucket)
        fwd_s = self.clock() - t_fwd
        completing = [slot for slot, take in plan
                      if self.scheduler.active[slot].prefill_pos + take
                      >= self.scheduler.active[slot].prompt_len]
        toks = self._sample(logits) if completing else None
        n_tok = 0
        for slot, take in plan:
            req = self.scheduler.active[slot]
            req.prefill_pos += take
            n_tok += take
            if req.prefill_pos >= req.prompt_len:
                self._prefill_fifo.remove(slot)
                self._first_token(req, int(toks[slot]))
        self._record(phase="prefill", n_active=len(plan), tokens=n_tok,
                     batch_tokens=b * s_bucket, aux=aux, fwd_s=fwd_s)
        return n_tok

    # -- the iteration --------------------------------------------------------
    def step(self) -> int:
        """One continuous-batching iteration. Returns #active sequences."""
        trc = self.tracer
        if not trc.enabled:
            return self._step()
        with trc.span("iter", cat="engine") as sp:
            n = self._step()
            sp.set(it=self._it, n_active=n, **self.profiler.span_args())
        return n

    def _step(self) -> int:
        self._it += 1
        # -2) scripted rank faults fire between iterations — the event
        # boundary of the elastic subsystem (dispatch tables, params and
        # plans are all quiescent here)
        if self._fault is not None:
            for ev in self._fault.due(self._it):
                if ev.kind == "fail":
                    self.fail_rank(ev.rank)
                else:
                    self.rejoin_rank(ev.rank)
        # weighted token splitting re-derives its per-replica schedule
        # from the latest residual-capacity prediction at the manager's
        # cadence — a pure table refresh, no weights move
        if self._placement is not None and \
                getattr(self._placement, "wants_table_refresh",
                        lambda it: False)(self._it):
            self._place_cache = None
        # -1) placement: apply a due replan before any forward of this
        # iteration sees the weights (plan and slabs move atomically),
        # then re-derive the replica-aware dispatch capacity from the
        # current prediction (migrated or not — drift under an unchanged
        # set must still re-grow a shrunk buffer)
        self._maybe_migrate()
        if self._placement is not None:
            self._maybe_resize_capacity()
        # everything up to here is the sanctioned between-iteration
        # window (faults, migration drains, resize re-jits); the rest of
        # the iteration is the hot loop the sentinel guards against
        # unsanctioned device->host syncs
        with self.sentinel.hot("iter"):
            return self._step_hot()

    def _step_hot(self) -> int:
        # the overlap window starts AFTER the migration charges: the
        # async budget must size against forward compute only — folding
        # a stall into the window would let the stall grow next
        # iteration's "hidden" budget, flattering the bounded-stall claim
        t_step0 = self.clock()
        # 0) purge slots freed by a mid-prefill retirement (e.g. a
        # max_new_tokens=0 request) before they can be re-admitted
        if self._prefill_fifo:
            self._prefill_fifo = [s for s in self._prefill_fifo
                                  if s in self.scheduler.active]
        # 1) admit new requests; route each to the chunked or one-shot path
        with self.tracer.span("admit", cat="engine") as sp:
            n_admitted = 0
            for req in self.scheduler.admit():
                n_admitted += 1
                self.active_mask[req.slot] = True
                self.decode_ready[req.slot] = False
                self.mod_state[req.slot] = req.decode_modality
                if self.chunked and req.vision_embeds is None:
                    req.prefill_pos = 0
                    self._prefill_fifo.append(req.slot)
                else:
                    self._prefill_oneshot(req)
            if self.tracer.enabled:
                sp.set(admitted=n_admitted)

        # 2) one batched chunk of prefill work across all pending slots
        if self._prefill_fifo:
            self._chunk_prefill_step()

        self.scheduler.retire()
        for s in range(self.max_slots):
            self.active_mask[s] = s in self.scheduler.active
            if not self.active_mask[s]:
                self.decode_ready[s] = False

        if not self.scheduler.active:
            self._observe_iter_s(t_step0)
            return 0

        # 3) batched decode over decode-ready slots (others run dummies whose
        # cache writes land out of bounds and are dropped — a mid-prefill
        # slot's cache must never be touched by the decode scatter)
        ready = self.decode_ready & self.active_mask
        n_active = 0
        if ready.any():
            tokens = jnp.asarray(self.last_tok[:, None], jnp.int32)
            pos = jnp.asarray(np.where(ready, self.pos, self.max_len),
                              jnp.int32)
            modality = jnp.asarray(
                np.where(ready, self.mod_state, False)[:, None])
            t_fwd = self.clock()
            with self.tracer.span("forward.decode", cat="forward") as sp:
                logits, self.cache, self.m_state, aux = self._decode(
                    self.params, self.cache, self.m_state, tokens, pos,
                    modality, jnp.asarray(ready[:, None]),
                    self._place_args())
                self._tick(self.max_slots)
                if self.tracer.enabled:
                    sp.set(batch_tokens=self.max_slots,
                           ready=int(ready.sum()))
            fwd_s = self.clock() - t_fwd
            toks = self._sample(logits)
            for slot, req in list(self.scheduler.active.items()):
                if ready[slot] and not req.done:
                    req.generated.append(int(toks[slot]))
                    self.last_tok[slot] = int(toks[slot])
                    self.pos[slot] += 1
                    n_active += 1
                    if req.done:
                        self._finish(req)
            self._record(phase="decode", n_active=n_active, tokens=n_active,
                         batch_tokens=self.max_slots, aux=aux, fwd_s=fwd_s)
        self.scheduler.retire()
        self._observe_iter_s(t_step0)
        return max(n_active, len(self._prefill_fifo))

    def _observe_iter_s(self, t_step0: float):
        """EWMA of one iteration's seconds on the engine clock (virtual
        charges or wall time alike) — the overlap window the async
        migration budget sizes its chunk batches against."""
        dt = self.clock() - t_step0
        if dt <= 0:
            return
        self._iter_s = dt if self._iter_s is None \
            else 0.75 * self._iter_s + 0.25 * dt

    def run(self, max_iters: int = 10_000) -> List[Request]:
        it = 0
        while not self.scheduler.idle and it < max_iters:
            self.step()
            it += 1
        return self.scheduler.finished

    # -- checkpointing --------------------------------------------------------
    def save_checkpoint(self, ckpt_dir: str, step: int, keep: int = 3) -> str:
        """Persist params + AIMD state (+ the chosen placement plan /
        replica set and predictor state, under the manager's own group) so
        a restored engine resumes with the same expert layout instead of
        silently reverting to identity.

        Refused while an async migration is draining: the params hold a
        mix of landed and not-yet-landed layer slabs whose in-flight
        plan is not part of the manager's persisted state — call
        :meth:`drain_migrations` first."""
        self._refuse_mid_flight("save")
        from repro.checkpoint import ckpt
        state = {"serving": {"params": self.params, "m_state": self.m_state}}
        if self._placement is not None:
            state[self._placement.ckpt_group] = self._placement.state_dict()
        return ckpt.save(ckpt_dir, step, state, keep=keep)

    def _refuse_mid_flight(self, what: str) -> None:
        if self.migration_draining \
                or getattr(self._placement, "in_flight", None) is not None:
            raise RuntimeError(
                f"cannot {what} a checkpoint while a migration is "
                "draining (params hold a partially-landed slab layout); "
                "call drain_migrations() first")
        if self._elastic is not None and self._elastic.recovering:
            raise RuntimeError(
                f"cannot {what} a checkpoint mid-recovery (params hold "
                "zeroed slabs for unroutable experts a restore would "
                "resurrect); let the recovery plan land first")

    def load_checkpoint(self, ckpt_dir: str,
                        step: Optional[int] = None) -> int:
        self._refuse_mid_flight("load")
        from repro.checkpoint import ckpt
        templates = {"serving": {"params": self.params,
                                 "m_state": self.m_state}}
        step, out = ckpt.restore(ckpt_dir, templates, step)

        def group_state(name):
            if not ckpt.has_group(ckpt_dir, name, step):
                return None
            return ckpt.restore_group(ckpt_dir, name, step)

        # the saved params are laid out for the writer's manager kind: a
        # bijective permutation ("placement") or a replica-slot order with
        # S >= E rows ("replication").  Any mismatched reader — manager-
        # free, or the other kind — would silently route its own table
        # through foreign weights, so refuse instead of desynchronizing.
        own = None if self._placement is None \
            else self._placement.ckpt_group
        for name, kind in (("placement", "a placement engine"),
                           ("replication", "a replication engine")):
            if name != own and group_state(name) is not None:
                raise ValueError(
                    f"checkpoint {ckpt_dir} step {step} was written by "
                    f"{kind} (weights are in its placed physical order); "
                    "construct this Engine with the matching manager to "
                    "restore it")
        self.params = out["serving"]["params"]
        self.m_state = out["serving"]["m_state"]
        if self._placement is not None:
            state = group_state(own)
            if state is None:
                # written by a manager-free engine: logical-order weights
                # and no layout state to resume — reset to a fresh
                # identity state (replica engines re-expand the logical
                # rows into their physical slot layout)
                self._placement.reset()
                if own == "replication":
                    from repro.replication import expand_moe_params
                    self.params = expand_moe_params(self.params,
                                                    self._placement.rsets)
            else:
                self._placement.load_state_dict(state)
            self._place_cache = None
        return step
