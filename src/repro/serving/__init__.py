"""serving subpackage."""
