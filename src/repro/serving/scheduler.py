"""Continuous-batching request scheduler (vLLM-style, PD-colocated).

Fixed-slot design: the engine owns ``max_slots`` cache slots; the scheduler
admits queued requests into free slots (prefill) and steps every active
slot each iteration (decode) — one "iteration" = one forward batch, the
paper's unit of routing dynamics.  Requests carry modality masks so ReaLB
sees the true vision/text composition.

Admission is modality-aware: under a vision burst, vision-heavy requests
can occupy at most ``max_slots - text_reserve`` slots while text requests
are waiting, so text TTFT is bounded instead of queueing behind every
long vision prompt (admission stays work-conserving — a vision request is
still admitted when no text request is queued).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray               # [S] int32 prompt (incl. vision slots)
    modality: np.ndarray             # [S] bool, True = vision token
    max_new_tokens: int = 16
    vision_embeds: Optional[np.ndarray] = None   # [Nv, D] stub frontend out
    decode_modality: bool = False    # modality flag of generated tokens
    arrival_time: Optional[float] = None  # engine-clock submission time;
    # None = stamp with the engine clock at submit()

    # runtime state
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    prefill_pos: int = 0             # prompt tokens already prefilled
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def is_vision(self) -> bool:
        """Vision-heavy request: majority of prompt tokens are vision."""
        return bool(self.modality.mean() > 0.5)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None or self.arrival_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token after the first."""
        if self.finish_time is None or self.first_token_time is None \
                or len(self.generated) < 2:
            return None
        return ((self.finish_time - self.first_token_time)
                / (len(self.generated) - 1))


class Scheduler:
    def __init__(self, max_slots: int, text_reserve: int = 1):
        self.max_slots = max_slots
        # slots a vision burst may occupy while text requests wait
        self.text_reserve = min(text_reserve, max(max_slots - 1, 0))
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}     # slot -> request
        self.finished: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.active]

    def _next_request(self) -> Optional[Request]:
        """FIFO pop with modality-aware override: when the vision slot cap
        is reached and a text request is waiting, the oldest text request
        jumps the queue."""
        if not self.queue:
            return None
        head = self.queue[0]
        if self.text_reserve and head.is_vision:
            n_vis = sum(r.is_vision for r in self.active.values())
            if n_vis >= self.max_slots - self.text_reserve:
                for i, r in enumerate(self.queue):
                    if not r.is_vision:
                        del self.queue[i]
                        return r
        return self.queue.popleft()

    def admit(self) -> List[Request]:
        """Move queued requests into free slots; returns newly admitted."""
        admitted = []
        for slot in self.free_slots():
            req = self._next_request()
            if req is None:
                break
            req.slot = slot
            self.active[slot] = req
            admitted.append(req)
        return admitted

    def retire(self) -> List[Request]:
        """Remove finished requests; returns them."""
        done = [r for r in self.active.values() if r.done]
        for r in done:
            del self.active[r.slot]
            self.finished.append(r)
        return done

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active
