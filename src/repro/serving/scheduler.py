"""Continuous-batching request scheduler (vLLM-style, PD-colocated).

Fixed-slot design: the engine owns ``max_slots`` cache slots; the scheduler
admits queued requests into free slots (prefill) and steps every active
slot each iteration (decode) — one "iteration" = one forward batch, the
paper's unit of routing dynamics.  Requests carry modality masks so ReaLB
sees the true vision/text composition.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray               # [S] int32 prompt (incl. vision slots)
    modality: np.ndarray             # [S] bool, True = vision token
    max_new_tokens: int = 16
    vision_embeds: Optional[np.ndarray] = None   # [Nv, D] stub frontend out

    # runtime state
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class Scheduler:
    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}     # slot -> request
        self.finished: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.active]

    def admit(self) -> List[Request]:
        """Move queued requests into free slots; returns newly admitted."""
        admitted = []
        for slot in self.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            req.slot = slot
            self.active[slot] = req
            admitted.append(req)
        return admitted

    def retire(self) -> List[Request]:
        """Remove finished requests; returns them."""
        done = [r for r in self.active.values() if r.done]
        for r in done:
            del self.active[r.slot]
            self.finished.append(r)
        return done

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active
