"""Elastic serving: rank loss and rejoin as first-class serving events.

A production EP mesh loses and regains ranks under load.  This module
turns both into events the engine handles *between iterations*, with no
full restart, built on three invariants the earlier subsystems already
provide:

- the replication planner's **distinct-rank rule** guarantees that an
  expert with ``n_rep >= 2`` has a surviving replica on any single rank
  loss — masking the dead rank out of the routable tables
  (:meth:`~repro.replication.replica_set.ReplicaSet.masked`) is a pure
  table flip, so those experts stay routable in the same iteration;
- the **staged-commit rule** (a table is routable only after its slab
  landed) makes recovery and rejoin ordinary migrations: re-materialized
  and warm-up slabs stream through the existing
  :class:`~repro.serving.async_migrate.MigrationExecutor` chunk queue,
  byte-budgeted and overlapped like any optimization plan;
- the **checkpoint groups** (``serving`` params + the manager's
  ``replication`` state) record where every logical expert's weights
  lived at save time, so a singleton expert whose only slab died with
  its rank is re-materialized from checkpoint rows.

State machine of the :class:`ElasticCoordinator`::

    healthy ──fail_rank──> degraded      (unroutable singletons pending)
                     └───> shrunk        (every expert had a survivor)
    degraded ──recovery chunks land──> shrunk
    shrunk ──rejoin_rank──> warming      (planned slabs streaming)
    warming ──rejoin plan lands──> healthy

Degraded-mode guarantees: experts with a surviving replica never drop a
token (their tokens re-split over live replicas immediately); tokens
routed to a lost expert are *counted* (``IterStats.lost_tokens``,
telemetry ``degraded_iters`` / ``availability``) while its recovery
chunk — ordered ahead of optimization chunks — streams under the same
byte budget as any migration.  Checkpoints are refused mid-recovery:
the weights contain zeroed slabs a restore could resurrect.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.obs.trace import NULL_TRACER
from repro.placement.migrate import MOE_WEIGHT_KEYS, moe_param_paths

Tree = Any

STATE_HEALTHY = "healthy"
STATE_DEGRADED = "degraded"    # unroutable experts pending recovery
STATE_SHRUNK = "shrunk"        # dead ranks, every expert routable
STATE_WARMING = "warming"      # rejoined rank streaming its slabs


def zero_rank_slabs(params: Tree, rank: int, slots_per_rank: int) -> Tree:
    """Zero every MoE weight row on ``rank``'s physical slots — the
    simulated loss of that rank's expert memory.  Returns a new tree
    (shallow-copied containers, non-MoE leaves aliased)."""
    lo, hi = rank * slots_per_rank, (rank + 1) * slots_per_rank
    out = dict(params)
    for group, lname in moe_param_paths(params):
        grp = dict(out[group])
        lp = dict(grp[lname])
        moe = dict(lp["moe"])
        for key in MOE_WEIGHT_KEYS:
            w = moe[key]
            axis = w.ndim - 3          # slot axis: [L,S,..] -> 1, [S,..] -> 0
            idx = [slice(None)] * w.ndim
            idx[axis] = slice(lo, hi)
            if isinstance(w, np.ndarray):
                w = w.copy()
                w[tuple(idx)] = 0
            else:
                w = w.at[tuple(idx)].set(0)
            moe[key] = w
        lp["moe"] = moe
        grp[lname] = lp
        out[group] = grp
    return out


class ElasticCoordinator:
    """Owns the rank-liveness state machine over a
    :class:`~repro.replication.manager.ReplicaManager` and drives the
    degraded-mode / recovery / rejoin flows.  Engine-agnostic: the
    engine (or a host-side test) calls :meth:`fail_rank` /
    :meth:`rejoin_rank` on events, passes :meth:`recovery_layers` /
    :meth:`patch_params` into its executor, and reports landed layers
    via :meth:`on_layers_landed`.

    ``ckpt_dir`` points at an engine checkpoint carrying the ``serving``
    params group and the manager's state group — the re-materialization
    source for singleton experts.  Without one, a rank loss that strands
    a singleton is refused (replicated-only losses still work).
    """

    tracer = NULL_TRACER            # optional span tracer (engine-shared)

    def _emit(self, ev: Dict) -> None:
        """Append one elastic event; mirror it as a trace instant so the
        fail/recover/warm timeline rides the same Perfetto view."""
        self.events.append(ev)
        if self.tracer.enabled:
            self.tracer.instant(f"elastic.{ev['kind']}", cat="elastic",
                                args={k: v for k, v in ev.items()
                                      if k != "kind"})

    def __init__(self, manager, ckpt_dir: Optional[str] = None,
                 clock=None, telemetry=None):
        if not hasattr(manager, "rsets"):
            raise TypeError("ElasticCoordinator requires a ReplicaManager "
                            "(replica sets are the availability mechanism)")
        self.manager = manager
        self.ckpt_dir = ckpt_dir
        self.clock = clock if clock is not None else time.monotonic
        self.telemetry = telemetry
        # layer index (manager table space) -> lost logical experts
        self.lost: Dict[int, np.ndarray] = {}
        self._warming: set = set()           # rejoined, not yet hosting
        self._fail_t: Optional[float] = None
        self.last_recovery_s: Optional[float] = None
        self.events: List[Dict] = []
        self._saved_cache = None

    # -- state views -------------------------------------------------------
    @property
    def rank_alive(self) -> np.ndarray:
        return self.manager.rank_alive

    @property
    def state(self) -> str:
        if self.lost:
            return STATE_DEGRADED
        if self._warming:
            return STATE_WARMING
        if not self.rank_alive.all():
            return STATE_SHRUNK
        return STATE_HEALTHY

    @property
    def recovering(self) -> bool:
        """Unroutable experts pending re-materialization."""
        return bool(self.lost)

    @property
    def lost_experts(self) -> np.ndarray:
        """Sorted union of unroutable logical experts across layers."""
        if not self.lost:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(list(self.lost.values())))

    def lost_token_count(self, expert_stats) -> float:
        """Tokens one iteration routed to unroutable experts —
        ``expert_stats [n_blocks, 2, E]`` per-layer (load, vis) counts."""
        if not self.lost:
            return 0.0
        es = np.asarray(expert_stats, np.float64)
        per_layer = (self.manager.per_layer
                     and es.shape[0] == self.manager.n_tables)
        tot = 0.0
        for l, exs in self.lost.items():
            rows = es[l: l + 1] if per_layer else es
            tot += float(rows[:, 0, exs].sum())
        return tot

    def effective_mesh(self, mesh, lost_axis: str = "model"):
        """The physical mesh minus the dead ``lost_axis`` slices —
        ``runtime.elastic.shrink_mesh`` applied per dead rank (highest
        index first so earlier indices stay valid)."""
        from repro.runtime.elastic import shrink_mesh
        for r in sorted(np.flatnonzero(~self.rank_alive), reverse=True):
            mesh = shrink_mesh(mesh, lost_axis, lost_index=int(r))
        return mesh

    # -- events ------------------------------------------------------------
    def fail_rank(self, rank: int, params: Optional[Tree] = None):
        """Handle a rank loss: mask the dead rank out of every routable
        set (experts with a surviving replica stay routable *now*),
        record unroutable singletons, zero the dead slabs in ``params``
        (when given) and arm an event-triggered replan whose diff
        re-places the strays onto the live ranks.  Returns ``params``
        (new tree when zeroed).  Raises if a stranded singleton has no
        checkpoint to be re-materialized from, before mutating state."""
        rank = int(rank)
        if not self.manager.rank_alive[rank]:
            raise ValueError(f"rank {rank} is already dead")
        alive = self.manager.rank_alive.copy()
        alive[rank] = False
        if not alive.any():
            raise ValueError("cannot fail the last live rank")
        would_lose = any(rs.masked(alive)[1].size
                         for rs in self.manager.rsets)
        if would_lose and not self._has_checkpoint():
            raise RuntimeError(
                f"rank {rank} hosts singleton experts and no checkpoint "
                f"is available to re-materialize them (ckpt_dir="
                f"{self.ckpt_dir!r}) — refusing to drop experts")
        t = self.clock()
        self.manager.rank_alive[rank] = False
        lost = self.manager.mask_dead_ranks()
        for l, exs in lost.items():
            prev = self.lost.get(l)
            self.lost[l] = exs if prev is None \
                else np.unique(np.concatenate([prev, exs]))
        self.manager.must_layers = set(self.lost)
        self._warming.discard(rank)
        if params is not None:
            params = zero_rank_slabs(params, rank,
                                     self.manager.slots_per_rank)
        self.manager.request_replan()
        if self.lost:
            if self._fail_t is None:
                self._fail_t = t
        else:
            # replicated everywhere: availability never broke
            self.last_recovery_s = 0.0
            if self.telemetry is not None:
                self.telemetry.record_recovery(0.0)
        self._emit(dict(kind="fail", rank=rank, t=t,
                        n_lost=int(self.lost_experts.size),
                        state=self.state))
        return params

    def rejoin_rank(self, rank: int) -> None:
        """Handle a rank rejoin: mark it live and arm a replan that
        places replicas there.  The rank stays *unroutable* until the
        staged plan's slabs land layer by layer (the warm-up is the
        staged-commit rule doing its normal job: a table entry flips to
        the rejoined rank only after that layer's slab streamed)."""
        rank = int(rank)
        if self.manager.rank_alive[rank]:
            raise ValueError(f"rank {rank} is already live")
        self.manager.rank_alive[rank] = True
        self._warming.add(rank)
        self.manager.request_replan()
        self._emit(dict(kind="rejoin", rank=rank, t=self.clock(),
                        state=self.state))

    # -- executor hooks ----------------------------------------------------
    def recovery_layers(self, plan) -> List[int]:
        """The plan's chunk layers that carry re-materialization of
        unroutable experts — the executor orders these first."""
        return [l for l in self.manager.plan_layers(plan) if l in self.lost]

    def on_layers_landed(self, plan, layers) -> None:
        """Engine callback after ``commit_layers(plan, layers)``: clears
        the recovered experts, stamps ``recovery_s`` when the last one
        lands, and retires the warming state once the rejoin plan has
        fully landed and the rank hosts replicas again."""
        now = self.clock()
        recovered = False
        for layer in layers:
            layer = int(layer)
            if layer in self.lost:
                del self.lost[layer]
                recovered = True
        if recovered:
            self.manager.must_layers = set(self.lost)
        if not self.lost and self._fail_t is not None:
            self.last_recovery_s = now - self._fail_t
            self._fail_t = None
            if self.telemetry is not None:
                self.telemetry.record_recovery(self.last_recovery_s)
            self._emit(dict(kind="recovered", t=now,
                            recovery_s=self.last_recovery_s,
                            state=self.state))
        if self._warming and self.manager.in_flight is None:
            for r in sorted(self._warming):
                if self.manager.hosts_rank(r):
                    self._warming.discard(r)
                    self._emit(dict(kind="warm", rank=r, t=now,
                                    state=self.state))

    # -- checkpoint re-materialization -------------------------------------
    def _has_checkpoint(self) -> bool:
        if self.ckpt_dir is None:
            return False
        return (ckpt_lib.has_group(self.ckpt_dir, "serving")
                and ckpt_lib.has_group(self.ckpt_dir,
                                       self.manager.ckpt_group))

    def _saved(self):
        """(flat serving group, saved rep_pos [T,E,R], saved n_tables) —
        where each logical expert's weights lived at save time."""
        if self._saved_cache is not None:
            return self._saved_cache
        if not self._has_checkpoint():
            raise RuntimeError(
                f"no checkpoint with 'serving' + "
                f"{self.manager.ckpt_group!r} groups under "
                f"{self.ckpt_dir!r} to re-materialize lost experts from")
        flat = ckpt_lib.restore_group(self.ckpt_dir, "serving")
        mstate = ckpt_lib.restore_group(self.ckpt_dir,
                                        self.manager.ckpt_group)
        rep_pos = np.asarray(mstate["rep_pos"], np.int64)
        if rep_pos.ndim == 2:
            rep_pos = rep_pos[None]
        self._saved_cache = (flat, rep_pos, rep_pos.shape[0])
        return self._saved_cache

    def invalidate_checkpoint_cache(self) -> None:
        """Forget the cached checkpoint rows (call after a new save)."""
        self._saved_cache = None

    def patch_params(self, params: Tree, plan, layers) -> Tree:
        """Overwrite the landing slots of lost experts in ``layers`` with
        their checkpoint rows — the slab gather sourced them from the
        dead (zeroed) slot, this re-materializes the real weights.  The
        executor calls this between the gather and the commit, so the
        staged-commit rule holds: the new table flips only once the
        slot holds the true expert weights."""
        todo = [int(l) for l in layers if int(l) in self.lost]
        if not todo:
            return params
        flat, saved_pos, saved_nt = self._saved()
        new_sets = getattr(plan, "new_sets", None)
        out = dict(params)
        for group, lname in moe_param_paths(params):
            grp = dict(out[group])
            lp = dict(grp[lname])
            moe = dict(lp["moe"])
            for key in MOE_WEIGHT_KEYS:
                w = moe[key]
                path = f"params|{group}|{lname}|moe|{key}"
                if path not in flat:
                    raise KeyError(f"checkpoint missing {path!r}")
                saved = flat[path]
                if saved.shape != tuple(w.shape):
                    raise ValueError(
                        f"checkpoint {path!r} shape {saved.shape} != "
                        f"current {tuple(w.shape)} — geometry changed")
                moe[key] = self._patch_weight(w, saved, saved_pos,
                                              saved_nt, plan, new_sets,
                                              todo)
            lp["moe"] = moe
            grp[lname] = lp
            out[group] = grp
        return out

    def _patch_weight(self, w, saved, saved_pos, saved_nt, plan,
                      new_sets, layers):
        """One weight array: write each lost expert's saved primary row
        into its destination slots.  ``[L, S, ...]`` stacked weights are
        row-patched per plan layer (per-layer manager) or across the
        whole stack (shared plan); ``[S, ...]`` unstacked weights are
        patched on the slot axis."""
        stacked = w.ndim == 4
        per_layer_plan = new_sets is not None
        writes = []                      # (index tuple, source rows)
        for l in layers:
            new_set = new_sets[l] if per_layer_plan \
                else plan.new_set
            spos = saved_pos[l if saved_nt > 1 else 0]
            for ex in self.lost[l]:
                src = int(spos[ex, 0])   # saved primary slot of ex
                dests = np.unique(
                    new_set.rep_pos[ex, :new_set.n_rep[ex]]).astype(int)
                for dst in dests:
                    if stacked and per_layer_plan and self.manager.n_tables > 1:
                        writes.append(((l, dst), saved[l, src]))
                    elif stacked:
                        writes.append(((slice(None), dst),
                                       saved[:, src]))
                    else:
                        writes.append(((dst,), saved[src]))
        if not writes:
            return w
        if isinstance(w, np.ndarray):
            w = w.copy()
            for idx, val in writes:
                w[idx] = val
            return w
        import jax.numpy as jnp
        for idx, val in writes:
            w = w.at[idx].set(jnp.asarray(val, dtype=w.dtype))
        return w
