"""Per-request multimodal prompt synthesis.

Turns a named workload profile (MMMU, TextVQA, … — the same calibration
the iteration-level trace generator uses, see
:mod:`repro.workloads.profiles`) into concrete serving requests: prompt
length, vision-token count and placement, modality masks, decode-side
modality, and optional stub vision embeddings.

Vision tokens are drawn from the upper half of the vocabulary (the stub
frontend's codebook) and placed either as a contiguous prefix block (the
common VLM image-then-question layout) or interleaved through the prompt
(document/figure-heavy layouts) — placement matters because ReaLB's
modality metadata is positional.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.scheduler import Request
from repro.workloads.profiles import WORKLOADS


@dataclasses.dataclass(frozen=True)
class PromptProfile:
    """Request-level synthesis parameters for one named workload."""
    name: str
    vision_frac_mean: float = 0.6
    vision_frac_std: float = 0.15
    prompt_len_mean: int = 160
    prompt_len_std: int = 64
    prompt_len_min: int = 16
    prompt_len_max: int = 384
    interleave_prob: float = 0.15    # scatter vision tokens vs prefix block
    decode_vision_prob: float = 0.05  # image-gen style: decoded tokens are vision
    max_new_mean: int = 12
    max_new_min: int = 2
    max_new_max: int = 32


def profile(name: str, **overrides) -> PromptProfile:
    """Build a :class:`PromptProfile` from the shared WORKLOADS calibration
    (modality-mix fields); routing-skew fields stay with the trace layer."""
    cal = WORKLOADS[name]
    kw = dict(vision_frac_mean=cal["vision_frac_mean"],
              vision_frac_std=cal["vision_frac_std"])
    kw.update(overrides)
    return PromptProfile(name=name, **kw)


@dataclasses.dataclass
class RequestSpec:
    """One synthesized request: everything needed to reconstruct the exact
    serving input, JSONL-serializable for record/replay."""
    uid: int
    arrival: float
    tokens: np.ndarray               # [S] int32
    modality: np.ndarray             # [S] bool
    max_new_tokens: int
    decode_modality: bool = False
    embed_seed: Optional[int] = None  # stub vision embeds, regenerated

    def to_request(self, d_model: int = 0) -> Request:
        embeds = None
        if self.embed_seed is not None and d_model > 0:
            n_vis = int(self.modality.sum())
            embeds = np.random.default_rng(self.embed_seed).normal(
                0, 0.02, (n_vis, d_model)).astype(np.float32)
        return Request(uid=self.uid,
                       tokens=self.tokens.astype(np.int32),
                       modality=self.modality.astype(bool),
                       max_new_tokens=self.max_new_tokens,
                       vision_embeds=embeds,
                       decode_modality=self.decode_modality,
                       arrival_time=float(self.arrival))


def synth_request(prof: PromptProfile, uid: int, arrival: float, rng,
                  vocab_size: int, max_prompt: Optional[int] = None,
                  with_embeds: bool = False) -> RequestSpec:
    p_max = min(prof.prompt_len_max, max_prompt or prof.prompt_len_max)
    p_len = int(np.clip(round(rng.normal(prof.prompt_len_mean,
                                         prof.prompt_len_std)),
                        prof.prompt_len_min, p_max))
    vf = float(np.clip(rng.normal(prof.vision_frac_mean,
                                  prof.vision_frac_std), 0.0, 0.95))
    n_vis = int(round(p_len * vf))
    toks = rng.integers(0, vocab_size // 2, p_len).astype(np.int32)
    modality = np.zeros(p_len, bool)
    if n_vis:
        if rng.random() < prof.interleave_prob:
            vis_pos = rng.choice(p_len, n_vis, replace=False)
        else:
            vis_pos = np.arange(n_vis)
        modality[vis_pos] = True
        # vision tokens live in the stub frontend's codebook (upper vocab)
        toks[modality] = vocab_size // 2 + toks[modality]
    max_new = int(np.clip(round(rng.normal(prof.max_new_mean,
                                           prof.max_new_mean / 3)),
                          prof.max_new_min, prof.max_new_max))
    return RequestSpec(
        uid=uid, arrival=float(arrival), tokens=toks, modality=modality,
        max_new_tokens=max_new,
        decode_modality=bool(rng.random() < prof.decode_vision_prob),
        embed_seed=(int(rng.integers(0, 2 ** 31)) if with_embeds and n_vis
                    else None))


def make_stream(prof: PromptProfile, arrivals: np.ndarray, vocab_size: int,
                seed: int = 0, max_prompt: Optional[int] = None,
                with_embeds: bool = False) -> List[RequestSpec]:
    """Synthesize one request per arrival time; fully determined by
    (profile, arrivals, seed, vocab_size)."""
    rng = np.random.default_rng(seed)
    return [synth_request(prof, uid, t, rng, vocab_size,
                          max_prompt=max_prompt, with_embeds=with_embeds)
            for uid, t in enumerate(np.sort(np.asarray(arrivals)))]


def stream_stats(specs: List[RequestSpec]) -> Dict[str, float]:
    """Quick composition summary of a request stream."""
    if not specs:
        return {}
    vis_fracs = [float(s.modality.mean()) for s in specs]
    return {
        "n_requests": len(specs),
        "prompt_tokens": int(sum(len(s.tokens) for s in specs)),
        "mean_prompt_len": float(np.mean([len(s.tokens) for s in specs])),
        "mean_vision_frac": float(np.mean(vis_fracs)),
        "vision_heavy_frac": float(np.mean([f > 0.5 for f in vis_fracs])),
        "span": float(specs[-1].arrival - specs[0].arrival),
    }
