"""Traffic-generation subsystem: request-level workloads for the serving
stack (arrival processes, multimodal prompt synthesis, record/replay).

The iteration-level trace generator in ``benchmarks/traces.py`` and this
request-level layer share one calibration (:mod:`repro.workloads.profiles`).
"""
from repro.workloads.arrivals import (ArrivalConfig, ClosedLoop,
                                      IterationCostModel, VirtualClock,
                                      arrival_times)
from repro.workloads.multimodal import (PromptProfile, RequestSpec,
                                        make_stream, profile, stream_stats,
                                        synth_request)
from repro.workloads.profiles import WORKLOADS
from repro.workloads.replay import load_stream, save_stream

__all__ = [
    "ArrivalConfig", "ClosedLoop", "IterationCostModel", "VirtualClock",
    "arrival_times", "PromptProfile", "RequestSpec", "make_stream",
    "profile", "stream_stats", "synth_request", "WORKLOADS",
    "load_stream", "save_stream",
]
