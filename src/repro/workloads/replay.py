"""Record/replay of request streams to JSONL.

A recorded stream pins the *exact* serving input — every token id,
modality bit, arrival timestamp and generation length — so policy A/B
runs (ReaLB vs. ReaLB-seq vs. off) see identical traffic, the same way
the iteration-level trace generator feeds identical randomness to every
strategy simulator.

Format: line 1 is a header object ``{"format": "repro.workloads", ...}``
with version + free-form metadata; each following line is one
:class:`~repro.workloads.multimodal.RequestSpec`.  Round-trips exactly
(integers and bools verbatim; arrival times via repr-float).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.workloads.multimodal import RequestSpec

FORMAT = "repro.workloads"
VERSION = 1


def _spec_to_obj(s: RequestSpec) -> Dict:
    return {
        "uid": int(s.uid),
        "arrival": float(s.arrival),
        "tokens": [int(t) for t in s.tokens],
        "modality": [int(b) for b in s.modality],
        "max_new_tokens": int(s.max_new_tokens),
        "decode_modality": bool(s.decode_modality),
        "embed_seed": (None if s.embed_seed is None else int(s.embed_seed)),
    }


def _obj_to_spec(o: Dict) -> RequestSpec:
    return RequestSpec(
        uid=int(o["uid"]),
        arrival=float(o["arrival"]),
        tokens=np.asarray(o["tokens"], np.int32),
        modality=np.asarray(o["modality"], bool),
        max_new_tokens=int(o["max_new_tokens"]),
        decode_modality=bool(o.get("decode_modality", False)),
        embed_seed=o.get("embed_seed"))


def save_stream(path, specs: List[RequestSpec],
                meta: Optional[Dict] = None) -> None:
    path = Path(path)
    header = {"format": FORMAT, "version": VERSION, "n": len(specs),
              "meta": meta or {}}
    with path.open("w") as f:
        f.write(json.dumps(header) + "\n")
        for s in specs:
            f.write(json.dumps(_spec_to_obj(s)) + "\n")


def load_stream(path) -> Tuple[Dict, List[RequestSpec]]:
    """Returns (header meta dict, specs)."""
    path = Path(path)
    with path.open() as f:
        header = json.loads(f.readline())
        if header.get("format") != FORMAT:
            raise ValueError(f"{path}: not a {FORMAT} stream")
        if header.get("version", 0) > VERSION:
            raise ValueError(f"{path}: stream version {header['version']} "
                             f"newer than supported {VERSION}")
        specs = [_obj_to_spec(json.loads(line)) for line in f if line.strip()]
    if header.get("n") is not None and header["n"] != len(specs):
        raise ValueError(f"{path}: truncated stream "
                         f"({len(specs)}/{header['n']} records)")
    return header.get("meta", {}), specs
