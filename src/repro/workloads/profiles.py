"""Per-benchmark workload calibration (modality mix & routing dynamics).

Single source of truth shared by BOTH workload layers:

* the iteration-level trace generator (``benchmarks/traces.py``) uses the
  modality mix plus the routing-skew fields (``zipf_a``, ``jump_every``),
* the request-level generator (:mod:`repro.workloads.multimodal`) uses the
  modality mix to synthesize per-request prompts,

so trace-driven policy simulations and end-to-end serving runs of the same
named workload are calibrated identically (paper §5.1 benchmark suite).
"""
from __future__ import annotations

from typing import Dict

WORKLOADS: Dict[str, Dict] = {
    "MMMU":      dict(vision_frac_mean=0.72, vision_frac_std=0.15,
                      zipf_a=1.18, jump_every=220),
    "MathVista": dict(vision_frac_mean=0.55, vision_frac_std=0.18,
                      zipf_a=1.12, jump_every=300),
    "DynaMath":  dict(vision_frac_mean=0.62, vision_frac_std=0.25,
                      zipf_a=1.2, jump_every=160),
    "AI2D":      dict(vision_frac_mean=0.5, vision_frac_std=0.12,
                      zipf_a=1.1, jump_every=350),
    "InfoVQA":   dict(vision_frac_mean=0.66, vision_frac_std=0.14,
                      zipf_a=1.15, jump_every=280),
    "TextVQA":   dict(vision_frac_mean=0.45, vision_frac_std=0.12,
                      zipf_a=1.08, jump_every=320),
    "MMBench":   dict(vision_frac_mean=0.55, vision_frac_std=0.15,
                      zipf_a=1.12, jump_every=260),
}
