"""Optimizer substrate: AdamW (from scratch) + distributed grad utilities."""
from repro.optim.adamw import (OptState, abstract_opt_state, adamw_update,
                               clip_by_global_norm, global_norm,
                               init_opt_state, lr_schedule)
