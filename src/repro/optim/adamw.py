"""AdamW from scratch (no optax), with warmup-cosine schedule.

Optimizer state mirrors the parameter tree (same shardings → ZeRO-style
sharding comes for free from the FSDP parameter specs), with f32 moments
regardless of the bf16 parameter dtype.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Tree = Any


class OptState(NamedTuple):
    step: jax.Array     # i32 scalar
    mu: Tree            # first moments (f32)
    nu: Tree            # second moments (f32)


def init_opt_state(params: Tree, cfg: TrainConfig) -> OptState:
    dt = jnp.dtype(cfg.opt_state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(z, params), jax.tree.map(z, params))


def abstract_opt_state(abstract_params: Tree, cfg: TrainConfig) -> OptState:
    dt = jnp.dtype(cfg.opt_state_dtype)

    def mk(p):
        sh = getattr(p, "sharding", None)
        return jax.ShapeDtypeStruct(p.shape, dt, sharding=sh)

    return OptState(jax.ShapeDtypeStruct((), jnp.int32),
                    jax.tree.map(mk, abstract_params),
                    jax.tree.map(mk, abstract_params))


def lr_schedule(step: jax.Array, cfg: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Tree, max_norm: float
                        ) -> Tuple[Tree, jax.Array]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), grads), g


def adamw_update(params: Tree, grads: Tree, state: OptState,
                 cfg: TrainConfig) -> Tuple[Tree, OptState, Dict]:
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
