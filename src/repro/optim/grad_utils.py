"""Distributed-optimization utilities: gradient accumulation and int8
gradient compression with error feedback.

``compressed_psum`` quantizes per-leaf gradients to int8 (per-tensor amax
scale), reduces the int8 payload over the data axis (8× less cross-node
traffic than f32), dequantizes, and carries the quantization residual in
an error-feedback buffer so the compression bias vanishes over steps —
the standard 1-bit/8-bit Adam trick adapted to jax collectives.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.models.common import shard_map

Tree = Any


def init_error_feedback(grads_like: Tree) -> Tree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-20)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_leaf(g: jax.Array, err: jax.Array,
                  psum: Callable[[jax.Array], jax.Array]
                  ) -> Tuple[jax.Array, jax.Array]:
    """One leaf: error-feedback + int8 quantize + reduce + new residual."""
    gf = g.astype(jnp.float32) + err
    q, scale = _quantize_int8(gf)
    g_hat = q.astype(jnp.float32) * scale
    new_err = gf - g_hat
    # reduce the *quantized* payload: int32 accumulate of int8 values, then
    # a tiny f32 psum of the per-shard scales (scales differ per shard, so
    # reduce q·scale in two terms: Σ q_i·scale_i ≡ psum(q·scale) — we keep
    # the int8-payload semantics by psumming q (int32) when scales agree
    # and falling back to the exact two-term form otherwise).
    reduced = psum(q.astype(jnp.int32).astype(jnp.float32) * scale)
    return reduced.astype(g.dtype), new_err


def compressed_grad_psum(grads: Tree, err: Tree, axis_name: str
                         ) -> Tuple[Tree, Tree]:
    """int8-compressed gradient all-reduce over `axis_name` (inside
    shard_map) with error feedback. Returns (reduced grads, new err)."""
    psum = lambda x: jax.lax.psum(x, axis_name)
    out = jax.tree.map(lambda g, e: compress_leaf(g, e, psum), grads, err,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return red, new_err


def compressed_all_reduce(stacked_grads: Tree, stacked_err: Tree, mesh,
                          axis_name: str = "data") -> Tuple[Tree, Tree]:
    """Host-level entry: reduce per-rank gradient shards stacked on a
    leading ``axis_name``-sized dim via :func:`compressed_grad_psum` inside
    a manual ``shard_map`` region.  Every leaf must be ``[R, ...]`` with
    ``R == mesh size along axis_name``; the returned reduced tree carries
    the (identical) reduction in every row, the error-feedback tree stays
    per-rank."""
    spec = PartitionSpec(axis_name)

    def fn(g, e):
        return compressed_grad_psum(g, e, axis_name)

    return shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec))(stacked_grads, stacked_err)


def accumulate_grads(loss_fn: Callable, params: Tree, batches,
                     n_accum: int, **kw) -> Tuple[jax.Array, Tree, Any]:
    """Microbatched gradient accumulation (unrolled; n_accum is small).

    `batches`: tree of arrays with leading dim n_accum (microbatch stack).
    Returns (mean loss, mean grads, last aux).
    """
    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    losses = []
    aux = None
    for i in range(n_accum):
        micro = jax.tree.map(lambda x: x[i], batches)
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, micro, **kw)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        losses.append(loss)
    return (jnp.stack(losses).mean(),
            jax.tree.map(lambda g: g / n_accum, acc), aux)
