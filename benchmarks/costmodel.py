"""Roofline-calibrated analytic latency model (TPU v5e) + strategy sims.

Per MoE layer, per EP rank:

    t_rank = max(flops_r / rate(precision), bytes_r / HBM_BW) + t_fixed
    t_layer = t_dispatch + max_r t_rank + t_combine (+ visible T_LB)

On TPU the FP4 path wins through 4.25-bit weight streaming (memory-bound
regimes) and the int8 MXU issue rate (compute-bound regimes) — see
DESIGN.md §2 for why this replaces the paper's FP4-tensor-core flop win.

Strategies (paper §5.1): Baseline, FP4-All, EPLB, Async-EPLB, ReaLB,
ReaLB-seq, ReaLB-m1/m2.  All run on identical traces; EPLB replicates
hot experts from sliding-window history (prediction-based), ReaLB runs
the real :mod:`repro.core.policy` AIMD controller on the instantaneous
loads.

Placement strategies (the repo's ``repro.placement`` subsystem on the
same traces): ``sim_placement`` runs the real EWMA predictor + planner
and charges each replan its migration time (moved expert slabs over
ICI), ``sim_realb_placement`` is the hybrid — placement remaps the
slow-timescale skew, ReaLB's FP4 absorbs what the plan missed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks import traces as tr
from repro.configs.base import MIGRATION_BW_DEFAULT
from repro.configs.hw import HBM_BW, PEAK_BF16, PEAK_INT8  # single-sourced
#                              with launch.roofline and obs.ledger (v5e)

ICI_BW = MIGRATION_BW_DEFAULT  # per link — single-sourced with the
#                                managers' migration_bw default, so sims,
#                                replan gates and engine accounting price
#                                the same bytes at the same rate
FIXED_US = 12.0               # dispatch/kernel fixed overhead per stage
BYTES_BF16 = 2.0
BYTES_FP4 = 0.53125           # 4 bits + e4m3 scale per 16-group = 4.25 b


@dataclasses.dataclass(frozen=True)
class MoEGeometry:
    """Model geometry of the MoE stack (per layer)."""
    name: str
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int
    n_moe_layers: int
    moe_time_share: float = 0.45   # MoE fraction of e2e at baseline (Fig 5)


KIMI_VL = MoEGeometry("Kimi-VL", 2048, 1408, 64, 6, 47)
QWEN3_VL = MoEGeometry("Qwen3-VL", 2048, 768, 128, 8, 48,
                       moe_time_share=0.38)


def expert_gemm_time(tokens_r: float, g: MoEGeometry, ep: int,
                     fp4: bool, fused: bool = True) -> float:
    """Per-rank grouped expert GEMM time (seconds).

    ``fused=True`` (default — what every strategy sim prices, and what the
    serving hot loop now runs via ``repro.kernels.grouped_fp4_ffn``):
    packed FP4 weights stream HBM→VMEM once at 4.25 bits/weight and are
    dequantized in-register.  ``fused=False`` models the unfused jnp
    fallback, which materializes a BF16 dequantized copy of the slab in
    HBM (write + read) before the grouped GEMM.
    """
    e_loc = g.n_experts // ep
    flops = tokens_r * 6.0 * g.d_model * g.d_ff           # gate+up+down
    w_raw = e_loc * 3.0 * g.d_model * g.d_ff
    w_bytes = w_raw * (BYTES_FP4 if fp4 else BYTES_BF16)
    if fp4 and not fused:
        w_bytes += w_raw * 2.0 * BYTES_BF16   # dequant round-trip (wr + rd)
    act_bytes = tokens_r * g.d_model * BYTES_BF16 * 4.0
    rate = PEAK_INT8 if fp4 else PEAK_BF16
    return max(flops / rate, (w_bytes + act_bytes) / HBM_BW)


def quantize_time(g: MoEGeometry, ep: int) -> float:
    """On-the-fly BF16→FP4 transformation of one rank's experts (read bf16,
    write packed): the T term hidden by the overlap pipeline."""
    e_loc = g.n_experts // ep
    w = e_loc * 3.0 * g.d_model * g.d_ff
    return (w * BYTES_BF16 + w * BYTES_FP4) / HBM_BW


def quantize_visible_time(g: MoEGeometry, ep: int, dispatch_s: float,
                          fused: bool = True) -> float:
    """Wall-visible share of the transformation T (paper §4.3).

    Fused, T issues inside the dispatch window (no data dependency on the
    a2a — the Pallas quantize kernel launches with dispatch in flight), so
    only the part longer than dispatch peeks out.  Unfused it is a
    separate serial stage: fully visible bytes plus the per-stage fixed
    launch overhead (the same FIXED_US every other standalone stage pays —
    cf. the ``+15e-6`` the ReaLB-seq sim charges a serialized T).
    """
    q = quantize_time(g, ep)
    return max(0.0, q - dispatch_s) if fused else q + FIXED_US * 1e-6


def dispatch_time(tokens_total: float, ep: int, d_model: float) -> float:
    """all-to-all dispatch (and combine) over the EP group."""
    per_rank = tokens_total / ep * (ep - 1) / ep * d_model * BYTES_BF16
    return per_rank / ICI_BW + FIXED_US * 1e-6


def migration_bytes(n_moved: int, g: MoEGeometry) -> float:
    """Weight bytes crossing ranks when ``n_moved`` experts change owner
    (gate+up+down, every MoE layer — the whole stack shares one table)."""
    from repro.placement.migrate import expert_bytes_raw
    return n_moved * expert_bytes_raw(g.d_model, g.d_ff, BYTES_BF16,
                                      g.n_moe_layers)


def _bw_of(bw) -> float:
    """bytes/s of a bandwidth argument: None = the static ICI constant,
    else anything float()-able — in particular a live
    :class:`repro.placement.migrate.MigrationBandwidth` EWMA, so measured
    apply_to_params wall clocks re-price the migration side of the gates
    the same way CalibratedReplanCostGate re-prices the savings side."""
    return ICI_BW if bw is None else max(float(bw), 1.0)


def migration_time(n_moved: int, g: MoEGeometry, bw=None) -> float:
    """Serial transfer time of a migration over the EP fabric — the cost
    term placement pays and ReaLB's precision switch does not."""
    return migration_bytes(n_moved, g) / _bw_of(bw)


def migration_bytes_layers(n_moved_pairs: int, g: MoEGeometry,
                           n_tables: int) -> float:
    """Weight bytes of a *layer-diff* migration: ``n_moved_pairs``
    (expert, layer) pairs changed owner, each dragging only its own
    table-layer's share of the stack (``n_moe_layers / n_tables`` MoE
    layers) instead of the whole stack."""
    from repro.placement.migrate import expert_bytes_raw
    per_table = g.n_moe_layers / max(n_tables, 1)
    return n_moved_pairs * expert_bytes_raw(g.d_model, g.d_ff, BYTES_BF16,
                                            per_table)


def migration_time_layers(n_moved_pairs: int, g: MoEGeometry,
                          n_tables: int, bw=None) -> float:
    return migration_bytes_layers(n_moved_pairs, g, n_tables) / _bw_of(bw)


@dataclasses.dataclass
class ReplanCostGate:
    """Amortized-gain guard coupling the replan cadence to the latency
    model: accept a migration only when the predicted per-iteration MoE
    layer-time saving, summed over the plan's amortization horizon (the
    iterations until the next replan can fire), exceeds the serial
    migration transfer time.  Plugs into ``PlacementManager`` /
    ``ReplicaManager`` as ``cost_gate``."""
    g: MoEGeometry
    ep: int
    horizon_iters: int              # replan_every of the manager
    tokens_per_iter: float = 4096.0  # typical routed batch the savings
    #                                  are evaluated at
    bandwidth: object = None        # None = static ICI_BW; the managers
    #                                 wire their measured-bandwidth EWMA
    #                                 in here so gate pricing tracks it
    time_scale: object = None       # None = trust the analytic model;
    #                                 the profiler wires its measured/
    #                                 predicted drift EWMA in here so the
    #                                 savings side of the gate tracks
    #                                 reality the way bandwidth does for
    #                                 the migration side

    def _time_scale(self) -> float:
        """Measured-over-predicted calibration of the savings side: 1.0
        when unwired, else anything float()-able — in particular the
        profiler's :meth:`repro.obs.profiler.Profiler.time_scale` EWMA."""
        if self.time_scale is None:
            return 1.0
        ts = self.time_scale
        return max(float(ts() if callable(ts) else ts), 1e-3)

    def layer_seconds(self, rank_loads: np.ndarray) -> float:
        """MoE layer time of one iteration under the given (relative)
        per-rank loads, scaled to ``tokens_per_iter``."""
        loads = np.asarray(rank_loads, np.float64)
        tot = loads.sum()
        if tot <= 0:
            return 0.0
        tok = loads * (self.tokens_per_iter * self.g.top_k / tot)
        t, _ = moe_layer_time(tok, np.zeros(self.ep), self.g, self.ep,
                              self.tokens_per_iter)
        return t * self._time_scale()

    def accept(self, old_rank_loads: np.ndarray,
               new_rank_loads: np.ndarray, n_moved: int) -> bool:
        if n_moved <= 0:
            return True
        saving = (self.layer_seconds(old_rank_loads)
                  - self.layer_seconds(new_rank_loads))
        horizon = saving * self.g.n_moe_layers * max(self.horizon_iters, 1)
        return horizon > migration_time(n_moved, self.g, bw=self.bandwidth)

    def accept_layers(self, old_rank_loads: np.ndarray,
                      new_rank_loads: np.ndarray, n_moved: int) -> bool:
        """Per-layer variant: ``old/new_rank_loads`` are ``[L, ep]``
        stacks and ``n_moved`` counts (expert, layer) pairs.  Savings sum
        over the per-layer plans; the migration side charges only the
        changed layers' slabs (``migration_time_layers``), so a plan that
        touches 2 of 48 layers amortizes ~24× faster than a full-stack
        one."""
        if n_moved <= 0:
            return True
        old = np.atleast_2d(np.asarray(old_rank_loads, np.float64))
        new = np.atleast_2d(np.asarray(new_rank_loads, np.float64))
        n_tables = old.shape[0]
        saving = sum(self.layer_seconds(old[l]) - self.layer_seconds(new[l])
                     for l in range(n_tables))
        # each table layer stands for n_moe_layers / n_tables model layers
        scale = self.g.n_moe_layers / max(n_tables, 1)
        horizon = saving * scale * max(self.horizon_iters, 1)
        return horizon > migration_time_layers(n_moved, self.g, n_tables,
                                               bw=self.bandwidth)


class CalibratedReplanCostGate:
    """A :class:`ReplanCostGate` whose ``tokens_per_iter`` is calibrated
    from *measured* engine iterations instead of the static TPU-v5e
    roofline constant (ROADMAP "Cost-gate calibration on hardware").

    The engine feeds ``observe_iter(tokens, t_wall)`` from every recorded
    :class:`~repro.serving.engine.IterStats`; the gate keeps a bounded
    window and evaluates replan savings at the measured mean routed
    tokens per iteration (``tokens_per_s`` is exposed for diagnostics).
    Before the first observation it falls back to ``default_tokens``.
    """

    def __init__(self, g: MoEGeometry, ep: int, horizon_iters: int,
                 default_tokens: float = 4096.0, window: int = 64,
                 bandwidth=None):
        self.g, self.ep = g, ep
        self.horizon_iters = int(horizon_iters)
        self.default_tokens = float(default_tokens)
        self.window = int(window)
        # migration-side calibration twin of tokens_per_iter: None until
        # a manager wires its measured-bandwidth EWMA in (then replans
        # are priced at observed apply_to_params bytes/s, not ICI_BW)
        self.bandwidth = bandwidth
        # savings-side calibration: None until the profiler wires its
        # measured/predicted drift EWMA in (then predicted savings are
        # rescaled by how fast the hardware actually runs the model)
        self.time_scale = None
        self._tokens: List[float] = []
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._total_tokens = 0.0
        self.n_obs = 0

    def observe_iter(self, tokens: float, t_wall: float = 0.0) -> None:
        self._tokens.append(float(tokens))
        if len(self._tokens) > self.window:
            self._tokens.pop(0)
        if self._t_first is None:
            self._t_first = float(t_wall)
        self._t_last = float(t_wall)
        self._total_tokens += float(tokens)
        self.n_obs += 1

    @property
    def tokens_per_iter(self) -> float:
        if not self._tokens:
            return self.default_tokens
        return float(np.mean(self._tokens))

    @property
    def tokens_per_s(self) -> float:
        """Measured throughput over the observed span (diagnostics)."""
        if self._t_first is None or self._t_last is None \
                or self._t_last <= self._t_first:
            return 0.0
        return self._total_tokens / (self._t_last - self._t_first)

    def _gate(self) -> ReplanCostGate:
        return ReplanCostGate(self.g, self.ep, self.horizon_iters,
                              tokens_per_iter=self.tokens_per_iter,
                              bandwidth=self.bandwidth,
                              time_scale=self.time_scale)

    def layer_seconds(self, rank_loads: np.ndarray) -> float:
        return self._gate().layer_seconds(rank_loads)

    def accept(self, old_rank_loads, new_rank_loads, n_moved: int) -> bool:
        return self._gate().accept(old_rank_loads, new_rank_loads, n_moved)

    def accept_layers(self, old_rank_loads, new_rank_loads,
                      n_moved: int) -> bool:
        return self._gate().accept_layers(old_rank_loads, new_rank_loads,
                                          n_moved)


def nongemm_time(tokens_r: float, g: MoEGeometry) -> float:
    """Router/softmax/sort/norm — bandwidth-ish + fixed kernel costs.
    Dominates at small batch (the LB-gate regime, Fig 4)."""
    return (tokens_r * g.d_model * 6.0) / HBM_BW + 3 * FIXED_US * 1e-6


def moe_layer_time(load: np.ndarray, fp4_mask: np.ndarray, g: MoEGeometry,
                   ep: int, tokens: float, visible_lb_s: float = 0.0
                   ) -> Tuple[float, np.ndarray]:
    per_rank = np.array([
        expert_gemm_time(load[r], g, ep, bool(fp4_mask[r]))
        + nongemm_time(load[r], g)
        for r in range(ep)])
    t = 2 * dispatch_time(tokens * g.top_k, ep, g.d_model) + per_rank.max() \
        + visible_lb_s
    return t, per_rank


# --------------------------------------------------------------------------
# strategy simulators
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SimResult:
    name: str
    layer_times: np.ndarray          # [iters] mean MoE layer time (s)
    fp4_token_frac: float            # fraction of routed tokens through FP4
    extra: Dict[str, List[float]]

    @property
    def mean_layer_ms(self) -> float:
        return float(self.layer_times.mean() * 1e3)

    def e2e_speedup(self, baseline: "SimResult", g: MoEGeometry) -> float:
        s = g.moe_time_share
        base = baseline.layer_times.mean()
        mine = self.layer_times.mean()
        return float(1.0 / (1.0 - s + s * (mine / base)))


def _sim(cfg: tr.TraceConfig, g: MoEGeometry, decide, name: str,
         visible_lb=lambda it: 0.0, placement=None) -> SimResult:
    ep = cfg.ep
    place = tr.default_placement(g.n_experts, ep) if placement is None \
        else placement
    times, fp4_tokens, tot_tokens = [], 0.0, 0.0
    extra: Dict[str, List[float]] = {"ib_global": [], "fp4_ranks": [],
                                     "m_d": []}
    state = {"place": place}
    for step in tr.generate(cfg):
        pl = state["place"]
        load, vis = tr.rank_loads(step, pl, ep)
        fp4_mask, diag = decide(step, load, vis, state)
        t, _ = moe_layer_time(load, fp4_mask, g, ep, step.tokens,
                              visible_lb(step.it) + diag.get("extra_s", 0.0))
        times.append(t)
        fp4_tokens += float(load[fp4_mask.astype(bool)].sum())
        tot_tokens += float(load.sum())
        extra["ib_global"].append(float(load.max() / max(load.mean(), 1e-9)))
        extra["fp4_ranks"].append(float(fp4_mask.sum()))
        extra["m_d"].append(diag.get("m_mean", 1.0))
    return SimResult(name, np.array(times), fp4_tokens / max(tot_tokens, 1),
                     extra)


def sim_baseline(cfg, g) -> SimResult:
    return _sim(cfg, g, lambda s, l, v, st: (np.zeros(cfg.ep), {}),
                "Baseline")


def sim_fp4_all(cfg, g) -> SimResult:
    return _sim(cfg, g, lambda s, l, v, st: (np.ones(cfg.ep), {}),
                "FP4-All")


def make_realb(g, rcfg, adaptive=True, m_fixed: Optional[float] = None,
               overlap=True):
    """ReaLB decision fn wrapping the real repro.core.policy controller."""
    import jax.numpy as jnp

    from repro.core.policy import realb_policy

    def decide(step, load, vis, state):
        m = state.setdefault("m_d", np.full(load.shape, rcfg.md_init))
        if m_fixed is not None:
            m = np.full(load.shape, m_fixed)
        dec = realb_policy(jnp.asarray(load), jnp.asarray(vis),
                           jnp.asarray(m), rcfg)
        if m_fixed is None and adaptive:
            state["m_d"] = np.asarray(dec.m_new)
        extra = 0.0
        if not overlap:
            # ReaLB-seq: metadata + transformation land on the critical path
            extra = quantize_time(g, load.shape[0]) + 15e-6
        return (np.asarray(dec.use_fp4, dtype=np.float64),
                {"m_mean": float(np.mean(state.get("m_d", m))),
                 "extra_s": extra})

    return decide


def sim_realb(cfg, g, rcfg, name="ReaLB", adaptive=True,
              m_fixed=None, overlap=True) -> SimResult:
    return _sim(cfg, g, make_realb(g, rcfg, adaptive, m_fixed, overlap),
                name)


def sim_eplb(cfg, g, window=100, interval=100, redundant=8,
             async_transfer=False, name="EPLB") -> SimResult:
    """Sliding-window prediction + hot-expert replication (EPLB-like)."""
    ep = cfg.ep
    e = g.n_experts
    e_loc = e // ep
    hist: List[np.ndarray] = []
    expert_bytes = 3.0 * g.d_model * g.d_ff * BYTES_BF16

    def decide(step, load, vis, state):
        hist.append(step.expert_load.copy())
        extra = 0.0
        if step.it > 0 and step.it % interval == 0 and len(hist) >= 10:
            avg = np.mean(hist[-window:], axis=0)
            hot = np.argsort(avg)[-redundant:]
            # fractional placement: hot experts split across 2 ranks
            mat = np.zeros((e, ep))
            base = tr.default_placement(e, ep)
            for e_id in range(e):
                mat[e_id, base[e_id]] = 1.0
            order = np.argsort(avg[hot])
            for j, e_id in enumerate(hot[order]):
                mirror = int(np.argmin(mat.T @ avg))
                mat[e_id] *= 0.5
                mat[e_id, mirror] += 0.5
            state["place"] = mat
            moved = redundant
            if not async_transfer:
                extra = moved * expert_bytes / ICI_BW / max(g.n_moe_layers, 1)
        return np.zeros(ep), {"extra_s": extra}

    return _sim(cfg, g, decide, name)


# --------------------------------------------------------------------------
# predictive placement strategies (repro.placement on the same traces)
# --------------------------------------------------------------------------
def make_placement(g: MoEGeometry, ep: int, planner: str = "least_loaded",
                   interval: int = 50, warmup: int = 8,
                   alpha: float = 0.25, min_gain: float = 0.02):
    """Decision fn driving the *real* serving-side PlacementManager
    (same predictor, planner, cadence and churn guard); FP4 stays off.

    Returns (decide, manager) — the manager carries the cumulative
    migration accounting for the strategy comparison.
    """
    from repro.configs.base import PlacementConfig
    from repro.placement import PlacementManager

    pcfg = PlacementConfig(planner=planner, replan_every=interval,
                           warmup_iters=warmup, ewma_alpha=alpha,
                           min_gain=min_gain)
    mgr = PlacementManager.from_geometry(
        g.n_experts, pcfg, ep,
        bytes_per_expert=int(migration_bytes(1, g)))

    def decide(step, load, vis, state):
        mgr.observe(np.stack([step.expert_load,
                              step.expert_vis])[None])       # [1, 2, E]
        extra = 0.0
        plan = mgr.maybe_replan(step.it) if step.it > 0 else None
        if plan is not None:
            mgr.commit(plan)           # sim: the slab copy is atomic
            state["place"] = mgr.table.e2r        # rank_loads view
            # amortized per MoE layer (the trace step is one layer)
            extra = migration_time(plan.n_moved, g) / g.n_moe_layers
        return np.zeros(ep), {"extra_s": extra}

    return decide, mgr


def _attach_migration(res: SimResult, mgr) -> SimResult:
    res.extra["n_migrations"] = [float(mgr.n_migrations)]
    res.extra["moved_bytes"] = [float(mgr.migrated_bytes)]
    return res


def sim_placement(cfg, g, planner="least_loaded", interval=50,
                  name="Placement") -> SimResult:
    decide, mgr = make_placement(g, cfg.ep, planner, interval)
    return _attach_migration(_sim(cfg, g, decide, name), mgr)


def sim_realb_placement(cfg, g, rcfg, planner="modality_aware",
                        interval=50, name="ReaLB+Placement") -> SimResult:
    """The hybrid arm: the planner remaps slow-timescale skew, ReaLB's
    AIMD controller compresses whatever burst the plan could not predict.
    The ReaLB decision runs on the *placed* per-rank loads the simulator
    computes from the current table."""
    p_decide, mgr = make_placement(g, cfg.ep, planner, interval)
    r_decide = make_realb(g, rcfg)

    def decide(step, load, vis, state):
        fp4, r_diag = r_decide(step, load, vis, state)
        _, p_diag = p_decide(step, load, vis, state)
        return fp4, {"extra_s": r_diag.get("extra_s", 0.0)
                     + p_diag.get("extra_s", 0.0),
                     "m_mean": r_diag.get("m_mean", 1.0)}

    return _attach_migration(_sim(cfg, g, decide, name), mgr)


# --------------------------------------------------------------------------
# redundant-expert strategies (repro.replication on the same traces)
# --------------------------------------------------------------------------
def make_replication(g: MoEGeometry, ep: int, interval: int = 50,
                     warmup: int = 8, alpha: float = 0.25,
                     min_gain: float = 0.02, spare_per_rank: int = 1,
                     max_replicas: int = 2, vis_weight: float = 1.0,
                     cost_gate=None):
    """Decision fn driving the *real* serving-side ReplicaManager (same
    predictor, EPLB-style planner, staged-commit discipline); FP4 stays
    off.  The simulator models the round-robin token split as fractional
    ownership rows (``traces.rank_loads``)."""
    from repro.configs.base import ReplicationConfig
    from repro.replication import ReplicaManager

    rpcfg = ReplicationConfig(replan_every=interval, warmup_iters=warmup,
                              ewma_alpha=alpha, min_gain=min_gain,
                              spare_per_rank=spare_per_rank,
                              max_replicas=max_replicas,
                              vis_weight=vis_weight)
    mgr = ReplicaManager.from_geometry(
        g.n_experts, rpcfg, ep,
        bytes_per_expert=int(migration_bytes(1, g)), cost_gate=cost_gate)

    def decide(step, load, vis, state):
        mgr.observe(np.stack([step.expert_load,
                              step.expert_vis])[None])        # [1, 2, E]
        extra = 0.0
        plan = mgr.maybe_replan(step.it) if step.it > 0 else None
        if plan is not None:
            mgr.commit(plan)           # sim: the slab copy is atomic
            state["place"] = mgr.rset.ownership_matrix()
            # amortized per MoE layer; only cross-rank slabs travel
            extra = migration_time(len(plan.crossrank_slots),
                                   g) / g.n_moe_layers
        return np.zeros(ep), {"extra_s": extra}

    return decide, mgr


def sim_replication(cfg, g, interval=50, name="Replicate",
                    **kw) -> SimResult:
    decide, mgr = make_replication(g, cfg.ep, interval, **kw)
    return _attach_migration(_sim(cfg, g, decide, name), mgr)


def sim_realb_replication(cfg, g, rcfg, interval=50,
                          name="ReaLB+Replicate", **kw) -> SimResult:
    """The precision hybrid: replication flattens the predictable hot
    experts, ReaLB's FP4 compresses whatever burst the replica set could
    not anticipate — the decision runs on the *post-split* rank loads."""
    p_decide, mgr = make_replication(g, cfg.ep, interval, **kw)
    r_decide = make_realb(g, rcfg)

    def decide(step, load, vis, state):
        fp4, r_diag = r_decide(step, load, vis, state)
        _, p_diag = p_decide(step, load, vis, state)
        return fp4, {"extra_s": r_diag.get("extra_s", 0.0)
                     + p_diag.get("extra_s", 0.0),
                     "m_mean": r_diag.get("m_mean", 1.0)}

    return _attach_migration(_sim(cfg, g, decide, name), mgr)


# --------------------------------------------------------------------------
# per-layer strategies: depth-varying skew, one table per layer
# --------------------------------------------------------------------------
def generate_layers(cfg: tr.TraceConfig, n_layers: int,
                    seed_stride: int = 101):
    """Zip ``n_layers`` traces with depth-varying skew (layer ``l``
    re-seeded, so each layer's hot-expert set drifts independently —
    the paper's Fig. 2 observation that vision-token concentration varies
    sharply across depth).  Yields ``[L]`` tuples of TraceSteps."""
    gens = [tr.generate(dataclasses.replace(cfg,
                                            seed=cfg.seed + seed_stride * l))
            for l in range(n_layers)]
    yield from zip(*gens)


def _sim_layers(cfg: tr.TraceConfig, g: MoEGeometry, n_layers: int,
                mgr, rank_view, name: str,
                drain_bytes_per_iter: Optional[int] = None) -> SimResult:
    """Shared harness of the per-layer strategy sims: feed the real
    manager stacked ``[L, 2, E]`` stats, apply its (layer-diff) plans,
    and score the depth-peak rank imbalance plus the mean layer time.
    ``rank_view(mgr, l)`` exposes the current *routable* table of layer
    ``l`` as a ``traces.rank_loads`` placement argument.

    ``drain_bytes_per_iter`` selects the async overlapped-migration
    mode: a staged plan's chunks land over the following iterations (at
    most the budget of bytes per iteration, each landed layer committed
    independently), so serving keeps routing old tables for layers still
    in flight and the per-iteration stall is the transfer seconds of
    the *excess* over the budget only (the budgeted share hides under
    the iteration's compute).  ``None`` is the synchronous baseline: the
    whole plan lands — and stalls — in the iteration it fired."""
    ep = cfg.ep
    times: List[float] = []
    extra: Dict[str, List[float]] = {"ib_global": [], "fp4_ranks": [],
                                     "m_d": [], "mig_stall_s": [],
                                     "mig_hidden_s": []}
    pending = None                     # (plan, [SlabChunk-like queue])
    for steps in generate_layers(cfg, n_layers):
        es = np.stack([np.stack([s.expert_load, s.expert_vis])
                       for s in steps])                       # [L, 2, E]
        mgr.observe(es)
        it = steps[0].it
        stall_s = hidden_s = 0.0
        if pending is None:
            plan = mgr.maybe_replan(it) if it > 0 else None
            if plan is not None:
                chunks = [(l, mgr.layer_bytes(plan, l))
                          for l in mgr.plan_layers(plan)]
                if drain_bytes_per_iter is None:
                    # synchronous: whole plan lands now, whole transfer
                    # stalls this iteration (amortized per model layer;
                    # layer-diff plans already cover changed layers only)
                    mgr.commit(plan)
                    stall_s = (plan.moved_bytes / ICI_BW) \
                        / max(g.n_moe_layers, 1)
                else:
                    pending = (plan, chunks)
        if pending is not None:
            plan, chunks = pending
            budget = max(int(drain_bytes_per_iter), 1)
            batch = [chunks.pop(0)]
            while chunks and sum(b for _, b in batch) + chunks[0][1] \
                    <= budget:
                batch.append(chunks.pop(0))
            nbytes = sum(b for _, b in batch)
            mgr.commit_layers(plan, [l for l, _ in batch])
            excess = max(0, nbytes - budget)
            stall_s = (excess / ICI_BW) / max(g.n_moe_layers, 1)
            hidden_s = ((nbytes - excess) / ICI_BW) \
                / max(g.n_moe_layers, 1)
            if not chunks:
                pending = None
        t_layers, ib_layers = [], []
        for l, s in enumerate(steps):
            load, _ = tr.rank_loads(s, rank_view(mgr, l), ep)
            t, _ = moe_layer_time(load, np.zeros(ep), g, ep, s.tokens,
                                  stall_s)
            t_layers.append(t)
            ib_layers.append(float(load.max() / max(load.mean(), 1e-9)))
        times.append(float(np.mean(t_layers)))
        # the acceptance metric: PEAK rank imbalance across depth — the
        # straggler layer sets the iteration time
        extra["ib_global"].append(float(np.max(ib_layers)))
        extra["fp4_ranks"].append(0.0)
        extra["m_d"].append(1.0)
        extra["mig_stall_s"].append(stall_s)
        extra["mig_hidden_s"].append(hidden_s)
    return _attach_migration(SimResult(name, np.array(times), 0.0, extra),
                             mgr)


def _placement_layers_mgr(cfg, g, n_layers, per_layer, planner, interval,
                          warmup, min_gain, audit=None):
    from repro.configs.base import PlacementConfig
    from repro.placement import PlacementManager

    pcfg = PlacementConfig(planner=planner, replan_every=interval,
                           warmup_iters=warmup, min_gain=min_gain,
                           per_layer=per_layer)
    bpe = int(migration_bytes_layers(1, g, n_layers)) if per_layer \
        else int(migration_bytes(1, g))
    mgr = PlacementManager.from_geometry(g.n_experts, pcfg, cfg.ep,
                                         bytes_per_expert=bpe,
                                         n_layers=n_layers)
    if audit is not None:
        mgr.audit = audit
    return mgr


def _placement_rank_view(m, l):
    return m.tables[l if m.per_layer else 0].e2r


def sim_placement_layers(cfg, g, n_layers: int = 4, per_layer: bool = True,
                         planner: str = "least_loaded", interval: int = 50,
                         warmup: int = 8, min_gain: float = 0.02,
                         name: Optional[str] = None,
                         audit=None) -> SimResult:
    """Placement on a depth-varying trace: ``per_layer=True`` plans one
    table per layer (layer-diff migration), ``False`` is the shared-table
    baseline that balances the depth-summed skew no single layer has."""
    mgr = _placement_layers_mgr(cfg, g, n_layers, per_layer, planner,
                                interval, warmup, min_gain, audit=audit)
    return _sim_layers(cfg, g, n_layers, mgr, _placement_rank_view,
                       name=name or ("Placement/L" if per_layer
                                     else "Placement(shared)"))


def sim_placement_async(cfg, g, n_layers: int = 4,
                        bytes_per_iter: Optional[int] = None,
                        planner: str = "least_loaded", interval: int = 50,
                        warmup: int = 8, min_gain: float = 0.02,
                        name: str = "Placement/L/async",
                        audit=None) -> SimResult:
    """Async overlapped placement migration: the per-layer plan's chunks
    drain one byte-budgeted batch per iteration (default budget: one
    layer's worst-case slab, so every per-layer chunk fits), each landed
    layer committed independently — per-iteration stall is bounded by
    the budget excess while the synchronous arm charges the whole
    transfer in the iteration the plan fired."""
    mgr = _placement_layers_mgr(cfg, g, n_layers, True, planner,
                                interval, warmup, min_gain, audit=audit)
    if bytes_per_iter is None:
        bytes_per_iter = int(g.n_experts
                             * migration_bytes_layers(1, g, n_layers))
    return _sim_layers(cfg, g, n_layers, mgr, _placement_rank_view,
                       name=name, drain_bytes_per_iter=bytes_per_iter)


def _replication_layers_mgr(cfg, g, n_layers, per_layer, interval, warmup,
                            min_gain, spare_per_rank, max_replicas,
                            audit=None):
    from repro.configs.base import ReplicationConfig
    from repro.replication import ReplicaManager

    rpcfg = ReplicationConfig(replan_every=interval, warmup_iters=warmup,
                              min_gain=min_gain, per_layer=per_layer,
                              spare_per_rank=spare_per_rank,
                              max_replicas=max_replicas)
    bpe = int(migration_bytes_layers(1, g, n_layers)) if per_layer \
        else int(migration_bytes(1, g))
    mgr = ReplicaManager.from_geometry(g.n_experts, rpcfg, cfg.ep,
                                       bytes_per_expert=bpe,
                                       n_layers=n_layers)
    if audit is not None:
        mgr.audit = audit
    return mgr


def _replication_rank_view(m, l):
    return m.rsets[l if m.per_layer else 0].ownership_matrix()


def sim_replication_layers(cfg, g, n_layers: int = 4,
                           per_layer: bool = True, interval: int = 50,
                           warmup: int = 8, min_gain: float = 0.02,
                           spare_per_rank: int = 1, max_replicas: int = 2,
                           name: Optional[str] = None,
                           audit=None) -> SimResult:
    """Redundant experts on a depth-varying trace, per-layer replica sets
    vs one shared set (token split modeled as fractional ownership)."""
    mgr = _replication_layers_mgr(cfg, g, n_layers, per_layer, interval,
                                  warmup, min_gain, spare_per_rank,
                                  max_replicas, audit=audit)
    return _sim_layers(cfg, g, n_layers, mgr, _replication_rank_view,
                       name=name or ("Replicate/L" if per_layer
                                     else "Replicate(shared)"))


def sim_replication_async(cfg, g, n_layers: int = 4,
                          bytes_per_iter: Optional[int] = None,
                          interval: int = 50, warmup: int = 8,
                          min_gain: float = 0.02, spare_per_rank: int = 1,
                          max_replicas: int = 2,
                          name: str = "Replicate/L/async",
                          audit=None) -> SimResult:
    """Async overlapped replica add/drop: staged per-layer replica plans
    drain chunk-by-chunk (a replica becomes routable as its layer's slab
    lands), bounding the per-iteration stall by the byte budget."""
    mgr = _replication_layers_mgr(cfg, g, n_layers, True, interval,
                                  warmup, min_gain, spare_per_rank,
                                  max_replicas, audit=audit)
    if bytes_per_iter is None:
        # worst-case layer chunk: every slot of one layer sourced
        # cross-rank — any real chunk fits the budget
        bytes_per_iter = int((g.n_experts + cfg.ep * spare_per_rank)
                             * migration_bytes_layers(1, g, n_layers))
    return _sim_layers(cfg, g, n_layers, mgr, _replication_rank_view,
                       name=name, drain_bytes_per_iter=bytes_per_iter)
