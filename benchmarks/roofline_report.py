"""Regenerate the EXPERIMENTS.md §Roofline table from dry-run records.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single_pod]

Reads experiments/dryrun/*.json (skipping .base/.opt §Perf variants) and
prints the per-cell roofline terms as a markdown table, plus the
single-pod↔multi-pod collective-byte scaling comparison.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import ALL_SHAPES, ARCH_IDS

NOTES = {
    ("memory", "train"): "remat policy + f32 moment traffic",
    ("memory", "prefill"): "fused Pallas flash kernel",
    ("memory", "decode"): "w4 weight/cache streaming (paper's lever)",
    ("collective", "train"): "bwd all-reduce→reduce-scatter",
    ("collective", "decode"): "replicate small weights at inference",
    ("collective", "prefill"): "a2a capacity ↓ + overlap",
    ("memory", "long"): "SSM state chunking in VMEM",
    ("collective", "long"): "replicate small weights at inference",
}


def load(outdir="experiments/dryrun"):
    recs = {}
    for f in pathlib.Path(outdir).glob("*.json"):
        if ".base" in f.name or ".opt" in f.name:
            continue
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.outdir)

    print("| arch | shape | dominant | bound s | compute s | memory s |"
          " collective s | RF | MF/HF | temp GB/dev | next lever |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_IDS:
        for s in ALL_SHAPES:
            r = recs.get((a, s.name, args.mesh))
            if r is None:
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s.name} | — | skip | | | | | | | |")
                continue
            rf = r["roofline"]
            kind = "long" if s.name == "long_500k" else s.kind
            note = NOTES.get((rf["dominant"], kind), "")
            print(f"| {a} | {s.name} | {rf['dominant']} |"
                  f" {rf['bound_s']:.4f} | {rf['compute_s']:.4f} |"
                  f" {rf['memory_s']:.4f} | {rf['collective_s']:.4f} |"
                  f" {rf['roofline_fraction']:.3f} |"
                  f" {r['useful_flop_ratio']:.3f} |"
                  f" {r['memory']['temp_bytes']/1e9:.1f} | {note} |")

    print()
    print("| arch | coll GB/dev (256) | coll GB/dev (512) | ratio |")
    print("|---|---|---|---|")
    for a in ARCH_IDS:
        r1 = recs.get((a, "train_4k", "single_pod"))
        r2 = recs.get((a, "train_4k", "multi_pod"))
        if r1 and r2 and r1["status"] == "ok":
            c1 = r1["collective_bytes_per_device"]
            c2 = r2["collective_bytes_per_device"]
            print(f"| {a} | {c1/1e9:.1f} | {c2/1e9:.1f} |"
                  f" {c2/max(c1, 1):.2f} |")

    fused_vs_unfused()


def fused_vs_unfused(ep: int = 16):
    """Analytic fused-vs-unfused FP4 expert-FFN arm (costmodel terms).

    ``fused`` is what the serving hot loop now runs (the Pallas grouped
    FP4 FFN + quantize kernels: packed weights stream once, the
    transformation hides inside the dispatch window); ``unfused`` is the
    jnp fallback (BF16 dequant slab round-trips HBM, the transformation
    is a fully visible stage).  Per-rank per-layer seconds on the paper
    geometries at a sweep of routed-token loads.
    """
    from benchmarks import costmodel as cm

    print()
    print(f"### FP4 expert FFN: fused kernel vs unfused fallback "
          f"(analytic, per rank/layer, ep={ep})")
    print("| geometry | tokens/rank | bf16 s | fp4 unfused s | "
          "fp4 fused s | fused/unfused | fused gemm only s |")
    print("|---|---:|---:|---:|---:|---:|---:|")
    for g in (cm.KIMI_VL, cm.QWEN3_VL):
        for t in (64.0, 512.0, 4096.0):
            disp = cm.dispatch_time(t * ep, ep, g.d_model)
            bf16 = cm.expert_gemm_time(t, g, ep, fp4=False)
            unf = (cm.expert_gemm_time(t, g, ep, fp4=True, fused=False)
                   + cm.quantize_visible_time(g, ep, disp, fused=False))
            fus = (cm.expert_gemm_time(t, g, ep, fp4=True, fused=True)
                   + cm.quantize_visible_time(g, ep, disp, fused=True))
            gemm_f = cm.expert_gemm_time(t, g, ep, fp4=True, fused=True)
            print(f"| {g.name} | {t:.0f} | {bf16 * 1e3:.3f} |"
                  f" {unf * 1e3:.3f} | {fus * 1e3:.3f} |"
                  f" {fus / unf:.2f} | {gemm_f * 1e3:.3f} |")


if __name__ == "__main__":
    main()
