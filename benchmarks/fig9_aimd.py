"""Fig 9 / Appendix H: AIMD controller dynamics — evolution of M_d and
IB_global over iterations on DynaMath (the real repro.core.policy code).

CSV: iter,ib_global,m_d_mean,m_d_min,congested,fp4_ranks
"""
from __future__ import annotations

import numpy as np

from benchmarks import costmodel as cm
from benchmarks import traces as tr
from repro.configs import ReaLBConfig


def run(iters: int = 300, stride: int = 5):
    g = cm.KIMI_VL
    rcfg = ReaLBConfig()
    cfg = tr.workload("DynaMath", iters=iters, n_experts=g.n_experts,
                      top_k=g.top_k)
    import jax.numpy as jnp

    from repro.core.policy import realb_policy
    place = tr.default_placement(g.n_experts, cfg.ep)
    m = np.full(cfg.ep, rcfg.md_init)
    rows = []
    for step in tr.generate(cfg):
        load, vis = tr.rank_loads(step, place, cfg.ep)
        dec = realb_policy(jnp.asarray(load), jnp.asarray(vis),
                           jnp.asarray(m), rcfg)
        m = np.asarray(dec.m_new)
        if step.it % stride == 0:
            rows.append(dict(
                iter=step.it,
                ib_global=round(float(dec.ib_global), 3),
                m_d_mean=round(float(m.mean()), 3),
                m_d_min=round(float(m.min()), 3),
                congested=int(float(dec.ib_global) > rcfg.tau),
                fp4_ranks=int(np.asarray(dec.use_fp4).sum())))
    return rows


def main():
    rows = run()
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
